"""Paper Figure 5: virtual model vs PHYSICAL prototype.

The paper validated an AVSM of a Virtex-7 FPGA against the real board
(8.3 % end-to-end deviation, 0.6-11.2 % per layer).  Our physical hardware
is this container's CPU: we calibrate a virtual CPU model from two
microbenchmarks (achieved GEMM FLOP/s, achieved stream bandwidth — the
paper's 'import physical annotations' step), then predict the runtime of
held-out workloads with the AVSM and compare against measured wall-clock.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.avsm.model import build_avsm
from repro.core.hw import container_cpu_system
from repro.core.taskgraph.ops import LayerOp, elementwise_op, matmul_op


def _time_jit(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def calibrate() -> Tuple[float, float, float]:
    """Measure achieved matmul FLOP/s, bandwidth, and launch overhead —
    the paper's 'physical annotations' imported into the virtual model."""
    # matmul throughput at two operating points (large square + skinny MLP
    # shape); geometric mean annotates the virtual compute engine
    rates = []
    for (m, k, n) in ((1024, 1024, 1024), (512, 768, 3072)):
        a = jnp.ones((m, k), jnp.float32)
        b = jnp.ones((k, n), jnp.float32)
        t = _time_jit(jax.jit(lambda a, b: a @ b), a, b, iters=8)
        rates.append(2.0 * m * k * n / t)
    flops = float(np.sqrt(rates[0] * rates[1]))

    # streaming bandwidth: one fused read+write pass over a large buffer
    big = jnp.ones((64 * 1024 * 1024 // 4,), jnp.float32)   # 64 MB
    t_cp = _time_jit(jax.jit(lambda x: x * 1.0001 + 0.5), big, iters=8)
    bw = 2 * big.size * 4 / t_cp

    tiny = jnp.ones((8,), jnp.float32)
    t_launch = _time_jit(jax.jit(lambda x: x + 1), tiny, iters=50)
    return flops, bw, t_launch


def _workloads(n_layers=4, d=768, t=256, f=3072):
    """Held-out workloads: (name, jit fn, args, LayerOp graph)."""
    k = jax.random.key(0)
    ws = {
        "w1": jax.random.normal(k, (n_layers, d, f), jnp.float32) * 0.02,
        "w2": jax.random.normal(k, (n_layers, f, d), jnp.float32) * 0.02,
    }
    x = jax.random.normal(k, (t, d), jnp.float32)

    def mlp_stack(x, ws):
        for i in range(n_layers):
            x = jnp.maximum(x @ ws["w1"][i], 0.0) @ ws["w2"][i]
        return x

    # the DL compiler is part of the flow (paper Fig 1): XLA fuses the relu
    # into the preceding matmul, so the hardware-adapted task graph must NOT
    # model it as a separate memory-traffic op.
    ops_mlp = []
    for i in range(n_layers):
        ops_mlp.append(matmul_op(f"l{i}/up", f"l{i}", t, d, f, 4))
        ops_mlp.append(matmul_op(f"l{i}/down", f"l{i}", t, f, d, 4))

    n2 = 1536
    y = jax.random.normal(k, (n2, n2), jnp.float32)

    def mm_chain(y):
        for _ in range(6):
            y = y @ y
        return y

    ops_mm = [matmul_op(f"mm{i}", f"mm{i}", n2, n2, n2, 4) for i in range(6)]

    v = jax.random.normal(k, (48 * 1024 * 1024 // 4,), jnp.float32)

    def elemwise(v):
        for _ in range(4):
            v = v * 1.0001 + 0.5
        return v

    # compiler-aware task graph: XLA fuses the 4 chained multiply-adds into
    # a single pass over memory -> ONE elementwise op in the graph
    ops_ew = [elementwise_op("ew_fused", "ew_fused", v.size * 4,
                             v.size * 4, 8, 4)]

    return [("mlp_stack", mlp_stack, (x, ws), ops_mlp),
            ("matmul_chain", mm_chain, (y,), ops_mm),
            ("elementwise", elemwise, (v,), ops_ew)]


def run() -> List[Tuple[str, float, str]]:
    flops, bw, launch = calibrate()
    system = container_cpu_system(flops=flops, mem_bw=bw,
                                  launch_overhead=launch)
    rows = [("fig5_calibration", 0.0,
             f"achieved={flops / 1e9:.1f}GFLOP/s bw={bw / 1e9:.1f}GB/s "
             f"launch={launch * 1e6:.0f}us")]
    devs = []
    for name, fn, args, ops in _workloads():
        measured = _time_jit(jax.jit(fn), *args)
        predicted = build_avsm(ops, system).simulate().step_time
        dev = abs(predicted - measured) / measured * 100
        devs.append(dev)
        rows.append((f"fig5_{name}", measured * 1e6,
                     f"pred={predicted * 1e3:.2f}ms "
                     f"meas={measured * 1e3:.2f}ms dev={dev:.1f}%"))
    rows.append(("fig5_mean_deviation", float(np.mean(devs)) * 1e4,
                 f"mean_dev={np.mean(devs):.1f}% (paper: 8.3% end-to-end, "
                 f"0.6-11.2% per layer)"))
    return rows
