"""What-if DSE engine: re-annotation fast path vs full recompile.

The paper's Figure 3 argument is turn-around time: a sweep point must not
pay SystemC (here: task-graph) regeneration.  This benchmark measures, on
the pod-scale deepseek-v2 training graph (~64k tasks):

  * parity  — the re-annotated graph's DES step time vs a full recompile's
    (acceptance: within 1%);
  * speed   — model-regeneration seconds per sweep point (acceptance: the
    fast path is >= 10x faster than recompiling);
  * escalation — roofline-prune -> DES-confirm over chip variants.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.avsm.model import AVSM, annotate_system, build_avsm
from repro.core.config import LM_SHAPES, get_arch
from repro.core.dse import DesignSpaceExplorer
from repro.core.hw import tpu_v5e_pod
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

SWEEP = [("link_bandwidth", 100e9), ("mem_bandwidth", 1638e9),
         ("matrix_flops", 394e12), ("launch_overhead", 0.6e-6),
         ("num_dma_engines", 4)]


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    spec = get_arch("deepseek-v2-236b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"deepseek_train": ops})
    graph = dse.compiled("deepseek_train", base)
    graph.anno_arrays()                     # steady-state sweep loop
    avsm = AVSM(system=base, graph=graph)

    worst_err = 0.0
    t_fast_tot = t_full_tot = 0.0
    for key, val in SWEEP:
        t0 = time.perf_counter()
        fast = avsm.what_if(**{key: val})
        t_fast = time.perf_counter() - t0
        t0 = time.perf_counter()
        full = build_avsm(ops, fast.system, graph.plan)
        t_full = time.perf_counter() - t0
        step_fast = fast.simulate().step_time
        step_full = full.simulate().step_time
        err = abs(step_fast - step_full) / step_full
        worst_err = max(worst_err, err)
        t_fast_tot += t_fast
        t_full_tot += t_full
        rows.append((f"dse_whatif_{key}", t_fast * 1e6,
                     f"recompile={t_full * 1e6:.0f}us "
                     f"speedup={t_full / t_fast:.0f}x err={err:.2e}"))
    rows.append(("dse_whatif_total", t_fast_tot * 1e6,
                 f"{len(SWEEP)} points, recompile={t_full_tot:.2f}s, "
                 f"speedup={t_full_tot / t_fast_tot:.0f}x, "
                 f"worst_err={worst_err:.2e} "
                 f"(accept: err<1e-2, speedup>=10x)"))

    # roofline-prune -> DES-confirm over chip variants
    variants = {
        "v5e": base,
        "2x_ici": annotate_system(base, link_bandwidth=100e9),
        "2x_hbm": annotate_system(base, mem_bandwidth=1638e9),
        "2x_mxu": annotate_system(base, matrix_flops=394e12),
        "2x_all": annotate_system(base, link_bandwidth=100e9,
                                  mem_bandwidth=1638e9, matrix_flops=394e12),
    }
    t0 = time.perf_counter()
    confirmed = dse.explore(variants, keep=2)
    wall = time.perf_counter() - t0
    best = confirmed[0]
    rows.append(("dse_escalation", wall * 1e6,
                 f"{len(variants)} variants -> {len(confirmed)} DES-confirmed"
                 f", best={best.system} "
                 f"({best.confirmed.step_time * 1e3:.1f}ms), "
                 f"compiles={dse.stats['compiles']} "
                 f"reannot={dse.stats['reannotations']}"))
    return rows
