"""DES-engine microbenchmarks: event throughput and contention scaling.

Measures the simulation core this PR optimized:

  * fifo event throughput — a layered 10k-task DAG over 4 FIFO resources,
    dict-based general engine vs the array-backed static fast path (cold
    cache = first sweep point, warm cache = steady-state what-if loop);
  * shared-channel scaling — n concurrent transfers with distinct
    durations on one width-2 processor-sharing channel.  Virtual-time GPS
    completes each in O(log n); the seed engine's per-event remaining-work
    sweep was O(n), i.e. O(n^2) per burst, so its throughput collapsed
    with n (see ``BASELINE_PR2`` in ``perf_record.py`` for the measured
    collapse: 10.6k -> 1.3k tasks/s from n=200 to n=6400).  Acceptance:
    throughput stays roughly flat with n.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core.sim.engine import (DynamicSimulator, GraphTemplate,
                                   ResourceSpec, Simulator, StaticCache,
                                   Task, simulate_static)

SHARED_NS = (200, 800, 3200, 6400)


def layered_dag(n_layers: int = 200, width: int = 50) -> List[Task]:
    """A deep, wide DAG: each task depends on two tasks of the previous
    layer and lands on one of four FIFO resources."""
    tasks: List[Task] = []
    tid = 0
    prev: List[int] = []
    for layer in range(n_layers):
        cur = []
        for w in range(width):
            tasks.append(Task(tid, f"t{tid}", f"L{layer}", f"r{w % 4}",
                              1e-6, deps=tuple(prev[:2])))
            cur.append(tid)
            tid += 1
        prev = cur
    return tasks


def shared_burst(n: int) -> Tuple[List[Task], Dict[str, ResourceSpec]]:
    """n concurrent transfers with distinct durations on one shared
    channel — the worst case for per-event remaining-work bookkeeping."""
    tasks = [Task(i, f"s{i}", "L", "link", (i + 1) * 1e-6) for i in range(n)]
    specs = {"link": ResourceSpec("link", servers=2, mode="shared")}
    return tasks, specs


def _best_of(fn, reps: int = 3) -> float:
    """Minimum wall time over ``reps`` runs (stable against CI noise)."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def fifo_events_per_sec() -> Dict[str, float]:
    tasks = layered_dag()
    n = len(tasks)
    t_dict = _best_of(lambda: Simulator(tasks).run())
    t_cold = _best_of(lambda: simulate_static(tasks))
    cache = StaticCache(tasks)
    t_warm = _best_of(lambda: simulate_static(tasks, cache=cache))
    return {"dict": n / t_dict, "static_cold": n / t_cold,
            "static_warm": n / t_warm}


def shared_tasks_per_sec() -> Dict[str, float]:
    out = {}
    for n in SHARED_NS:
        tasks, specs = shared_burst(n)
        out[str(n)] = n / _best_of(lambda: simulate_static(tasks, specs))
    return out


def dynamic_events_per_sec(n_phases: int = 3000,
                           chunks: int = 4) -> Dict[str, float]:
    """Traffic-style dynamic injection: phases of ``chunks`` chained
    compute tasks plus zero-cost KV writes, each phase injected when the
    previous one completes — the serving simulator's task-graph pattern
    without the scheduler, isolating engine injection overhead.  Compares
    the dict engine (``Simulator.inject`` + global ``on_complete``)
    against the array-backed ``DynamicSimulator.inject_template``."""
    n_tasks = n_phases * 2 * chunks

    def run_dict() -> None:
        sim_box = []
        tails = set()
        done = [0]

        def submit() -> None:
            if done[0] >= n_phases:
                return
            done[0] += 1
            sim = sim_box[0]
            tid = sim.next_task_id()
            prev = -1
            for _ in range(chunks):
                sim.inject(Task(tid, "c", "rep", "rep", 1e-6,
                                deps=(prev,) if prev >= 0 else ()))
                sim.inject(Task(tid + 1, "kv", "kv", "rep:kv", 0.0,
                                deps=(tid,)))
                prev = tid
                tid += 2
            tails.add(prev)

        def on_complete(task: Task, now: float) -> None:
            if task.tid in tails:
                tails.discard(task.tid)
                submit()

        sim_box.append(Simulator(on_complete=on_complete))
        sim_box[0].at(0.0, submit)
        sim_box[0].run()

    tpl_tasks = []
    for i in range(chunks):
        tpl_tasks.append(Task(2 * i, "c", "rep", "rep", 0.0,
                              deps=(2 * i - 2,) if i else ()))
        tpl_tasks.append(Task(2 * i + 1, "kv", "kv", "rep:kv", 0.0,
                              deps=(2 * i,)))
    tpl = GraphTemplate(tpl_tasks, tail=2 * chunks - 2)
    durs = [1e-6, 0.0] * chunks

    def run_fast() -> None:
        sim = DynamicSimulator()
        done = [0]

        def submit(now: float = 0.0) -> None:
            if done[0] >= n_phases:
                return
            done[0] += 1
            sim.inject_template(tpl, durs, on_done=submit)

        sim.at(0.0, submit)
        sim.run()

    return {"dict": n_tasks / _best_of(run_dict),
            "fast": n_tasks / _best_of(run_fast)}


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    fifo = fifo_events_per_sec()
    rows.append(("engine_fifo_10k", 1e6 * 10_000 / fifo["dict"],
                 f"dict={fifo['dict']:.0f}ev/s "
                 f"static_cold={fifo['static_cold']:.0f}ev/s "
                 f"static_warm={fifo['static_warm']:.0f}ev/s"))
    shared = shared_tasks_per_sec()
    lo, hi = str(SHARED_NS[0]), str(SHARED_NS[-1])
    rows.append((
        "engine_shared_scaling",
        1e6 * SHARED_NS[-1] / shared[hi],
        " ".join(f"n{k}={v:.0f}/s" for k, v in shared.items())
        + f" flatness={shared[hi] / shared[lo]:.2f}"
        " (accept: >0.3; the seed engine collapsed to 0.12)"))
    dyn = dynamic_events_per_sec()
    rows.append((
        "engine_dynamic_injection",
        1e6 * 24_000 / dyn["fast"],
        f"dict={dyn['dict']:.0f}ev/s fast={dyn['fast']:.0f}ev/s "
        f"speedup={dyn['fast'] / dyn['dict']:.2f}x (accept: >=3x)"))
    return rows
