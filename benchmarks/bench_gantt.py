"""Paper Figure 4: Gantt chart of compute/communication resource usage,
distinguishing compute-bound and communication-bound phases."""
from __future__ import annotations

import os
from typing import List, Tuple

from repro.core.avsm.model import build_avsm
from repro.core.config import LM_SHAPES, get_arch
from repro.core.hw import tpu_v5e_pod, virtex7_nce_system
from repro.core.sim.trace import ascii_gantt, chrome_trace
from repro.core.taskgraph.builders import ShardPlan, convnet_ops, lm_step_ops

OUT_DIR = "runs/gantt"


def run() -> List[Tuple[str, float, str]]:
    os.makedirs(OUT_DIR, exist_ok=True)
    rows = []

    # compute-bound vs memory-bound layers of DilatedVGG (the paper's case)
    cfg = get_arch("dilated-vgg").model
    rep = build_avsm(convnet_ops(cfg), virtex7_nce_system()).simulate()
    path = os.path.join(OUT_DIR, "vgg_virtex7.trace.json")
    chrome_trace(rep.sim_result, path)
    print("\n--- Fig 4 analog: DilatedVGG on Virtex-7 NCE (first layers) ---")
    print(ascii_gantt(rep.sim_result, width=88, max_rows=6))
    rows.append(("fig4_vgg_gantt", rep.step_time * 1e6,
                 f"nce={rep.nce_util:.0%} dma={rep.dma_util:.0%} "
                 f"trace={path}"))

    # a communication-heavy MoE cell on the pod (collective rows visible)
    spec = get_arch("granite-moe-1b-a400m")
    rep2 = build_avsm(
        lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan()),
        tpu_v5e_pod()).simulate()
    path2 = os.path.join(OUT_DIR, "granite_train.trace.json")
    chrome_trace(rep2.sim_result, path2)
    rows.append(("fig4_granite_gantt", rep2.step_time * 1e6,
                 f"nce={rep2.nce_util:.0%} ici={rep2.ici_util:.0%} "
                 f"trace={path2}"))
    return rows
