"""Assignment roofline table: all (arch x shape) cells from the dry-run
artifacts in runs/dryrun/*.json (single-pod 16x16 = 256 chips), plus the
AVSM-simulated step time for cross-checking (the DES must respect the
analytical bound it generalises)."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

from repro.core.config import LM_SHAPES, get_arch, list_archs
from repro.core.roofline.model import RooflineCell, cell_from_report, \
    format_table

import os as _os

def _latest_dir():
    for d in ("runs/dryrun_v3", "runs/dryrun_v2", "runs/dryrun"):
        if _os.path.isdir(d) and _os.listdir(d):
            return d
    return "runs/dryrun"

DRYRUN_DIR = _latest_dir()


def load_cells(mesh: str = "16x16") -> List[RooflineCell]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        if rep.get("mesh") != mesh:
            continue
        cells.append(cell_from_report(
            rep["arch"], rep["shape"], rep["mesh"], rep["chips"], rep,
            rep["model_flops"]))
    return cells


def run() -> List[Tuple[str, float, str]]:
    cells = load_cells()
    if not cells:
        return [("roofline_cells", 0.0, "no dry-run artifacts found")]
    print("\n--- Roofline table (single-pod 16x16, per step) ---")
    print(format_table(cells))
    skipped = []
    for aid in list_archs():
        spec = get_arch(aid)
        for s in spec.skip_shapes:
            skipped.append(f"{aid}/{s}")
    if skipped:
        print(f"\nskipped cells (assignment rule): {', '.join(skipped)}")
    rows = []
    for c in cells:
        rows.append((f"roofline_{c.arch}_{c.shape}",
                     c.bound_time * 1e6,
                     f"bound={c.dominant} useful={c.useful_ratio:.2f} "
                     f"roofline_frac={c.roofline_fraction:.2%}"))
    worst = min(cells, key=lambda c: c.roofline_fraction)
    most_coll = max(cells, key=lambda c: c.t_collective /
                    max(c.bound_time, 1e-12))
    rows.append(("roofline_summary", 0.0,
                 f"cells={len(cells)} worst_fraction={worst.arch}/"
                 f"{worst.shape} most_collective={most_coll.arch}/"
                 f"{most_coll.shape}"))
    return rows
