"""Paper Figures 6/7: per-layer roofline of DilatedVGG on the AVSM.

Prints each layer as a roofline dot (arithmetic intensity, achieved
FLOP/s, share of inference time) plus the bound classification; the paper's
observation to reproduce: Conv4_0-Conv4_5 sit near the compute roof,
Dense1/Upscaling/Conv1 layers do not.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.avsm.model import build_avsm
from repro.core.config import get_arch
from repro.core.hw import virtex7_nce_system
from repro.core.taskgraph.builders import convnet_ops


def run() -> List[Tuple[str, float, str]]:
    cfg = get_arch("dilated-vgg").model
    sys = virtex7_nce_system()
    avsm = build_avsm(convnet_ops(cfg), sys)
    rep = avsm.simulate()
    peak = sys.chip.compute.matrix_flops
    bw = sys.chip.memory.bandwidth
    ridge = peak / bw

    print("\n--- Fig 6/7 analog: DilatedVGG per-layer roofline "
          f"(ridge OI={ridge:.0f} flop/B) ---")
    print(f"{'layer':12s} {'OI(F/B)':>9s} {'achieved':>12s} {'peak%':>7s} "
          f"{'t_share':>8s}  bound")
    total = rep.step_time
    rows: List[Tuple[str, float, str]] = []
    compute_bound = []
    for l in sorted(rep.layers, key=lambda l: l.name):
        if l.flops <= 0:
            continue
        frac = l.achieved_flops / peak * 100
        share = l.time / total * 100
        print(f"{l.name:12s} {l.intensity:9.1f} "
              f"{l.achieved_flops / 1e9:10.1f}GF {frac:6.1f}% "
              f"{share:7.1f}%  {l.bound}")
        if l.bound == "compute":
            compute_bound.append(l.name)
    conv4 = [n for n in compute_bound if n.startswith("conv4")]
    rows.append(("fig6_vgg_roofline", rep.step_time * 1e6,
                 f"compute_bound={len(compute_bound)} layers; "
                 f"conv4 near roof: {len(conv4)}/6 (paper: 6/6)"))

    # backend stack cross-check on the same compiled graph: the closed-form
    # roofline backend must lower-bound the DES within the launch/padding gap
    roof = avsm.estimate("roofline")
    ana = avsm.estimate("analytic")
    rows.append(("fig6_backend_stack", roof.step_time * 1e6,
                 f"roofline={roof.step_time * 1e3:.0f}ms <= "
                 f"analytic={ana.step_time * 1e3:.0f}ms <= "
                 f"des={rep.step_time * 1e3:.0f}ms "
                 f"(roofline est in {roof.estimate_seconds * 1e6:.0f}us)"))
    return rows
