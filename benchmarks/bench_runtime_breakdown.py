"""Paper Figure 3: turn-around-time breakdown of the virtual-system flow.

The paper reports (Xeon E5620): ML compiler & graph generation 16.6 s,
SystemC model build + tool import/export 1231 s, simulation 105.8 s.  Our
flow replaces SystemC generation with direct DES construction, so the
"model build" leg is the AVSM task-graph compilation.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.avsm.model import build_avsm
from repro.core.config import LM_SHAPES, get_arch
from repro.core.hw import tpu_v5e_pod, virtex7_nce_system
from repro.core.taskgraph.builders import ShardPlan, convnet_ops, lm_step_ops
from repro.core.taskgraph.compiler import compile_ops


def run() -> List[Tuple[str, float, str]]:
    rows = []
    # --- DilatedVGG on the paper's FPGA system (paper's own experiment) ---
    cfg = get_arch("dilated-vgg").model
    t0 = time.perf_counter()
    ops = convnet_ops(cfg)
    t_graph = time.perf_counter() - t0

    t0 = time.perf_counter()
    avsm = build_avsm(ops, virtex7_nce_system())
    t_build = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = avsm.simulate()
    t_sim = time.perf_counter() - t0

    rows += [
        ("fig3_vgg_graph_generation", t_graph * 1e6,
         f"paper=16.6s ours={t_graph:.3f}s"),
        ("fig3_vgg_model_build", t_build * 1e6,
         f"paper=1231s(SystemC) ours={t_build:.3f}s"),
        ("fig3_vgg_simulation", t_sim * 1e6,
         f"paper=105.8s ours={t_sim:.3f}s tasks={rep.n_tasks}"),
        ("fig3_vgg_total", (t_graph + t_build + t_sim) * 1e6,
         f"paper=1353.5s ours={t_graph + t_build + t_sim:.3f}s"),
    ]

    # --- a pod-scale LM cell (beyond-paper scale) ---
    spec = get_arch("deepseek-v2-236b")
    t0 = time.perf_counter()
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    t_graph = time.perf_counter() - t0
    t0 = time.perf_counter()
    avsm = build_avsm(ops, tpu_v5e_pod())
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep = avsm.simulate()
    t_sim = time.perf_counter() - t0
    rows.append(("fig3_deepseek_train_total",
                 (t_graph + t_build + t_sim) * 1e6,
                 f"graph={t_graph:.2f}s build={t_build:.2f}s "
                 f"sim={t_sim:.2f}s tasks={rep.n_tasks} "
                 f"pred_step={rep.step_time * 1e3:.1f}ms"))
    return rows
