"""Serving-simulator throughput + scheduler comparison.

Measures the virtual serving stack at the scale the ROADMAP asks about:

  * sim speed — wall seconds (and simulated requests per wall second) for
    10k requests through continuous batching (acceptance: < 10 s on CPU);
  * dynamic fast path — the same 10k requests with *full task-graph
    injection* (chunked phase graphs + KV writes) on the array-backed
    dynamic engine vs the dict engine (acceptance: >= 3x);
  * speculative leap — 10k requests under a scheduler that declares only
    the ``decode_stable`` contract, so every decode fusion takes the
    snapshot/rollback path;
  * graph-mode speculative leap — the same decode_stable-only scheduler
    with full task-graph injection on the fast engine: each leap books
    one ``TemplateLane`` burst of per-step template instances and rolls
    back by truncating the burst at a snapshot boundary;
  * Monte-Carlo seed batch — 16 seeds x 10k requests in one
    ``MonteCarloServingSimulator`` call on the fused continuous-batching
    fast path, reporting cross-seed mean and 95% CI for p99 TTFT;
  * scheduler tails — p99 TTFT of continuous vs static batching under the
    same Poisson traffic (continuous batching should dominate);
  * cost-model derivation — seconds to fit a per-request cost model from
    compiled graphs, and the re-annotation fast path for a chip variant.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.avsm.model import annotate_system
from repro.core.config import get_arch
from repro.core.hw import SystemDescription, tpu_v5e_chip
from repro.core.taskgraph.builders import ShardPlan
from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                             MonteCarloServingSimulator,
                             ServingCostModelBuilder, ServingSimulator,
                             StaticBatchScheduler, poisson_workload,
                             poisson_workload_batch, simulate_serving)


class SpeculativeContinuousScheduler(ContinuousBatchingScheduler):
    """Continuous batching declaring only the speculative contract
    (``decode_stable`` without ``steady_decode``): every decode leap
    takes the snapshot/rollback path — the non-``steady_decode`` case
    the speculative leap opened up."""

    name = "continuous_speculative"
    steady_decode = False


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    cfg = get_arch("qwen1.5-0.5b").model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())

    t0 = time.perf_counter()
    builder = ServingCostModelBuilder(cfg, shard=ShardPlan(data=1, model=1))
    cost = builder.model_for(base)
    t_fit = time.perf_counter() - t0
    t0 = time.perf_counter()
    builder.model_for(annotate_system(base, mem_bandwidth=1638e9))
    t_refit = time.perf_counter() - t0
    rows.append(("serve_cost_fit", t_fit * 1e6,
                 f"variant_via_reannotate={t_refit * 1e6:.0f}us "
                 f"speedup={t_fit / max(t_refit, 1e-9):.0f}x"))

    def traffic(n, rate=120.0, seed=0):
        return poisson_workload(rate, n,
                                prompt=LengthDist(mean=512, cv=0.6),
                                output=LengthDist(mean=96, cv=0.5), seed=seed)

    t0 = time.perf_counter()
    rep = simulate_serving(cost, ContinuousBatchingScheduler, traffic(10_000),
                           replicas=4, slots=8)
    wall = time.perf_counter() - t0
    rows.append(("serve_sim_10k", wall * 1e6,
                 f"{rep.n_requests} reqs, {rep.output_tokens} toks, "
                 f"{rep.n_requests / wall:.0f} req/wall-s "
                 f"(accept: wall<10s)"))

    # full task-graph injection: fast dynamic engine vs dict engine
    # (interleaved best-of-2, so machine-load drifts hit both engines)
    walls = {"fast": float("inf"), "dict": float("inf")}
    for _ in range(2):
        for engine in ("fast", "dict"):
            t0 = time.perf_counter()
            g = ServingSimulator(cost, ContinuousBatchingScheduler,
                                 traffic(10_000), replicas=4, slots=8,
                                 phase_tasks=4, engine=engine).run()
            walls[engine] = min(walls[engine], time.perf_counter() - t0)
    rows.append(("serve_sim_10k_taskgraph", walls["fast"] * 1e6,
                 f"fast={walls['fast']:.2f}s dict={walls['dict']:.2f}s "
                 f"speedup={walls['dict'] / walls['fast']:.2f}x "
                 f"({g.n_requests} reqs, {4 * 2} tasks/phase, accept: >=3x)"))

    # speculative decode leap: decode_stable-only scheduler, rollbacks on
    t0 = time.perf_counter()
    spec = simulate_serving(cost, SpeculativeContinuousScheduler,
                            traffic(10_000), replicas=4, slots=8)
    wall_spec = time.perf_counter() - t0
    rows.append(("serve_sim_10k_speculative", wall_spec * 1e6,
                 f"{spec.n_requests} reqs, "
                 f"{spec.n_requests / wall_spec:.0f} req/wall-s "
                 f"(decode_stable-only leap w/ rollback)"))

    # graph-mode speculative leap: full task-graph fidelity, leaps booked
    # as TemplateLane bursts with snapshot/rollback
    t0 = time.perf_counter()
    gspec = ServingSimulator(cost, SpeculativeContinuousScheduler,
                             traffic(10_000), replicas=4, slots=8,
                             phase_tasks=4).run()
    wall_gspec = time.perf_counter() - t0
    rows.append(("serve_sim_10k_taskgraph_speculative", wall_gspec * 1e6,
                 f"{gspec.n_requests} reqs, "
                 f"{gspec.n_requests / wall_gspec:.0f} req/wall-s "
                 f"(burst leap w/ rollback, {4 * 2} tasks/phase)"))

    # seed-batched Monte-Carlo: 16 seeds through the fused fast path
    batch = poisson_workload_batch(300.0, 10_000,
                                   prompt=LengthDist(mean=512, cv=0.6),
                                   output=LengthDist(mean=96, cv=0.5),
                                   seeds=16)
    t0 = time.perf_counter()
    mc = MonteCarloServingSimulator(cost, ContinuousBatchingScheduler,
                                    batch, replicas=4, slots=32).run()
    wall_mc = time.perf_counter() - t0
    s = mc.stat("ttft_p99")
    rows.append(("serve_sim_mc_16x10k", wall_mc * 1e6,
                 f"{mc.n_requests / wall_mc:.0f} "
                 f"seed-req/wall-s, ttft_p99={s.mean * 1e3:.2f}ms "
                 f"ci95=[{s.ci_lo * 1e3:.2f}, {s.ci_hi * 1e3:.2f}]ms"))

    cont = simulate_serving(cost, ContinuousBatchingScheduler,
                            traffic(2000, rate=60.0), replicas=4, slots=8)
    stat = simulate_serving(cost, lambda: StaticBatchScheduler(8, 0.25),
                            traffic(2000, rate=60.0), replicas=4, slots=8)
    rows.append(("serve_sched_p99_ttft", cont.ttft.p99 * 1e6,
                 f"static={stat.ttft.p99 * 1e6:.0f}us "
                 f"continuous_wins={cont.ttft.p99 <= stat.ttft.p99}"))
    return rows
