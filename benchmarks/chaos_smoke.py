"""CI chaos-smoke: fault-injection serving must stay deterministic and
cheap.

Two gates:

  * **Determinism** — a seeded 8-replica chaos scenario (MTBF/MTTR crash
    churn + retry/backoff/deadline) run twice end-to-end (scalar
    ``ServingSimulator`` and the fused Monte-Carlo path) produces
    bit-identical availability / goodput / abandonment numbers and
    per-request rows, and the two paths agree with each other.
  * **Overhead** — threading the fault machinery through the fused
    10k-request scenario with *no* fault profile attached costs < 10%
    vs the pre-fault fast path (the ``faults=None`` branches must stay
    out of the hot loop).  CI containers see background load spikes, so
    the estimate is the min of two noise-robust estimators over
    alternating-order pairs (median of per-pair ratios, ratio of
    best-of-N walls) — additive noise inflates both, never deflates.

Exit code 0 on pass, 1 on any violation.
"""
from __future__ import annotations

import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MAX_OVERHEAD_PCT = 10.0
PAIRS = 5


def _chaos_reports():
    from repro.serve_sim import (ContinuousBatchingScheduler, FailureModel,
                                 LengthDist, MonteCarloServingSimulator,
                                 RetryPolicy, poisson_workload_batch,
                                 simulate_serving)

    from benchmarks.perf_record import _serve_cost

    cost = _serve_cost()
    failures = FailureModel(mtbf=5.0, mttr=0.8, seed=7, horizon=120.0)
    retry = RetryPolicy(max_attempts=4, backoff=0.02, deadline=30.0)
    batch = poisson_workload_batch(
        120.0, 2000, prompt=LengthDist(mean=512, cv=0.6),
        output=LengthDist(mean=96, cv=0.5), seeds=4)
    scalar = simulate_serving(cost, ContinuousBatchingScheduler,
                              batch.workload(0), replicas=8, slots=8,
                              failures=failures, retry=retry,
                              fault_seed=(failures.seed, int(batch.seeds[0])))
    mc = MonteCarloServingSimulator(cost, ContinuousBatchingScheduler, batch,
                                    replicas=8, slots=8, failures=failures,
                                    retry=retry)
    assert mc.fast_path, "chaos scenario must be fast-path eligible"
    return scalar, mc.run()


def _fingerprint(rep):
    return (rep.n_requests, rep.duration, rep.output_tokens, rep.n_offered,
            rep.n_failures, rep.n_retries, rep.n_abandoned, rep.n_shed,
            rep.availability, rep.goodput_rps, rep.attempt_rps,
            rep.abandonment_rate, rep.ttft.p99, rep.e2e.p99,
            tuple((m.rid, m.replica, m.slot, m.t_admit, m.t_done)
                  for m in rep.requests))


def _determinism_gate() -> bool:
    s1, m1 = _chaos_reports()
    s2, m2 = _chaos_reports()
    ok = True
    if _fingerprint(s1) != _fingerprint(s2):
        print("FAIL: scalar chaos run not bit-identical across runs")
        ok = False
    if [_fingerprint(r) for r in m1.reports] != \
            [_fingerprint(r) for r in m2.reports]:
        print("FAIL: Monte-Carlo chaos run not bit-identical across runs")
        ok = False
    if _fingerprint(m1.reports[0]) != _fingerprint(s1):
        print("FAIL: fused seed-0 report != scalar path report")
        ok = False
    if not any(r.n_failures for r in m1.reports):
        print("FAIL: chaos scenario injected no failures")
        ok = False
    a = m1.stat("availability")
    print(f"chaos determinism OK: {s1.n_failures} failures, "
          f"{s1.n_retries} retries, {s1.n_abandoned} abandoned on seed 0; "
          f"availability mean={a.mean:.4f} "
          f"ci=[{a.ci_lo:.4f}, {a.ci_hi:.4f}] over {len(m1.reports)} seeds")
    return ok


def _overhead_gate() -> bool:
    from repro.serve_sim import ReplicaFault, compile_faults
    from repro.serve_sim.monte_carlo import _simulate_continuous_fast

    from benchmarks.perf_record import _serve_cost, _traffic

    cost = _serve_cost()
    wl = _traffic()
    times = [r.t_arrive for r in wl.requests]
    prompts = [r.prompt_tokens for r in wl.requests]
    outputs = [r.output_tokens for r in wl.requests]
    # armed but never firing during traffic: the one window opens long
    # after the last completion, so every per-event fault gate runs while
    # the simulated outcome stays that of a fault-free run
    armed = compile_faults([ReplicaFault(0, 1.0e6, 1.0e6 + 1.0)], replicas=4)

    def fused(faults):
        t0 = time.perf_counter()
        rep = _simulate_continuous_fast(cost, times, prompts, outputs, 4, 8,
                                        "chaos", faults=faults)
        return time.perf_counter() - t0, rep

    # sanity: arming the machinery must not perturb the simulation
    _, r_off = fused(None)
    _, r_on = fused(armed)
    same = (r_off.duration == r_on.duration
            and r_off.output_tokens == r_on.output_tokens
            and r_off.ttft.p99 == r_on.ttft.p99
            and r_on.n_failures == 0 and r_on.availability == 1.0)
    if not same:
        print("FAIL: armed-but-idle fault schedule changed the simulation")
        return False

    # alternating-order pairs; two noise-robust estimators, take the min
    # (additive load spikes inflate both, never deflate them)
    on_walls, off_walls, ratios = [], [], []
    for i in range(PAIRS):
        if i % 2 == 0:
            off, _ = fused(None)
            on, _ = fused(armed)
        else:
            on, _ = fused(armed)
            off, _ = fused(None)
        on_walls.append(on)
        off_walls.append(off)
        ratios.append(on / off)
    med = (statistics.median(ratios) - 1.0) * 100.0
    best = (min(on_walls) / min(off_walls) - 1.0) * 100.0
    overhead = min(med, best)
    rps = len(times) / min(off_walls)
    print(f"fused no-fault: {rps:,.0f} req/s (best); armed-machinery "
          f"overhead median={med:.1f}% best-of={best:.1f}% "
          f"-> {overhead:.1f}%")
    ok = True
    if overhead > MAX_OVERHEAD_PCT:
        print(f"FAIL: fault-injection overhead {overhead:.1f}% > "
              f"{MAX_OVERHEAD_PCT:.0f}% on the no-fault scenario")
        ok = False
    if rps < 80_000:
        print(f"FAIL: fused no-fault path {rps:,.0f} req/s < 80,000 req/s "
              "floor — fault branches leaked into the hot loop")
        ok = False
    return ok


def main() -> int:
    ok = _determinism_gate()
    ok = _overhead_gate() and ok
    print("chaos smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
