"""CI cluster-smoke: resilient cluster serving must stay deterministic,
faithful and cheap.

Three gates:

  * **Determinism** — a seeded 3-zone chaos cluster (per-zone MTBF/MTTR
    churn, health-checked rotation, circuit breakers, fixed-delay
    hedging, cross-pool failover) run twice end-to-end produces
    bit-identical counters, routing tallies and latency percentiles.
  * **Parity** — a 1-pool cluster behind ``PassThroughRouter``
    reproduces the standalone ``ServingSimulator`` bit-exactly under
    fault churn: the routing tier is pure bookkeeping on that path.
  * **Cost** — the routing tier costs < 10% wall-clock vs the
    standalone simulator on an identical 1-pool workload, and the
    3-zone chaos cluster sustains a conservative requests/sec floor.
    CI containers see background load spikes, so overhead is the min of
    two noise-robust estimators over alternating-order pairs (median of
    per-pair ratios, ratio of best-of-N walls).

Exit code 0 on pass, 1 on any violation.
"""
from __future__ import annotations

import os
import statistics
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MAX_OVERHEAD_PCT = 10.0
MIN_CLUSTER_RPS = 5_000.0
PAIRS = 5


def _cost():
    from repro.serve_sim import ServingCostModel
    return ServingCostModel(name="chip", prefill_fixed=0.004,
                            prefill_per_token=2e-5, decode_fixed=0.002,
                            decode_per_token=1e-5, decode_per_ctx_token=2e-8)


def _chaos_cluster(n=20_000, rate=1200.0):
    from repro.serve_sim import (CircuitBreakerPolicy, ClusterSimulator,
                                 FailureModel, HealthCheckPolicy, HedgePolicy,
                                 LeastLoadedRouter, ReplicaPool, RetryPolicy,
                                 poisson_workload)
    cost = _cost()
    pools = [ReplicaPool(f"zone-{z}", cost, 8, slots=16,
                         failures=FailureModel(mtbf=30.0, mttr=3.0,
                                               seed=10 + z, horizon=120.0),
                         retry=RetryPolicy())
             for z in range(3)]
    return ClusterSimulator(
        pools, poisson_workload(rate, n, seed=1),
        LeastLoadedRouter(retry_budget=4),
        health=HealthCheckPolicy(interval=1.0),
        hedge=HedgePolicy(delay=1.0, max_fraction=0.05),
        breaker=CircuitBreakerPolicy(error_threshold=8, window=10.0,
                                     cooldown=10.0))


def _fingerprint(rep):
    per_pool = tuple(
        (name, p.n_requests, p.duration, p.output_tokens, p.n_failures,
         p.n_retries, p.n_abandoned, p.availability, p.e2e.p99)
        for name, p in sorted(rep.pools.items()))
    return (rep.n_requests, rep.n_offered, rep.duration, rep.output_tokens,
            rep.n_failures, rep.n_retries, rep.n_failovers,
            rep.hedges_issued, rep.hedges_won, rep.hedge_waste_tokens,
            tuple(sorted(rep.n_lost.items())),
            tuple(sorted(rep.n_routed.items())),
            tuple(sorted(rep.breaker_trips.items())),
            rep.availability, rep.fleet_availability,
            rep.ttft.p99, rep.e2e.p99, per_pool)


def _solo_fingerprint(rep):
    return (rep.n_requests, rep.n_offered, rep.duration, rep.output_tokens,
            rep.n_failures, rep.n_retries, rep.n_abandoned,
            rep.availability, rep.ttft.p99, rep.e2e.p99)


def _determinism_gate() -> bool:
    t0 = time.perf_counter()
    r1 = _chaos_cluster().run()
    wall = time.perf_counter() - t0
    r2 = _chaos_cluster().run()
    ok = True
    if _fingerprint(r1) != _fingerprint(r2):
        print("FAIL: seeded chaos cluster not bit-identical across runs")
        ok = False
    if not (r1.n_failures and r1.n_failovers):
        print("FAIL: chaos cluster injected no failures/failovers")
        ok = False
    rps = r1.n_requests / wall
    print(f"cluster determinism OK: {r1.replicas} replicas / 3 zones, "
          f"{r1.n_failures} failures, {r1.n_failovers} failovers, "
          f"{r1.hedges_issued} hedges, "
          f"{sum(r1.breaker_trips.values())} breaker trips, "
          f"availability={r1.availability:.4%}; {rps:,.0f} req/s")
    if rps < MIN_CLUSTER_RPS:
        print(f"FAIL: chaos cluster {rps:,.0f} req/s < "
              f"{MIN_CLUSTER_RPS:,.0f} req/s floor")
        ok = False
    return ok


def _parity_gate() -> bool:
    from repro.serve_sim import (ClusterSimulator, ContinuousBatchingScheduler,
                                 FailureModel, PassThroughRouter, ReplicaPool,
                                 RetryPolicy, ServingSimulator,
                                 poisson_workload)
    cost = _cost()
    failures = FailureModel(mtbf=8.0, mttr=1.5, seed=7, horizon=60.0)
    retry = RetryPolicy()

    def wl():
        return poisson_workload(300.0, 5_000, seed=3)

    solo = ServingSimulator(cost, ContinuousBatchingScheduler, wl(),
                            replicas=4, slots=8, failures=failures,
                            retry=retry).run()
    clus = ClusterSimulator(
        [ReplicaPool("only", cost, 4, slots=8, failures=failures,
                     retry=retry)],
        wl(), PassThroughRouter()).run()
    if _solo_fingerprint(solo) != _solo_fingerprint(clus.pools["only"]):
        print("FAIL: 1-pool pass-through cluster != standalone simulator")
        return False
    print(f"1-pool golden parity OK: {solo.n_requests} requests, "
          f"{solo.n_failures} failures, duration={solo.duration:.6f}s")
    return True


def _overhead_gate() -> bool:
    from repro.serve_sim import (ClusterSimulator, ContinuousBatchingScheduler,
                                 PassThroughRouter, ReplicaPool,
                                 ServingSimulator, poisson_workload)
    cost = _cost()

    def solo():
        t0 = time.perf_counter()
        ServingSimulator(cost, ContinuousBatchingScheduler,
                         poisson_workload(300.0, 10_000, seed=1),
                         replicas=4, slots=8).run()
        return time.perf_counter() - t0

    def clus():
        t0 = time.perf_counter()
        ClusterSimulator([ReplicaPool("p", cost, 4, slots=8)],
                         poisson_workload(300.0, 10_000, seed=1),
                         PassThroughRouter()).run()
        return time.perf_counter() - t0

    solo_walls, clus_walls, ratios = [], [], []
    for i in range(PAIRS):
        if i % 2 == 0:
            s, c = solo(), clus()
        else:
            c, s = clus(), solo()
        solo_walls.append(s)
        clus_walls.append(c)
        ratios.append(c / s)
    med = (statistics.median(ratios) - 1.0) * 100.0
    best = (min(clus_walls) / min(solo_walls) - 1.0) * 100.0
    overhead = min(med, best)
    print(f"routing-tier overhead: median={med:.1f}% best-of={best:.1f}% "
          f"-> {overhead:.1f}%")
    if overhead > MAX_OVERHEAD_PCT:
        print(f"FAIL: routing tier costs {overhead:.1f}% > "
              f"{MAX_OVERHEAD_PCT:.0f}% on a 1-pool pass-through workload")
        return False
    return True


def main() -> int:
    ok = _determinism_gate()
    ok = _parity_gate() and ok
    ok = _overhead_gate() and ok
    print("cluster smoke:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
