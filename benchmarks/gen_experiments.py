"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.gen_experiments
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.config import get_arch, list_archs
from repro.core.roofline.model import cell_from_report


def load(dirname):
    out = {}
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            rep = json.load(f)
        out[(rep["arch"], rep["shape"], rep["mesh"])] = rep
    return out


def main():
    v1 = load("runs/dryrun")       # both meshes: compile proof + memory
    _rl_dir = next((d for d in ("runs/dryrun_v3", "runs/dryrun_v2")
                if os.path.isdir(d) and os.listdir(d)), "runs/dryrun")
    v2 = load(_rl_dir)             # single-pod: roofline terms

    print("## Dry-run matrix (lower+compile success, bytes/device)\n")
    print("| arch | shape | 16x16 (256) | 2x16x16 (512) | peak GB/dev "
          "(256) | peak GB/dev (512) |")
    print("|---|---|---|---|---|---|")
    for aid in list_archs():
        spec = get_arch(aid)
        for s in spec.shapes:
            if s in spec.skip_shapes:
                continue
            r1 = v1.get((aid, s, "16x16"))
            r2 = v1.get((aid, s, "2x16x16"))
            print(f"| {aid} | {s} | {'OK' if r1 else 'MISSING'} | "
                  f"{'OK' if r2 else 'MISSING'} | "
                  f"{(r1 or {}).get('peak_bytes', 0) / 1e9:.1f} | "
                  f"{(r2 or {}).get('peak_bytes', 0) / 1e9:.1f} |")

    print("\n## Roofline table (single-pod, 256 chips, per step)\n")
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
          "useful | roofline |")
    print("|---|---|---|---|---|---|---|---|")
    cells = []
    for (aid, s, mesh), rep in sorted(v2.items()):
        if mesh != "16x16":
            continue
        c = cell_from_report(aid, s, mesh, rep["chips"], rep,
                             rep["model_flops"])
        cells.append(c)
        print(f"| {aid} | {s} | {c.t_compute * 1e3:.1f} | "
              f"{c.t_memory * 1e3:.1f} | {c.t_collective * 1e3:.1f} | "
              f"{c.dominant} | {c.useful_ratio:.2f} | "
              f"{c.roofline_fraction:.1%} |")
    if cells:
        worst = min(cells, key=lambda c: c.roofline_fraction)
        coll = max(cells, key=lambda c: c.t_collective / max(c.bound_time,
                                                             1e-12))
        print(f"\nworst roofline fraction: {worst.arch}/{worst.shape} "
              f"({worst.roofline_fraction:.2%})")
        print(f"most collective-bound: {coll.arch}/{coll.shape} "
              f"(t_coll share {coll.t_collective / coll.bound_time:.0%})")

    print("\n## Perf iterations\n")
    for p in sorted(glob.glob("runs/perf/*.jsonl")):
        print(f"### {os.path.basename(p)}")
        print("| tag | t_comp | t_mem | t_coll | bound | roofline | "
              "peak GB |")
        print("|---|---|---|---|---|---|---|")
        for line in open(p):
            r = json.loads(line)
            print(f"| {r['tag']} | {r['t_compute_ms']:.1f} | "
                  f"{r['t_memory_ms']:.1f} | {r['t_collective_ms']:.1f} | "
                  f"{r['dominant']} | {r['roofline_fraction']:.1%} | "
                  f"{r['peak_bytes_gb']:.1f} |")
        print()


if __name__ == "__main__":
    main()
