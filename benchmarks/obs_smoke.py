"""CI obs-smoke: the observability layer must stay valid and cheap.

Runs the 10k-request serving scenario instrumented with a
``repro.obs.Probe`` (default bundle sampling, ``sample_every=64``),
writes a ``runs/<name>/`` bundle, and asserts:

  * the bundle's ``trace.json`` passes :func:`repro.obs.validate_trace`
    (so it loads in Perfetto / chrome://tracing);
  * the trace carries >= 3 counter tracks (queue depth, engine/serving
    counters, per-replica occupancy);
  * ``metrics.json`` round-trips through :func:`repro.obs.load_bundle`
    with the report summary intact;
  * probe-on overhead vs an uninstrumented interleaved run is < 10% —
    measured twice, on the express-lane scenario and on full task-graph
    mode (``phase_tasks=4``, the ``TemplateLane`` serving path).  CI
    containers see background load spikes larger than the margin
    being measured, so each estimate is the minimum of two noise-robust
    estimators over alternating-order pairs on a shared pre-generated
    workload: the median of per-pair on/off wall ratios (adjacent runs
    see similar momentary load) and the ratio of best-of-N walls (each
    side only needs to hit one quiet window).  Additive load spikes
    inflate both estimators, never deflate them, so taking the min
    rejects noise while a real regression — which moves every on-run —
    still trips both;
  * ``python -m repro.obs.compare`` diffs the bundle against itself
    with zero regressions and against a perturbed copy with at least
    one (the regression-gate path CI relies on).

Exit code 0 on pass, 1 on any violation.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MAX_OVERHEAD_PCT = 10.0
MIN_COUNTER_TRACKS = 3


def main() -> int:
    from benchmarks.perf_record import _serve_cost, _traffic
    from repro.obs import Probe, load_bundle, validate_trace, write_bundle
    from repro.obs.compare import main as compare_main
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    cost = _serve_cost()
    workload = _traffic()
    failures = []

    # Alternating probe-off / probe-on walls on the same workload (order
    # within each pair alternates too, cancelling drift).  See the
    # module docstring for why the estimate is the min of two
    # noise-robust estimators.
    def run_once(with_probe, phase_tasks=0):
        prb = Probe("obs-smoke", sample_every=64) if with_probe else None
        t0 = time.perf_counter()
        rep = ServingSimulator(cost, ContinuousBatchingScheduler, workload,
                               replicas=4, slots=8,
                               phase_tasks=phase_tasks, probe=prb).run()
        return time.perf_counter() - t0, prb, rep

    def measure_overhead(label, phase_tasks=0, pairs=7):
        ratios, off_walls, on_walls = [], [], []
        probe = report = None
        for i in range(pairs):
            if i % 2:
                on, probe, report = run_once(True, phase_tasks)
                off, _, _ = run_once(False, phase_tasks)
            else:
                off, _, _ = run_once(False, phase_tasks)
                on, probe, report = run_once(True, phase_tasks)
            off_walls.append(off)
            on_walls.append(on)
            ratios.append(on / off)
        paired = statistics.median(ratios)
        quiet = min(on_walls) / min(off_walls)
        overhead_pct = (min(paired, quiet) - 1.0) * 100.0
        print(f"{label}: off best {min(off_walls):.4f}s, probe-on best "
              f"{min(on_walls):.4f}s, overhead {overhead_pct:+.1f}% "
              f"(median paired {(paired - 1) * 100:+.1f}%, best-of-{pairs} "
              f"{(quiet - 1) * 100:+.1f}%; max {MAX_OVERHEAD_PCT:g}%)")
        if overhead_pct >= MAX_OVERHEAD_PCT:
            failures.append(f"{label} probe overhead {overhead_pct:.1f}% >= "
                            f"{MAX_OVERHEAD_PCT:g}%")
        return probe, report

    probe, report = measure_overhead("serve_sim 10k")
    # task-graph mode: the TemplateLane serving path must honour the
    # same budget (serving-level countdown sites; lanes stay probe-free)
    measure_overhead("serve_sim 10k graph-mode", phase_tasks=4, pairs=5)

    with tempfile.TemporaryDirectory() as tmp:
        path = write_bundle("obs_smoke", out_dir=tmp, report=report,
                            probe=probe)
        with open(os.path.join(path, "trace.json")) as f:
            doc = json.load(f)
        problems = validate_trace(doc)
        if problems:
            failures.append(f"trace.json invalid: {problems[:3]}")
        counters = {(e.get("pid"), e.get("name"))
                    for e in doc["traceEvents"] if e.get("ph") == "C"}
        print(f"bundle {path}: {len(doc['traceEvents'])} events, "
              f"{len(counters)} counter tracks")
        if len(counters) < MIN_COUNTER_TRACKS:
            failures.append(f"{len(counters)} counter tracks < "
                            f"{MIN_COUNTER_TRACKS}")
        loaded = load_bundle(path)
        if loaded["report"]["n_requests"] != report.n_requests:
            failures.append("metrics.json round-trip lost the report")

        # compare: self-diff clean, perturbed diff flags a regression
        if compare_main([path, path, "--fail-on-regression"]) != 0:
            failures.append("self-compare reported a regression")
        worse = dict(loaded)
        worse["report"] = dict(loaded["report"])
        worse["report"]["throughput_rps"] = \
            loaded["report"]["throughput_rps"] * 0.5
        worse_path = os.path.join(tmp, "worse.json")
        with open(worse_path, "w") as f:
            json.dump(worse, f)
        if compare_main([path, worse_path, "--fail-on-regression",
                         "--flagged-only"]) != 1:
            failures.append("compare missed an injected 2x regression")

    if failures:
        print("OBS-SMOKE FAIL:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("OBS-SMOKE PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
