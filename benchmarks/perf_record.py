"""Machine-readable perf trajectory: writes ``BENCH_pr4.json``.

Collects the current throughput of the hot paths this PR optimized — the
dynamic-injection fast path (array-backed ``DynamicSimulator`` + template
instantiation vs the dict engine), the speculative decode leap
(``decode_stable``-only scheduler, rollbacks armed), and the persistent
worker pool (first call vs steady-state ``explore()`` sweeps) — next to
the PR 3 paths (engine events/sec, what-if points/sec, serve-sim
requests/sec), and records them against the PR 3 measurements::

    PYTHONPATH=src python benchmarks/run.py --json        # BENCH_pr4.json
    PYTHONPATH=src python benchmarks/perf_record.py       # same, standalone

``BASELINE_PR3`` is the ``current`` section of the committed
``BENCH_pr3.json`` (measured at 4fbf7df on the same container class);
absolute numbers are machine-dependent, the *ratios* are the tracked
signal.  Paired comparisons (fast vs dict engine) are measured
interleaved best-of-N in this process, so load drifts hit both sides.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Dict

# The "current" section of BENCH_pr3.json, measured at 4fbf7df (PR 3).
BASELINE_PR3: Dict = {
    "engine_fifo_events_per_sec": {
        "dict": 114_660.0, "static_cold": 406_958.0, "static_warm": 525_312.0},
    "engine_shared_tasks_per_sec": {
        "200": 263_286.0, "800": 224_867.0, "3200": 190_253.0,
        "6400": 174_760.0},
    "what_if_points_per_sec": {
        "roofline": 590.4, "analytic": 771.2, "des": 24.6},
    "serve_sim_10k": {"wall_seconds": 0.517, "requests_per_sec": 19_347.0},
}


def _what_if_points_per_sec() -> Dict[str, float]:
    import numpy as np

    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"w": ops})
    dse.compiled("w", base).anno_arrays()       # steady-state sweep loop
    values = list(np.linspace(50e9, 200e9, 16))
    out = {}
    for backend in ("roofline", "analytic", "des"):
        t0 = time.perf_counter()
        dse.what_if_sweep("w", base, "link_bandwidth", values,
                          backend=backend)
        out[backend] = len(values) / (time.perf_counter() - t0)
    return out


def _serve_cost() -> object:
    from repro.core.config import get_arch
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.serve_sim import ServingCostModelBuilder

    cfg = get_arch("qwen1.5-0.5b").model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    return ServingCostModelBuilder(
        cfg, shard=ShardPlan(data=1, model=1)).model_for(base)


def _traffic(n=10_000):
    from repro.serve_sim import LengthDist, poisson_workload

    return poisson_workload(120.0, n,
                            prompt=LengthDist(mean=512, cv=0.6),
                            output=LengthDist(mean=96, cv=0.5), seed=0)


def _serve_sim_10k() -> Dict[str, float]:
    import gc

    from repro.serve_sim import ContinuousBatchingScheduler, simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, ContinuousBatchingScheduler, _traffic(),
                               replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _serve_sim_10k_taskgraph(reps: int = 3) -> Dict[str, float]:
    """10k requests with full task-graph injection (4 chunks + KV writes
    per phase): array-backed dynamic engine vs the PR 3 dict path,
    interleaved best-of-``reps``."""
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    import gc

    cost = _serve_cost()
    walls = {"fast": float("inf"), "dict": float("inf")}
    n = 0
    for _ in range(reps):
        for engine in ("fast", "dict"):
            gc.collect()                     # drain prior suites' garbage
            t0 = time.perf_counter()
            rep = ServingSimulator(cost, ContinuousBatchingScheduler,
                                   _traffic(), replicas=4, slots=8,
                                   phase_tasks=4, engine=engine).run()
            walls[engine] = min(walls[engine], time.perf_counter() - t0)
            n = rep.n_requests
    return {"fast_wall_seconds": walls["fast"],
            "dict_wall_seconds": walls["dict"],
            "fast_requests_per_sec": n / walls["fast"],
            "speedup_fast_vs_dict": walls["dict"] / walls["fast"]}


def _serve_sim_10k_speculative() -> Dict[str, float]:
    """10k requests under a scheduler declaring only ``decode_stable``:
    every decode leap is speculative (snapshot + rollback on arrivals) —
    the non-``steady_decode`` case that previously ran per-step."""
    import gc

    from benchmarks.bench_serve_sim import SpeculativeContinuousScheduler
    from repro.serve_sim import simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, SpeculativeContinuousScheduler,
                               _traffic(), replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _persistent_pool() -> Dict[str, float]:
    """Repeated ``explore(workers=4)`` sweeps: the first call pays the
    fork + structural-graph broadcast, later calls must show no per-call
    pool startup (they reuse workers and worker-side caches)."""
    from repro.core.avsm.model import annotate_system
    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.parallel import close_pools
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    systems = {"base": base,
               "fast_mem": annotate_system(base, mem_bandwidth=1638e9),
               "fast_link": annotate_system(base, link_bandwidth=200e9),
               "slow_mem": annotate_system(base, mem_bandwidth=500e9)}
    dse = DesignSpaceExplorer({"w": ops})
    t0 = time.perf_counter()
    serial = dse.explore(systems, keep=4)
    t_serial = time.perf_counter() - t0
    close_pools()                            # measure a cold first call
    calls = []
    for _ in range(4):
        t0 = time.perf_counter()
        parallel = dse.explore(systems, keep=4, workers=4)
        calls.append(time.perf_counter() - t0)
    close_pools()
    assert [(r.system, r.confirmed.step_time) for r in serial] == \
        [(r.system, r.confirmed.step_time) for r in parallel]
    steady = min(calls[1:])
    return {"explore_serial_seconds": t_serial,
            "explore_first_call_seconds": calls[0],
            "explore_steady_call_seconds": steady,
            "steady_vs_first_speedup": calls[0] / steady}


def collect() -> Dict:
    from benchmarks import bench_engine

    return {
        "engine_fifo_events_per_sec": bench_engine.fifo_events_per_sec(),
        "engine_shared_tasks_per_sec": bench_engine.shared_tasks_per_sec(),
        "engine_dynamic_injection_events_per_sec":
            bench_engine.dynamic_events_per_sec(),
        "what_if_points_per_sec": _what_if_points_per_sec(),
        "serve_sim_10k": _serve_sim_10k(),
        "serve_sim_10k_taskgraph": _serve_sim_10k_taskgraph(),
        "serve_sim_10k_speculative": _serve_sim_10k_speculative(),
        "persistent_pool": _persistent_pool(),
    }


def _speedups(base: Dict, cur: Dict) -> Dict:
    out: Dict = {}
    for key, bval in base.items():
        cval = cur.get(key)
        if isinstance(bval, dict):
            out[key] = {k: round(cval[k] / v, 2) if k in cval and v else None
                        for k, v in bval.items()}
        elif bval:
            out[key] = round(cval / bval, 2)
    # wall times speed up as baseline/current
    ws = out.get("serve_sim_10k", {})
    if "wall_seconds" in ws and ws["wall_seconds"]:
        ws["wall_seconds"] = round(1.0 / ws["wall_seconds"], 2)
    return out


def write(path: str = "BENCH_pr4.json") -> Dict:
    current = collect()
    doc = {
        "pr": 4,
        "description": "Fast dynamic simulation: array-backed event loop "
                       "for injected task graphs, speculative decode-leap "
                       "with rollback, persistent DSE worker pool",
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "baseline_pr3": BASELINE_PR3,
        "current": current,
        "speedup_vs_pr3": _speedups(BASELINE_PR3, current),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


if __name__ == "__main__":
    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    out = write(sys.argv[1] if len(sys.argv) > 1 else "BENCH_pr4.json")
    print(json.dumps({"speedup_vs_pr3": out["speedup_vs_pr3"],
                      "taskgraph": out["current"]["serve_sim_10k_taskgraph"],
                      "speculative":
                          out["current"]["serve_sim_10k_speculative"],
                      "pool": out["current"]["persistent_pool"]}, indent=2))
