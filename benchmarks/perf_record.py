"""Machine-readable perf trajectory: writes ``BENCH_pr10.json``.

This PR adds resilient cluster serving: heterogeneous ``ReplicaPool``\\ s
behind a pluggable routing tier with health-checked rotation, cross-pool
failover, latency hedging, circuit breakers and reactive autoscaling.
The headline metric is the new ``cluster_1m_chaos`` scenario — one
million requests through a 72-replica, 3-zone cluster under live
MTBF/MTTR churn with health checks and failover, in a single
``ClusterSimulator`` run; the companion gate is
``benchmarks/cluster_smoke.py``, which pins seeded determinism, 1-pool
golden parity with the standalone simulator, and a < 10% routing-tier
overhead bound::

    PYTHONPATH=src python benchmarks/run.py --json        # BENCH_pr10.json
    PYTHONPATH=src python benchmarks/perf_record.py       # same, standalone
    PYTHONPATH=src python benchmarks/perf_record.py --trials 3   # medians

``BASELINE_PR9`` is the ``current`` section of the committed
``BENCH_pr9.json``; absolute numbers are machine-dependent, the *ratios*
are the tracked signal.  Paired comparisons (MC vs scalar loop, fast vs
dict engine, probe-on vs probe-off) are measured interleaved in this
process, so load drifts hit both sides.  The ``--trials N`` median mode
exists so recordings are robust to a single bad window: each trial runs
the full suite, and every leaf metric reports the across-trial median.
"""
from __future__ import annotations

import json
import platform
import statistics
import sys
import time
from typing import Dict, List

# The "current" section of BENCH_pr9.json, measured at db7ec02 (PR 9).
BASELINE_PR9: Dict = {
    "engine_fifo_events_per_sec": {"dict": 130041.6244, "static_cold": 395160.8601, "static_warm": 590498.0828},
    "engine_shared_tasks_per_sec": {"200": 315746.7647, "800": 281400.3184, "3200": 261530.9186, "6400": 237899.9265},
    "engine_dynamic_injection_events_per_sec": {"dict": 93394.3696, "fast": 753716.5291},
    "what_if_points_per_sec": {"roofline": 2145.7852, "analytic": 1558.9365, "des": 40.1664},
    "serve_sim_10k": {"wall_seconds": 0.3341, "requests_per_sec": 29928.7271},
    "serve_sim_10k_taskgraph": {"fast_wall_seconds": 0.4534, "dict_wall_seconds": 2.769, "fast_requests_per_sec": 22055.8395, "speedup_fast_vs_dict": 5.8425},
    "serve_sim_10k_speculative": {"wall_seconds": 0.3215, "requests_per_sec": 31100.726},
    "serve_sim_10k_taskgraph_speculative": {"wall_seconds": 0.4163, "requests_per_sec": 24022.497},
    "serve_sim_10k_chaos": {"wall_seconds": 0.1035, "requests_per_sec": 94650.2414, "availability": 0.9128, "n_failures": 69, "n_retries": 338, "n_abandoned": 207},
    "monte_carlo": {"seeds": 64, "requests_per_seed": 10000, "scalar_ref_seeds": 8, "mc_wall_seconds": 4.5762, "scalar_loop_wall_seconds_est": 30.7519, "mc_seed_requests_per_sec": 139855.2623, "scalar_seed_requests_per_sec": 20811.7361, "speedup_mc_vs_scalar_loop": 6.5956, "sweep_point_slots": 256, "sweep_single_seed_seconds": 1.4074, "sweep_64seed_seconds": 3.4171, "sweep_64seed_cost_vs_single": 2.4332},
    "persistent_pool": {"explore_serial_seconds": 0.1645, "explore_first_call_seconds": 0.6066, "explore_steady_call_seconds": 0.0965, "steady_vs_first_speedup": 6.2837},
    "obs_overhead": {"off_wall_seconds": 0.3493, "sampled_wall_seconds": 0.3794, "full_wall_seconds": 0.5884, "sampled_overhead_pct": 7.8482, "full_overhead_pct": 72.3129},
}


def _what_if_points_per_sec() -> Dict[str, float]:
    import numpy as np

    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"w": ops})
    dse.compiled("w", base).anno_arrays()       # steady-state sweep loop
    values = list(np.linspace(50e9, 200e9, 16))
    out = {}
    for backend in ("roofline", "analytic", "des"):
        t0 = time.perf_counter()
        dse.what_if_sweep("w", base, "link_bandwidth", values,
                          backend=backend)
        out[backend] = len(values) / (time.perf_counter() - t0)
    return out


def _serve_cost() -> object:
    from repro.core.config import get_arch
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.serve_sim import ServingCostModelBuilder

    cfg = get_arch("qwen1.5-0.5b").model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    return ServingCostModelBuilder(
        cfg, shard=ShardPlan(data=1, model=1)).model_for(base)


def _traffic(n=10_000):
    from repro.serve_sim import LengthDist, poisson_workload

    return poisson_workload(120.0, n,
                            prompt=LengthDist(mean=512, cv=0.6),
                            output=LengthDist(mean=96, cv=0.5), seed=0)


def _serve_sim_10k() -> Dict[str, float]:
    import gc

    from repro.serve_sim import ContinuousBatchingScheduler, simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, ContinuousBatchingScheduler, _traffic(),
                               replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _serve_sim_10k_taskgraph(reps: int = 3) -> Dict[str, float]:
    """10k requests with full task-graph injection (4 chunks + KV writes
    per phase): array-backed dynamic engine vs the PR 3 dict path,
    interleaved best-of-``reps``."""
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    import gc

    cost = _serve_cost()
    walls = {"fast": float("inf"), "dict": float("inf")}
    n = 0
    for _ in range(reps):
        for engine in ("fast", "dict"):
            gc.collect()                     # drain prior suites' garbage
            t0 = time.perf_counter()
            rep = ServingSimulator(cost, ContinuousBatchingScheduler,
                                   _traffic(), replicas=4, slots=8,
                                   phase_tasks=4, engine=engine).run()
            walls[engine] = min(walls[engine], time.perf_counter() - t0)
            n = rep.n_requests
    return {"fast_wall_seconds": walls["fast"],
            "dict_wall_seconds": walls["dict"],
            "fast_requests_per_sec": n / walls["fast"],
            "speedup_fast_vs_dict": walls["dict"] / walls["fast"]}


def _serve_sim_10k_speculative() -> Dict[str, float]:
    """10k requests under a scheduler declaring only ``decode_stable``:
    every decode leap is speculative (snapshot + rollback on arrivals) —
    the non-``steady_decode`` case that previously ran per-step."""
    import gc

    from benchmarks.bench_serve_sim import SpeculativeContinuousScheduler
    from repro.serve_sim import simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, SpeculativeContinuousScheduler,
                               _traffic(), replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _serve_sim_10k_taskgraph_speculative() -> Dict[str, float]:
    """10k requests with full task-graph injection under the
    decode_stable-only scheduler: every decode leap is booked as one
    ``TemplateLane`` burst of per-step template instances and rolled
    back (burst truncation at a snapshot boundary) when an arrival
    lands mid-leap — graph fidelity at lane-path speed."""
    import gc

    from benchmarks.bench_serve_sim import SpeculativeContinuousScheduler
    from repro.serve_sim import ServingSimulator

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = ServingSimulator(cost, SpeculativeContinuousScheduler,
                               _traffic(), replicas=4, slots=8,
                               phase_tasks=4).run()
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _serve_sim_10k_chaos() -> Dict[str, float]:
    """10k requests on the fused fast path under live fault injection:
    MTBF=5s / MTTR=0.8s crash churn across 4 replicas with
    retry/backoff/deadline on every cancelled request.  The recorded
    availability / failure / retry counts are seeded and bit-stable;
    ``benchmarks/chaos_smoke.py`` separately bounds the armed-but-idle
    machinery cost on the no-fault scenario."""
    import gc

    from repro.serve_sim import FailureModel, RetryPolicy, compile_faults
    from repro.serve_sim.monte_carlo import _simulate_continuous_fast

    cost = _serve_cost()
    wl = _traffic()
    times = [r.t_arrive for r in wl.requests]
    prompts = [r.prompt_tokens for r in wl.requests]
    outputs = [r.output_tokens for r in wl.requests]
    failures = FailureModel(mtbf=5.0, mttr=0.8, seed=7, horizon=120.0)
    retry = RetryPolicy(max_attempts=4, backoff=0.02, deadline=30.0)
    cf = compile_faults(failures, 4, seed=(failures.seed, 0))
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = _simulate_continuous_fast(cost, times, prompts, outputs, 4, 8,
                                        "chaos", faults=cf, retry=retry)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall,
            "requests_per_sec": rep.n_requests / wall,
            "availability": rep.availability,
            "n_failures": rep.n_failures,
            "n_retries": rep.n_retries,
            "n_abandoned": rep.n_abandoned}


def _cluster_1m_chaos() -> Dict[str, float]:
    """One million requests through a 72-replica, 3-zone heterogeneous
    cluster under live fault churn: per-zone MTBF=60s / MTTR=5s crash
    processes, health-checked rotation (1s probes), least-loaded routing
    with cross-pool failover, all in a single ``ClusterSimulator`` run.
    Long-running by design — the acceptance point for this PR is that a
    fleet-scale scenario completes in one simulation, so it runs once
    per collect() pass (no inner best-of reps)."""
    import gc

    from repro.serve_sim import (ClusterSimulator, FailureModel,
                                 HealthCheckPolicy, LeastLoadedRouter,
                                 ReplicaPool, RetryPolicy, poisson_workload)

    cost = _serve_cost()
    pools = [ReplicaPool(f"zone-{z}", cost, 24, slots=16,
                         failures=FailureModel(mtbf=60.0, mttr=5.0,
                                               seed=10 + z, horizon=600.0),
                         retry=RetryPolicy())
             for z in range(3)]
    n = 1_000_000
    wl = poisson_workload(8000.0, n, seed=1)
    gc.collect()
    t0 = time.perf_counter()
    rep = ClusterSimulator(pools, wl, LeastLoadedRouter(retry_budget=4),
                           health=HealthCheckPolicy(interval=1.0)).run()
    wall = time.perf_counter() - t0
    return {"wall_seconds": wall,
            "requests_per_sec": rep.n_requests / wall,
            "replicas": rep.replicas,
            "sim_duration_seconds": rep.duration,
            "availability": rep.availability,
            "fleet_availability": rep.fleet_availability,
            "n_failures": rep.n_failures,
            "n_failovers": rep.n_failovers}


def _monte_carlo() -> Dict[str, float]:
    """Seed-batched Monte-Carlo serving vs looping the scalar simulator.

    Headline: 64 seeds x 10k requests through continuous batching
    (replicas=4, slots=32, 300 rps Poisson) in one
    ``MonteCarloServingSimulator`` call, against the scalar
    ``simulate_serving`` loop over the same seed rows — measured on
    ``scalar_ref_seeds`` rows and scaled linearly (per-seed scalar cost
    is independent across seeds).  Acceptance: the MC path sustains
    >= 5x (seeds x requests)/wall-second.

    Second check: one ``sweep_serving`` design point at slots=256 with
    ``num_seeds=64`` vs the single-seed point.  Acceptance: <= 3x —
    decode bursts dominate at large batch, and the MC fast path
    advances one in O(log slots) (packed completion heap) where the
    scalar simulator scans all slots.
    """
    import functools
    import gc

    from repro.core.config import get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.core.taskgraph.ops import matmul_op
    from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                                 MonteCarloServingSimulator,
                                 ServingCostModelBuilder,
                                 poisson_workload, poisson_workload_batch,
                                 simulate_serving)

    cost = _serve_cost()
    dists = dict(prompt=LengthDist(mean=512, cv=0.6),
                 output=LengthDist(mean=96, cv=0.5))
    seeds, n = 64, 10_000
    batch = poisson_workload_batch(300.0, n, seeds=seeds, **dists)
    sim = MonteCarloServingSimulator(cost, ContinuousBatchingScheduler,
                                     batch, replicas=4, slots=32)
    assert sim.fast_path, "headline scenario must hit the fused fast path"
    gc.collect()
    t0 = time.perf_counter()
    sim.run()
    mc_wall = time.perf_counter() - t0
    ref = 8                                  # scalar loop sample (i.i.d.)
    gc.collect()
    t0 = time.perf_counter()
    for k in range(ref):
        simulate_serving(cost, ContinuousBatchingScheduler,
                         batch.workload(k), replicas=4, slots=32)
    scalar_wall = (time.perf_counter() - t0) * (seeds / ref)
    out = {
        "seeds": seeds, "requests_per_seed": n, "scalar_ref_seeds": ref,
        "mc_wall_seconds": mc_wall,
        "scalar_loop_wall_seconds_est": scalar_wall,
        "mc_seed_requests_per_sec": seeds * n / mc_wall,
        "scalar_seed_requests_per_sec": seeds * n / scalar_wall,
        "speedup_mc_vs_scalar_loop": scalar_wall / mc_wall,
    }

    # one sweep_serving design point: num_seeds=64 vs num_seeds=1
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    dse = DesignSpaceExplorer({"w": [matmul_op("m", "m", 64, 64, 64)]})
    builder = ServingCostModelBuilder(
        get_arch("qwen1.5-0.5b").model, shard=ShardPlan(data=1, model=1))
    sched = {"continuous": ContinuousBatchingScheduler}
    walls = {}
    gc.collect()
    for label, traffic, kw in (
            ("single", functools.partial(poisson_workload, 1000.0, n,
                                         seed=0, **dists), {}),
            ("mc64", functools.partial(poisson_workload_batch, 1000.0, n,
                                       seeds=seeds, **dists),
             {"num_seeds": seeds})):
        t0 = time.perf_counter()
        dse.sweep_serving({"v5e": base}, {"poisson": traffic}, sched,
                          cost_builder=builder, replicas=4, slots=256, **kw)
        walls[label] = time.perf_counter() - t0
    out.update({
        "sweep_point_slots": 256,
        "sweep_single_seed_seconds": walls["single"],
        "sweep_64seed_seconds": walls["mc64"],
        "sweep_64seed_cost_vs_single": walls["mc64"] / walls["single"],
    })
    return out


def _persistent_pool() -> Dict[str, float]:
    """Repeated ``explore(workers=4)`` sweeps: the first call pays the
    fork + structural-graph broadcast, later calls must show no per-call
    pool startup (they reuse workers and worker-side caches)."""
    from repro.core.avsm.model import annotate_system
    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.parallel import close_pools
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    systems = {"base": base,
               "fast_mem": annotate_system(base, mem_bandwidth=1638e9),
               "fast_link": annotate_system(base, link_bandwidth=200e9),
               "slow_mem": annotate_system(base, mem_bandwidth=500e9)}
    dse = DesignSpaceExplorer({"w": ops})
    t0 = time.perf_counter()
    serial = dse.explore(systems, keep=4)
    t_serial = time.perf_counter() - t0
    close_pools()                            # measure a cold first call
    calls = []
    for _ in range(4):
        t0 = time.perf_counter()
        parallel = dse.explore(systems, keep=4, workers=4)
        calls.append(time.perf_counter() - t0)
    close_pools()
    assert [(r.system, r.confirmed.step_time) for r in serial] == \
        [(r.system, r.confirmed.step_time) for r in parallel]
    steady = min(calls[1:])
    return {"explore_serial_seconds": t_serial,
            "explore_first_call_seconds": calls[0],
            "explore_steady_call_seconds": steady,
            "steady_vs_first_speedup": calls[0] / steady}


def _obs_overhead() -> Dict[str, float]:
    """Probe-on vs probe-off cost of the 10k-request serving run,
    interleaved best-of-3.  ``sampled`` uses the default bundle sampling
    (``sample_every=64``); acceptance is < 10% overhead there (asserted
    by ``benchmarks/obs_smoke.py`` in CI)."""
    import gc

    from repro.obs import Probe
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    cost = _serve_cost()
    walls = {"off": float("inf"), "sampled": float("inf"),
             "full": float("inf")}
    for _ in range(3):
        for label, factory in (("off", lambda: None),
                               ("sampled", lambda: Probe(sample_every=64)),
                               ("full", lambda: Probe())):
            gc.collect()
            t0 = time.perf_counter()
            ServingSimulator(cost, ContinuousBatchingScheduler, _traffic(),
                             replicas=4, slots=8, probe=factory()).run()
            walls[label] = min(walls[label], time.perf_counter() - t0)
    return {
        "off_wall_seconds": walls["off"],
        "sampled_wall_seconds": walls["sampled"],
        "full_wall_seconds": walls["full"],
        "sampled_overhead_pct":
            (walls["sampled"] / walls["off"] - 1.0) * 100.0,
        "full_overhead_pct": (walls["full"] / walls["off"] - 1.0) * 100.0,
    }


def _median_merge(docs: List[Dict]) -> Dict:
    """Element-wise median across identically-shaped metric dicts."""
    out: Dict = {}
    for key, v in docs[0].items():
        if isinstance(v, dict):
            out[key] = _median_merge([d[key] for d in docs])
        elif isinstance(v, (int, float)):
            out[key] = statistics.median(d[key] for d in docs)
        else:
            out[key] = v
    return out


def collect(trials: int = 1) -> Dict:
    """One full suite pass — or, with ``trials > 1``, the per-metric
    median over that many passes (robust to a transiently loaded
    machine; see the module docstring on the PR 4 recording)."""
    from benchmarks import bench_engine

    def once() -> Dict:
        return {
            "engine_fifo_events_per_sec": bench_engine.fifo_events_per_sec(),
            "engine_shared_tasks_per_sec":
                bench_engine.shared_tasks_per_sec(),
            "engine_dynamic_injection_events_per_sec":
                bench_engine.dynamic_events_per_sec(),
            "what_if_points_per_sec": _what_if_points_per_sec(),
            "serve_sim_10k": _serve_sim_10k(),
            "serve_sim_10k_taskgraph": _serve_sim_10k_taskgraph(),
            "serve_sim_10k_speculative": _serve_sim_10k_speculative(),
            "serve_sim_10k_taskgraph_speculative":
                _serve_sim_10k_taskgraph_speculative(),
            "serve_sim_10k_chaos": _serve_sim_10k_chaos(),
            "cluster_1m_chaos": _cluster_1m_chaos(),
            "monte_carlo": _monte_carlo(),
            "persistent_pool": _persistent_pool(),
            "obs_overhead": _obs_overhead(),
        }

    if trials <= 1:
        return once()
    return _median_merge([once() for _ in range(trials)])


def _speedups(base: Dict, cur: Dict) -> Dict:
    """Per-metric current/baseline ratios; keys measured in seconds
    invert (baseline/current) so that > 1 always means faster."""
    out: Dict = {}
    for key, bval in base.items():
        if key not in cur:
            continue
        cval = cur[key]
        if isinstance(bval, dict):
            sub = {}
            for k, v in bval.items():
                if k not in cval or not v:
                    sub[k] = None
                elif k.endswith("seconds"):
                    sub[k] = round(v / cval[k], 2)
                else:
                    sub[k] = round(cval[k] / v, 2)
            out[key] = sub
        elif bval:
            out[key] = round(cval / bval, 2)
    return out


def write(path: str = "BENCH_pr10.json", trials: int = 1) -> Dict:
    current = collect(trials=trials)
    doc = {
        "pr": 10,
        "description": "Resilient cluster serving: health-checked "
                       "routing tier over heterogeneous replica pools "
                       "with failover, hedging, circuit breakers and "
                       "fault-aware autoscaling",
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "trials": trials,
        "note": "baseline_pr9 is a different-day recording on shared "
                "hardware; cross-recording ratios carry ~10-15% machine "
                "variance (verified by interleaving HEAD and PR 10 "
                "working trees on one machine: identical within noise). "
                "Regression gating uses the same-run paired floors in "
                "perf_smoke.py / cluster_smoke.py, not this file.",
        "baseline_pr9": BASELINE_PR9,
        "current": current,
        "speedup_vs_pr9": _speedups(BASELINE_PR9, current),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


if __name__ == "__main__":
    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    argv = sys.argv[1:]
    trials = 1
    if "--trials" in argv:
        i = argv.index("--trials")
        trials = int(argv[i + 1])
        del argv[i:i + 2]
    out = write(argv[0] if argv else "BENCH_pr10.json", trials=trials)
    print(json.dumps({"speedup_vs_pr9": out["speedup_vs_pr9"],
                      "chaos": out["current"]["serve_sim_10k_chaos"],
                      "cluster": out["current"]["cluster_1m_chaos"],
                      }, indent=2))
