"""Machine-readable perf trajectory: writes ``BENCH_pr6.json``.

Collects the current throughput of the hot paths this PR optimized — the
seed-batched Monte-Carlo serving simulator (one
``MonteCarloServingSimulator`` call over 64 pre-generated seed rows vs
looping the scalar simulator) and the ``num_seeds=64`` DSE design point
(must stay within 3x of the single-seed point) — next to the PR 3/4
paths (engine events/sec, what-if points/sec, serve-sim requests/sec)::

    PYTHONPATH=src python benchmarks/run.py --json        # BENCH_pr6.json
    PYTHONPATH=src python benchmarks/perf_record.py       # same, standalone
    PYTHONPATH=src python benchmarks/perf_record.py --trials 3   # medians

``BASELINE_PR4`` is the ``current`` section of the committed
``BENCH_pr4.json``; absolute numbers are machine-dependent, the *ratios*
are the tracked signal.  Paired comparisons (MC vs scalar loop, fast vs
dict engine) are measured interleaved in this process, so load drifts
hit both sides.

A note on the PR 4 absolute numbers: they show a uniform ~0.6x drop on
the pure-Python benches vs PR 3 (fifo dict 114.7k -> 67.1k ev/s) while
numpy-heavy benches *rose* — the signature of a contended recording
container, not a code change.  Replaying the PR 3 tree interleaved with
the current one on one machine confirms it: current code matches or
beats PR 3 on every fifo metric (dict ~137k vs ~129k ev/s).  The
``--trials N`` median mode exists so future recordings are robust to a
single bad window: each trial runs the full suite, and every leaf metric
reports the across-trial median.
"""
from __future__ import annotations

import json
import platform
import statistics
import sys
import time
from typing import Dict, List

# The "current" section of BENCH_pr4.json, measured at 44edf76 (PR 4).
BASELINE_PR4: Dict = {
    "engine_fifo_events_per_sec": {
        "dict": 67_110.4, "static_cold": 280_771.3, "static_warm": 353_703.6},
    "engine_shared_tasks_per_sec": {
        "200": 176_430.2, "800": 171_743.9, "3200": 159_026.5,
        "6400": 139_543.5},
    "engine_dynamic_injection_events_per_sec": {
        "dict": 68_446.5, "fast": 284_920.5},
    "what_if_points_per_sec": {
        "roofline": 910.6, "analytic": 947.2, "des": 27.4},
    "serve_sim_10k": {"wall_seconds": 0.6187, "requests_per_sec": 16_163.9},
    "serve_sim_10k_taskgraph": {
        "fast_wall_seconds": 1.0869, "dict_wall_seconds": 4.7604,
        "fast_requests_per_sec": 9_200.4, "speedup_fast_vs_dict": 4.38},
    "serve_sim_10k_speculative": {
        "wall_seconds": 0.4316, "requests_per_sec": 23_169.4},
    "persistent_pool": {
        "explore_serial_seconds": 0.2958,
        "explore_first_call_seconds": 2.2242,
        "explore_steady_call_seconds": 0.1327,
        "steady_vs_first_speedup": 16.77},
}


def _what_if_points_per_sec() -> Dict[str, float]:
    import numpy as np

    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"w": ops})
    dse.compiled("w", base).anno_arrays()       # steady-state sweep loop
    values = list(np.linspace(50e9, 200e9, 16))
    out = {}
    for backend in ("roofline", "analytic", "des"):
        t0 = time.perf_counter()
        dse.what_if_sweep("w", base, "link_bandwidth", values,
                          backend=backend)
        out[backend] = len(values) / (time.perf_counter() - t0)
    return out


def _serve_cost() -> object:
    from repro.core.config import get_arch
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.serve_sim import ServingCostModelBuilder

    cfg = get_arch("qwen1.5-0.5b").model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    return ServingCostModelBuilder(
        cfg, shard=ShardPlan(data=1, model=1)).model_for(base)


def _traffic(n=10_000):
    from repro.serve_sim import LengthDist, poisson_workload

    return poisson_workload(120.0, n,
                            prompt=LengthDist(mean=512, cv=0.6),
                            output=LengthDist(mean=96, cv=0.5), seed=0)


def _serve_sim_10k() -> Dict[str, float]:
    import gc

    from repro.serve_sim import ContinuousBatchingScheduler, simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, ContinuousBatchingScheduler, _traffic(),
                               replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _serve_sim_10k_taskgraph(reps: int = 3) -> Dict[str, float]:
    """10k requests with full task-graph injection (4 chunks + KV writes
    per phase): array-backed dynamic engine vs the PR 3 dict path,
    interleaved best-of-``reps``."""
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    import gc

    cost = _serve_cost()
    walls = {"fast": float("inf"), "dict": float("inf")}
    n = 0
    for _ in range(reps):
        for engine in ("fast", "dict"):
            gc.collect()                     # drain prior suites' garbage
            t0 = time.perf_counter()
            rep = ServingSimulator(cost, ContinuousBatchingScheduler,
                                   _traffic(), replicas=4, slots=8,
                                   phase_tasks=4, engine=engine).run()
            walls[engine] = min(walls[engine], time.perf_counter() - t0)
            n = rep.n_requests
    return {"fast_wall_seconds": walls["fast"],
            "dict_wall_seconds": walls["dict"],
            "fast_requests_per_sec": n / walls["fast"],
            "speedup_fast_vs_dict": walls["dict"] / walls["fast"]}


def _serve_sim_10k_speculative() -> Dict[str, float]:
    """10k requests under a scheduler declaring only ``decode_stable``:
    every decode leap is speculative (snapshot + rollback on arrivals) —
    the non-``steady_decode`` case that previously ran per-step."""
    import gc

    from benchmarks.bench_serve_sim import SpeculativeContinuousScheduler
    from repro.serve_sim import simulate_serving

    cost = _serve_cost()
    wall = float("inf")
    for _ in range(2):
        gc.collect()
        t0 = time.perf_counter()
        rep = simulate_serving(cost, SpeculativeContinuousScheduler,
                               _traffic(), replicas=4, slots=8)
        wall = min(wall, time.perf_counter() - t0)
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def _monte_carlo() -> Dict[str, float]:
    """Seed-batched Monte-Carlo serving vs looping the scalar simulator.

    Headline: 64 seeds x 10k requests through continuous batching
    (replicas=4, slots=32, 300 rps Poisson) in one
    ``MonteCarloServingSimulator`` call, against the scalar
    ``simulate_serving`` loop over the same seed rows — measured on
    ``scalar_ref_seeds`` rows and scaled linearly (per-seed scalar cost
    is independent across seeds).  Acceptance: the MC path sustains
    >= 5x (seeds x requests)/wall-second.

    Second check: one ``sweep_serving`` design point at slots=256 with
    ``num_seeds=64`` vs the single-seed point.  Acceptance: <= 3x —
    decode bursts dominate at large batch, and the MC fast path
    advances one in O(log slots) (packed completion heap) where the
    scalar simulator scans all slots.
    """
    import functools
    import gc

    from repro.core.config import get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.core.taskgraph.ops import matmul_op
    from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                                 MonteCarloServingSimulator,
                                 ServingCostModelBuilder,
                                 poisson_workload, poisson_workload_batch,
                                 simulate_serving)

    cost = _serve_cost()
    dists = dict(prompt=LengthDist(mean=512, cv=0.6),
                 output=LengthDist(mean=96, cv=0.5))
    seeds, n = 64, 10_000
    batch = poisson_workload_batch(300.0, n, seeds=seeds, **dists)
    sim = MonteCarloServingSimulator(cost, ContinuousBatchingScheduler,
                                     batch, replicas=4, slots=32)
    assert sim.fast_path, "headline scenario must hit the fused fast path"
    gc.collect()
    t0 = time.perf_counter()
    sim.run()
    mc_wall = time.perf_counter() - t0
    ref = 8                                  # scalar loop sample (i.i.d.)
    gc.collect()
    t0 = time.perf_counter()
    for k in range(ref):
        simulate_serving(cost, ContinuousBatchingScheduler,
                         batch.workload(k), replicas=4, slots=32)
    scalar_wall = (time.perf_counter() - t0) * (seeds / ref)
    out = {
        "seeds": seeds, "requests_per_seed": n, "scalar_ref_seeds": ref,
        "mc_wall_seconds": mc_wall,
        "scalar_loop_wall_seconds_est": scalar_wall,
        "mc_seed_requests_per_sec": seeds * n / mc_wall,
        "scalar_seed_requests_per_sec": seeds * n / scalar_wall,
        "speedup_mc_vs_scalar_loop": scalar_wall / mc_wall,
    }

    # one sweep_serving design point: num_seeds=64 vs num_seeds=1
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    dse = DesignSpaceExplorer({"w": [matmul_op("m", "m", 64, 64, 64)]})
    builder = ServingCostModelBuilder(
        get_arch("qwen1.5-0.5b").model, shard=ShardPlan(data=1, model=1))
    sched = {"continuous": ContinuousBatchingScheduler}
    walls = {}
    gc.collect()
    for label, traffic, kw in (
            ("single", functools.partial(poisson_workload, 1000.0, n,
                                         seed=0, **dists), {}),
            ("mc64", functools.partial(poisson_workload_batch, 1000.0, n,
                                       seeds=seeds, **dists),
             {"num_seeds": seeds})):
        t0 = time.perf_counter()
        dse.sweep_serving({"v5e": base}, {"poisson": traffic}, sched,
                          cost_builder=builder, replicas=4, slots=256, **kw)
        walls[label] = time.perf_counter() - t0
    out.update({
        "sweep_point_slots": 256,
        "sweep_single_seed_seconds": walls["single"],
        "sweep_64seed_seconds": walls["mc64"],
        "sweep_64seed_cost_vs_single": walls["mc64"] / walls["single"],
    })
    return out


def _persistent_pool() -> Dict[str, float]:
    """Repeated ``explore(workers=4)`` sweeps: the first call pays the
    fork + structural-graph broadcast, later calls must show no per-call
    pool startup (they reuse workers and worker-side caches)."""
    from repro.core.avsm.model import annotate_system
    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.parallel import close_pools
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    systems = {"base": base,
               "fast_mem": annotate_system(base, mem_bandwidth=1638e9),
               "fast_link": annotate_system(base, link_bandwidth=200e9),
               "slow_mem": annotate_system(base, mem_bandwidth=500e9)}
    dse = DesignSpaceExplorer({"w": ops})
    t0 = time.perf_counter()
    serial = dse.explore(systems, keep=4)
    t_serial = time.perf_counter() - t0
    close_pools()                            # measure a cold first call
    calls = []
    for _ in range(4):
        t0 = time.perf_counter()
        parallel = dse.explore(systems, keep=4, workers=4)
        calls.append(time.perf_counter() - t0)
    close_pools()
    assert [(r.system, r.confirmed.step_time) for r in serial] == \
        [(r.system, r.confirmed.step_time) for r in parallel]
    steady = min(calls[1:])
    return {"explore_serial_seconds": t_serial,
            "explore_first_call_seconds": calls[0],
            "explore_steady_call_seconds": steady,
            "steady_vs_first_speedup": calls[0] / steady}


def _median_merge(docs: List[Dict]) -> Dict:
    """Element-wise median across identically-shaped metric dicts."""
    out: Dict = {}
    for key, v in docs[0].items():
        if isinstance(v, dict):
            out[key] = _median_merge([d[key] for d in docs])
        elif isinstance(v, (int, float)):
            out[key] = statistics.median(d[key] for d in docs)
        else:
            out[key] = v
    return out


def collect(trials: int = 1) -> Dict:
    """One full suite pass — or, with ``trials > 1``, the per-metric
    median over that many passes (robust to a transiently loaded
    machine; see the module docstring on the PR 4 recording)."""
    from benchmarks import bench_engine

    def once() -> Dict:
        return {
            "engine_fifo_events_per_sec": bench_engine.fifo_events_per_sec(),
            "engine_shared_tasks_per_sec":
                bench_engine.shared_tasks_per_sec(),
            "engine_dynamic_injection_events_per_sec":
                bench_engine.dynamic_events_per_sec(),
            "what_if_points_per_sec": _what_if_points_per_sec(),
            "serve_sim_10k": _serve_sim_10k(),
            "serve_sim_10k_taskgraph": _serve_sim_10k_taskgraph(),
            "serve_sim_10k_speculative": _serve_sim_10k_speculative(),
            "monte_carlo": _monte_carlo(),
            "persistent_pool": _persistent_pool(),
        }

    if trials <= 1:
        return once()
    return _median_merge([once() for _ in range(trials)])


def _speedups(base: Dict, cur: Dict) -> Dict:
    """Per-metric current/baseline ratios; keys measured in seconds
    invert (baseline/current) so that > 1 always means faster."""
    out: Dict = {}
    for key, bval in base.items():
        if key not in cur:
            continue
        cval = cur[key]
        if isinstance(bval, dict):
            sub = {}
            for k, v in bval.items():
                if k not in cval or not v:
                    sub[k] = None
                elif k.endswith("seconds"):
                    sub[k] = round(v / cval[k], 2)
                else:
                    sub[k] = round(cval[k] / v, 2)
            out[key] = sub
        elif bval:
            out[key] = round(cval / bval, 2)
    return out


def write(path: str = "BENCH_pr6.json", trials: int = 1) -> Dict:
    current = collect(trials=trials)
    doc = {
        "pr": 6,
        "description": "Seed-batched Monte-Carlo serving: policy/advance "
                       "split, fused continuous-batching fast path, "
                       "num_seeds DSE sweeps and CI-aware capacity "
                       "planning",
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "trials": trials,
        "baseline_pr4": BASELINE_PR4,
        "current": current,
        "speedup_vs_pr4": _speedups(BASELINE_PR4, current),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


if __name__ == "__main__":
    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    argv = sys.argv[1:]
    trials = 1
    if "--trials" in argv:
        i = argv.index("--trials")
        trials = int(argv[i + 1])
        del argv[i:i + 2]
    out = write(argv[0] if argv else "BENCH_pr6.json", trials=trials)
    print(json.dumps({"speedup_vs_pr4": out["speedup_vs_pr4"],
                      "monte_carlo": out["current"]["monte_carlo"],
                      "pool": out["current"]["persistent_pool"]}, indent=2))
