"""Machine-readable perf trajectory: writes ``BENCH_pr3.json``.

Collects the current throughput of the three hot paths this PR optimized
(DES engine events/sec, DSE what-if points/sec, serve_sim requests/sec,
plus wall times) and records them next to the pre-PR baseline, so the
perf trajectory is tracked across PRs::

    PYTHONPATH=src python benchmarks/run.py --json        # BENCH_pr3.json
    PYTHONPATH=src python benchmarks/perf_record.py       # same, standalone

``BASELINE_PR2`` was measured at commit d90c17b (the PR 2 tree, seed
dict-based engine with the O(n)-per-event shared channel) on the same
container that produced the committed ``BENCH_pr3.json``; absolute
numbers are machine-dependent, the *ratios* are the tracked signal.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Dict

# Measured at d90c17b (pre-PR3), same best-of-3 harness as collect() below.
BASELINE_PR2: Dict = {
    "engine_fifo_events_per_sec": {"dict": 82_309.0},
    "engine_shared_tasks_per_sec": {
        "200": 29_831.0, "800": 8_710.0, "3200": 3_217.0, "6400": 1_548.0},
    "what_if_points_per_sec": {
        "roofline": 289.5, "analytic": 67.9, "des": 7.0},
    "serve_sim_10k": {"wall_seconds": 5.235, "requests_per_sec": 1_910.0},
}


def _what_if_points_per_sec() -> Dict[str, float]:
    import numpy as np

    from repro.core.config import LM_SHAPES, get_arch
    from repro.core.dse import DesignSpaceExplorer
    from repro.core.hw import tpu_v5e_pod
    from repro.core.taskgraph.builders import ShardPlan, lm_step_ops

    spec = get_arch("qwen1.5-0.5b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"w": ops})
    dse.compiled("w", base).anno_arrays()       # steady-state sweep loop
    values = list(np.linspace(50e9, 200e9, 16))
    out = {}
    for backend in ("roofline", "analytic", "des"):
        t0 = time.perf_counter()
        dse.what_if_sweep("w", base, "link_bandwidth", values,
                          backend=backend)
        out[backend] = len(values) / (time.perf_counter() - t0)
    return out


def _serve_sim_10k() -> Dict[str, float]:
    from repro.core.config import get_arch
    from repro.core.hw import SystemDescription, tpu_v5e_chip
    from repro.core.taskgraph.builders import ShardPlan
    from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                                 ServingCostModelBuilder, poisson_workload,
                                 simulate_serving)

    cfg = get_arch("qwen1.5-0.5b").model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    cost = ServingCostModelBuilder(
        cfg, shard=ShardPlan(data=1, model=1)).model_for(base)
    wl = poisson_workload(120.0, 10_000,
                          prompt=LengthDist(mean=512, cv=0.6),
                          output=LengthDist(mean=96, cv=0.5), seed=0)
    t0 = time.perf_counter()
    rep = simulate_serving(cost, ContinuousBatchingScheduler, wl,
                           replicas=4, slots=8)
    wall = time.perf_counter() - t0
    return {"wall_seconds": wall, "requests_per_sec": rep.n_requests / wall}


def collect() -> Dict:
    from benchmarks import bench_engine

    return {
        "engine_fifo_events_per_sec": bench_engine.fifo_events_per_sec(),
        "engine_shared_tasks_per_sec": bench_engine.shared_tasks_per_sec(),
        "what_if_points_per_sec": _what_if_points_per_sec(),
        "serve_sim_10k": _serve_sim_10k(),
    }


def _speedups(base: Dict, cur: Dict) -> Dict:
    out: Dict = {}
    for key, bval in base.items():
        cval = cur.get(key)
        if isinstance(bval, dict):
            out[key] = {k: round(cval[k] / v, 2) if k in cval and v else None
                        for k, v in bval.items()}
        elif bval:
            out[key] = round(cval / bval, 2)
    # wall times speed up as baseline/current
    ws = out.get("serve_sim_10k", {})
    if "wall_seconds" in ws and ws["wall_seconds"]:
        ws["wall_seconds"] = round(1.0 / ws["wall_seconds"], 2)
    return out


def write(path: str = "BENCH_pr3.json") -> Dict:
    current = collect()
    doc = {
        "pr": 3,
        "description": "Fast simulation core: virtual-time processor "
                       "sharing, array-backed DES hot path, vectorized "
                       "what-if sweeps, parallel DSE",
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "baseline_pr2": BASELINE_PR2,
        "current": current,
        "speedup_vs_pr2": _speedups(BASELINE_PR2, current),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return doc


if __name__ == "__main__":
    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    out = write(sys.argv[1] if len(sys.argv) > 1 else "BENCH_pr3.json")
    print(json.dumps(out["speedup_vs_pr2"], indent=2))
