"""CI perf-smoke: fail if simulation-core throughput regresses.

Runs the DES and serve-sim microbenchmarks and enforces conservative
floors — roughly two thirds of the throughput measured on the PR 8 tree
on a quiet container — so ordinary CI-machine variance passes but a
reintroduced O(n^2) hot path or per-task object churn fails loudly.
All scenarios run with ``probe=None``, so these floors also guard the
observability layer's disabled-path contract (one dead branch per hot
site, nothing else):

  * fifo static fast path (warm cache) >= 300k events/s
    (seed dict engine: ~86k; measured: ~400-615k)
  * shared-channel burst, n=3200       >= 120k tasks/s
    (seed: ~2.3k — the quadratic collapse; measured: ~140-260k)
  * shared-channel flatness n=6400/200 >= 0.3
    (quadratic scaling gives ~0.12: completions per burst grow 32x while
    per-event cost also grows 32x)
  * serve_sim 10k requests             >= 17k req/wall-s
    (seed: ~1.9k; measured: ~26k)
  * dynamic injection, fast engine     >= 420k events/s
    (PR 4's array-backed ``DynamicSimulator`` + template instantiation;
    the dict engine measures ~70k on the same scenario; measured ~700k)
  * serve_sim 10k, speculative leap    >= 15k req/wall-s
    (a ``decode_stable``-only scheduler: every decode fusion takes the
    snapshot/rollback path; measured ~23k)
  * serve_sim 10k, task-graph mode     >= 12k req/wall-s
    (PR 8's ``TemplateLane`` graph serving on the fast engine, 4 chunks
    + KV writes per phase; measured ~16-22k — the dict per-chunk engine
    sustains ~3k and the pre-TemplateLane fast path ~11k on the same
    scenario, so a lost burst/closed-form path fails loudly; the >= 2x
    vs PR 4 headline itself is recorded in BENCH_pr8.json)
  * serve_sim 10k, graph speculative   >= 11k req/wall-s
    (task-graph mode under the ``decode_stable``-only scheduler: every
    leap is one ``TemplateLane`` burst with snapshot rollback)
  * monte-carlo seed batch, 16 x 10k   >= 80k seed-requests/wall-s
    (PR 6's fused continuous-batching fast path at replicas=4 slots=32,
    300 rps Poisson; measured: ~108-128k — the scalar loop over the
    same rows sustains ~20k, so this floor also guards the >= 5x
    headline)

Exit code 0 on pass, 1 on any floor violation.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FLOORS = {
    "fifo_static_warm_events_per_sec": 300_000.0,
    "shared_3200_tasks_per_sec": 120_000.0,
    "shared_flatness_6400_over_200": 0.3,
    "serve_sim_requests_per_sec": 17_000.0,
    "dynamic_injection_fast_events_per_sec": 420_000.0,
    "serve_sim_speculative_requests_per_sec": 15_000.0,
    "serve_sim_taskgraph_requests_per_sec": 12_000.0,
    "serve_sim_taskgraph_speculative_requests_per_sec": 11_000.0,
    "monte_carlo_seed_requests_per_sec": 80_000.0,
}


def _taskgraph_requests_per_sec(speculative: bool) -> float:
    """10k requests in full task-graph mode on the fast engine
    (``TemplateLane`` serving), best-of-2.  ``speculative`` swaps in the
    ``decode_stable``-only scheduler so every leap takes the burst
    snapshot/rollback path."""
    from benchmarks.bench_serve_sim import SpeculativeContinuousScheduler
    from benchmarks.perf_record import _serve_cost, _traffic
    from repro.serve_sim import ContinuousBatchingScheduler, ServingSimulator

    cost = _serve_cost()
    sched = (SpeculativeContinuousScheduler if speculative
             else ContinuousBatchingScheduler)
    wall = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        rep = ServingSimulator(cost, sched, _traffic(), replicas=4,
                               slots=8, phase_tasks=4).run()
        wall = min(wall, time.perf_counter() - t0)
    return rep.n_requests / wall


def _monte_carlo_seed_requests_per_sec() -> float:
    """16 seeds x 10k requests through the fused MC fast path, as
    (seeds x requests) per wall second."""
    from benchmarks.perf_record import _serve_cost
    from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                                 MonteCarloServingSimulator,
                                 poisson_workload_batch)

    cost = _serve_cost()
    seeds, n = 16, 10_000
    batch = poisson_workload_batch(300.0, n,
                                   prompt=LengthDist(mean=512, cv=0.6),
                                   output=LengthDist(mean=96, cv=0.5),
                                   seeds=seeds)
    sim = MonteCarloServingSimulator(cost, ContinuousBatchingScheduler,
                                     batch, replicas=4, slots=32)
    assert sim.fast_path, "smoke scenario must hit the fused fast path"
    t0 = time.perf_counter()
    sim.run()
    return seeds * n / (time.perf_counter() - t0)


def main() -> int:
    from benchmarks import bench_engine
    from benchmarks.perf_record import (_serve_sim_10k,
                                        _serve_sim_10k_speculative)

    measured = {}
    fifo = bench_engine.fifo_events_per_sec()
    measured["fifo_static_warm_events_per_sec"] = fifo["static_warm"]
    shared = bench_engine.shared_tasks_per_sec()
    measured["shared_3200_tasks_per_sec"] = shared["3200"]
    measured["shared_flatness_6400_over_200"] = \
        shared["6400"] / shared["200"]
    measured["dynamic_injection_fast_events_per_sec"] = \
        bench_engine.dynamic_events_per_sec()["fast"]
    serve = _serve_sim_10k()
    measured["serve_sim_requests_per_sec"] = serve["requests_per_sec"]
    spec = _serve_sim_10k_speculative()
    measured["serve_sim_speculative_requests_per_sec"] = \
        spec["requests_per_sec"]
    measured["serve_sim_taskgraph_requests_per_sec"] = \
        _taskgraph_requests_per_sec(speculative=False)
    measured["serve_sim_taskgraph_speculative_requests_per_sec"] = \
        _taskgraph_requests_per_sec(speculative=True)
    measured["monte_carlo_seed_requests_per_sec"] = \
        _monte_carlo_seed_requests_per_sec()

    failed = False
    for key, floor in FLOORS.items():
        got = measured[key]
        status = "ok " if got >= floor else "FAIL"
        if got < floor:
            failed = True
        print(f"[{status}] {key}: {got:,.1f} (floor {floor:,.1f})")
    return 1 if failed else 0


if __name__ == "__main__":
    t0 = time.perf_counter()
    rc = main()
    print(f"perf-smoke finished in {time.perf_counter() - t0:.1f}s -> "
          f"{'FAIL' if rc else 'PASS'}")
    sys.exit(rc)
