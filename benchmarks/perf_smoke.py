"""CI perf-smoke: fail if simulation-core throughput regresses.

Runs the DES and serve-sim microbenchmarks and enforces conservative
floors — roughly a third of the throughput measured on the PR 3/PR 4
containers (see ``BENCH_pr3.json`` / ``BENCH_pr4.json``), so ordinary
CI-machine variance passes but a reintroduced O(n^2) hot path or
per-task object churn fails loudly:

  * fifo static fast path (warm cache)  >= 170k events/s
    (seed dict engine: ~86k; PR 3 measured: ~525k)
  * shared-channel burst, n=3200       >= 60k tasks/s
    (seed: ~2.3k — the quadratic collapse; PR 3 measured: ~190k)
  * shared-channel flatness n=6400/200 >= 0.3
    (quadratic scaling gives ~0.12: completions per burst grow 32x while
    per-event cost also grows 32x)
  * serve_sim 10k requests             >= 6400 req/wall-s
    (seed: ~1.9k; PR 3 measured: ~19k)
  * dynamic injection, fast engine     >= 150k events/s
    (PR 4's array-backed ``DynamicSimulator`` + template instantiation;
    the dict engine measures ~73k on the same scenario)
  * serve_sim 10k, speculative leap    >= 7000 req/wall-s
    (a ``decode_stable``-only scheduler: every decode fusion takes the
    snapshot/rollback path; these policies ran per-step before PR 4)

Exit code 0 on pass, 1 on any floor violation.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FLOORS = {
    "fifo_static_warm_events_per_sec": 170_000.0,
    "shared_3200_tasks_per_sec": 60_000.0,
    "shared_flatness_6400_over_200": 0.3,
    "serve_sim_requests_per_sec": 6_400.0,
    "dynamic_injection_fast_events_per_sec": 150_000.0,
    "serve_sim_speculative_requests_per_sec": 7_000.0,
}


def main() -> int:
    from benchmarks import bench_engine
    from benchmarks.perf_record import (_serve_sim_10k,
                                        _serve_sim_10k_speculative)

    measured = {}
    fifo = bench_engine.fifo_events_per_sec()
    measured["fifo_static_warm_events_per_sec"] = fifo["static_warm"]
    shared = bench_engine.shared_tasks_per_sec()
    measured["shared_3200_tasks_per_sec"] = shared["3200"]
    measured["shared_flatness_6400_over_200"] = \
        shared["6400"] / shared["200"]
    measured["dynamic_injection_fast_events_per_sec"] = \
        bench_engine.dynamic_events_per_sec()["fast"]
    serve = _serve_sim_10k()
    measured["serve_sim_requests_per_sec"] = serve["requests_per_sec"]
    spec = _serve_sim_10k_speculative()
    measured["serve_sim_speculative_requests_per_sec"] = \
        spec["requests_per_sec"]

    failed = False
    for key, floor in FLOORS.items():
        got = measured[key]
        status = "ok " if got >= floor else "FAIL"
        if got < floor:
            failed = True
        print(f"[{status}] {key}: {got:,.1f} (floor {floor:,.1f})")
    return 1 if failed else 0


if __name__ == "__main__":
    t0 = time.perf_counter()
    rc = main()
    print(f"perf-smoke finished in {time.perf_counter() - t0:.1f}s -> "
          f"{'FAIL' if rc else 'PASS'}")
    sys.exit(rc)
