# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Figure mapping:
#   Fig 3 -> bench_runtime_breakdown   (flow turn-around time)
#   Fig 4 -> bench_gantt               (resource-occupancy Gantt)
#   Fig 5 -> bench_accuracy            (virtual model vs physical HW)
#   Fig 6/7 -> bench_roofline_vgg      (per-layer roofline, DilatedVGG)
#   assignment roofline table -> bench_roofline_cells (40-cell grid)
#
# ``--json [PATH]`` additionally writes the machine-readable perf record
# (events/sec, points/sec, requests/sec, wall times vs the pre-PR
# baseline) to PATH (default BENCH_pr10.json) — see benchmarks/perf_record;
# ``--trials N`` after the path makes the record a per-metric median over
# N full suite passes.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main(argv) -> None:
    from benchmarks import (bench_accuracy, bench_dse, bench_engine,
                            bench_gantt, bench_roofline_cells,
                            bench_roofline_vgg, bench_runtime_breakdown,
                            bench_serve_sim)

    suites = [
        ("runtime_breakdown", bench_runtime_breakdown),
        ("gantt", bench_gantt),
        ("accuracy", bench_accuracy),
        ("roofline_vgg", bench_roofline_vgg),
        ("roofline_cells", bench_roofline_cells),
        ("engine", bench_engine),
        ("dse", bench_dse),
        ("serve_sim", bench_serve_sim),
    ]
    rows = []
    for name, mod in suites:
        try:
            rows.extend(mod.run())
        except Exception as e:  # keep the harness robust; report failures
            import traceback

            traceback.print_exc()
            rows.append((f"{name}_FAILED", 0.0, str(e)[:120]))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    if "--json" in argv:
        import subprocess

        i = argv.index("--json")
        path = (argv[i + 1] if i + 1 < len(argv)
                and not argv[i + 1].startswith("-") else "BENCH_pr10.json")
        # fresh interpreter: the JAX-heavy suites above leave memory/GC
        # pressure that skews the microbenchmark timings
        script = os.path.join(os.path.dirname(__file__), "perf_record.py")
        cmd = [sys.executable, script, path]
        if "--trials" in argv:
            j = argv.index("--trials")
            cmd += ["--trials", argv[j + 1]]
        subprocess.run(cmd, check=True)
        print(f"\nwrote perf record -> {path}")


if __name__ == "__main__":
    main(sys.argv[1:])
