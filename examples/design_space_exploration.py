"""Design-space exploration with virtual models (paper Fig 1 right path).

Sweeps hardware parameters of a TPU-v5e-class chip for a pod-scale
deepseek-v2 training step and reports which knob actually moves each
bottleneck — the paper's bottom-up + top-down methodology at 256-chip
scale:

  * bottom-up: given these physical annotations, what step time results?
  * top-down: what ICI bandwidth would make the MoE all-to-all disappear
    from the critical path?

Run:  PYTHONPATH=src python examples/design_space_exploration.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.avsm.model import build_avsm
from repro.core.config import LM_SHAPES, get_arch
from repro.core.hw import tpu_v5e_pod
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops


def main():
    spec = get_arch("deepseek-v2-236b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    system = tpu_v5e_pod()
    avsm = build_avsm(ops, system)
    base = avsm.simulate()
    print(f"baseline: {base.summary()}")

    print("\n--- sweep: ICI link bandwidth (MoE all-to-all pressure) ---")
    for bw in (25e9, 50e9, 100e9, 200e9, 400e9):
        rep = avsm.what_if(link_bandwidth=bw).simulate()
        print(f"  ici={bw / 1e9:5.0f} GB/s  step={rep.step_time * 1e3:9.1f} ms"
              f"  ici_util={rep.ici_util:5.1%} nce_util={rep.nce_util:5.1%}")

    print("\n--- sweep: HBM bandwidth ---")
    for bw in (409e9, 819e9, 1638e9, 3276e9):
        rep = avsm.what_if(mem_bandwidth=bw).simulate()
        print(f"  hbm={bw / 1e9:5.0f} GB/s  step={rep.step_time * 1e3:9.1f} ms"
              f"  dma_util={rep.dma_util:5.1%}")

    print("\n--- sweep: MXU peak (compute roof) ---")
    for fl in (99e12, 197e12, 394e12, 788e12):
        rep = avsm.what_if(matrix_flops=fl).simulate()
        print(f"  mxu={fl / 1e12:5.0f} TF/s  step={rep.step_time * 1e3:9.1f} ms"
              f"  nce_util={rep.nce_util:5.1%}")

    print("\n--- top-down: required ICI bw for <5% collective share ---")
    lo, hi = 25e9, 1600e9
    for _ in range(12):
        mid = (lo + hi) / 2
        rep = avsm.what_if(link_bandwidth=mid).simulate()
        share = rep.ici_util
        if share > 0.05:
            lo = mid
        else:
            hi = mid
    print(f"  ~{hi / 1e9:.0f} GB/s per link")


if __name__ == "__main__":
    main()
