"""Design-space exploration with virtual models (paper Fig 1 right path).

Sweeps hardware parameters of a TPU-v5e-class chip for a pod-scale
deepseek-v2 training step through the :class:`DesignSpaceExplorer`:

  * bottom-up: given these physical annotations, what step time results?
    Every sweep point re-annotates the cached task graph (O(n_tasks))
    instead of recompiling — the paper's click-of-a-button loop.
  * pruned escalation: chip variants are ranked with the µs-fast roofline
    backend and only the promising ones are confirmed by the causal DES.
  * top-down: what ICI bandwidth would make the MoE all-to-all disappear
    from the critical path?  (bisection over the fast what-if path)

Run:  PYTHONPATH=src python examples/design_space_exploration.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.avsm.model import AVSM, annotate_system
from repro.core.config import LM_SHAPES, get_arch
from repro.core.dse import DesignSpaceExplorer
from repro.core.hw import tpu_v5e_pod
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops


def main():
    spec = get_arch("deepseek-v2-236b")
    ops = lm_step_ops(spec.model, LM_SHAPES["train_4k"], ShardPlan())
    base = tpu_v5e_pod()
    dse = DesignSpaceExplorer({"deepseek_train": ops})

    rep = AVSM(system=base, graph=dse.compiled("deepseek_train", base)) \
        .simulate()
    print(f"baseline: {rep.summary()}")

    print("\n--- sweep: ICI link bandwidth (MoE all-to-all pressure) ---")
    for bw, r in dse.what_if_sweep("deepseek_train", base, "link_bandwidth",
                                   (25e9, 50e9, 100e9, 200e9, 400e9)):
        print(f"  ici={bw / 1e9:5.0f} GB/s  step={r.step_time * 1e3:9.1f} ms"
              f"  ici_util={r.ici_util:5.1%} nce_util={r.nce_util:5.1%}")

    print("\n--- sweep: HBM bandwidth ---")
    for bw, r in dse.what_if_sweep("deepseek_train", base, "mem_bandwidth",
                                   (409e9, 819e9, 1638e9, 3276e9)):
        print(f"  hbm={bw / 1e9:5.0f} GB/s  step={r.step_time * 1e3:9.1f} ms"
              f"  dma_util={r.dma_util:5.1%}")

    print("\n--- sweep: MXU peak (compute roof) ---")
    for fl, r in dse.what_if_sweep("deepseek_train", base, "matrix_flops",
                                   (99e12, 197e12, 394e12, 788e12)):
        print(f"  mxu={fl / 1e12:5.0f} TF/s  step={r.step_time * 1e3:9.1f} ms"
              f"  nce_util={r.nce_util:5.1%}")

    print("\n--- chip variants: roofline-prune -> DES-confirm ---")
    variants = {
        "v5e": base,
        "2x_ici": annotate_system(base, link_bandwidth=100e9),
        "2x_hbm": annotate_system(base, mem_bandwidth=1638e9),
        "2x_mxu": annotate_system(base, matrix_flops=394e12),
        "2x_all": annotate_system(base, link_bandwidth=100e9,
                                  mem_bandwidth=1638e9, matrix_flops=394e12),
    }
    t0 = time.perf_counter()
    confirmed = dse.explore(variants, keep=3)
    wall = time.perf_counter() - t0
    for r in confirmed:
        print(f"  {r.system:8s} roofline={r.report.step_time * 1e3:8.1f} ms"
              f"  des={r.confirmed.step_time * 1e3:8.1f} ms")
    print(f"  ({len(variants)} points, {dse.stats['compiles']} compiles, "
          f"{dse.stats['reannotations']} re-annotations, {wall:.1f}s)")

    print("\n--- top-down: required ICI bw for <5% collective share ---")
    avsm = AVSM(system=base, graph=dse.compiled("deepseek_train", base))
    lo, hi = 25e9, 1600e9
    for _ in range(12):
        mid = (lo + hi) / 2
        share = avsm.what_if(link_bandwidth=mid).simulate().ici_util
        if share > 0.05:
            lo = mid
        else:
            hi = mid
    print(f"  ~{hi / 1e9:.0f} GB/s per link")


if __name__ == "__main__":
    main()
