"""Quickstart: the paper's whole flow in ~40 lines.

1. Pick a DNN (the paper's DilatedVGG) and a system description file
   (the paper's Virtex-7 NCE prototype).
2. The DL compiler lowers the DNN graph into a hardware-adapted task graph.
3. The model-generation engine builds an executable AVSM.
4. Simulate: end-to-end time, per-layer bounds, Gantt chart.
5. Ask a what-if question without re-compiling ("what if the NCE ran at
   500 MHz?") — the paper's click-of-a-button design-space exploration.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.avsm.model import build_avsm
from repro.core.config import get_arch
from repro.core.hw import virtex7_nce_system
from repro.core.sim.trace import ascii_gantt
from repro.core.taskgraph.builders import convnet_ops


def main():
    # 1. DNN + system description
    dnn = get_arch("dilated-vgg").model
    system = virtex7_nce_system()
    print(f"system: {system.name}, NCE peak "
          f"{system.chip.compute.matrix_flops / 1e12:.2f} TFLOP/s")

    # 2-3. compile to a task graph, generate the AVSM
    ops = convnet_ops(dnn)
    avsm = build_avsm(ops, system)

    # 4. simulate
    report = avsm.simulate()
    print(report.summary())
    print(f"\nper-layer bounds (paper Fig 5/6):")
    for l in sorted(report.layers, key=lambda l: -l.time)[:8]:
        print(f"  {l.name:12s} {l.time * 1e3:9.2f} ms  "
              f"OI={l.intensity:7.1f}  {l.bound}")
    print("\nGantt (paper Fig 4):")
    print(ascii_gantt(report.sim_result, width=80, max_rows=4))

    # 5. what-if: double the multiplier-array clock (250 -> 500 MHz)
    faster = avsm.what_if(
        matrix_flops=system.chip.compute.matrix_flops * 2).simulate()
    print(f"\nwhat-if NCE @500MHz: {report.step_time * 1e3:.1f} ms -> "
          f"{faster.step_time * 1e3:.1f} ms "
          f"({report.step_time / faster.step_time:.2f}x)")
    # compute-bound layers speed up, bandwidth-bound ones do not — the
    # paper's core design insight, quantified before any RTL exists.


if __name__ == "__main__":
    main()
