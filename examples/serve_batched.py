"""Batched serving example (deliverable b): continuous batching with slot
reuse over the decode kernel path.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "qwen1.5-0.5b", "--smoke", "--requests", "8",
                "--slots", "4", "--prompt-len", "12", "--max-new", "24",
                "--max-len", "96"])


if __name__ == "__main__":
    main()
