"""Serving simulation & SLO-aware capacity planning on virtual hardware.

The paper estimates one inference step on a virtual model before any
prototype exists; this example extends that to the ROADMAP's serving
question: *how does a deployment of this chip behave under production
traffic, and how many replicas does the SLO require?* — still entirely on
virtual models.

Three stages:

  1. derive per-request prefill/decode cost models from compiled task
     graphs (``ServingCostModelBuilder``; chip variants re-annotate, they
     do not recompile);
  2. sweep traffic patterns x batching schedulers x systems through
     ``DesignSpaceExplorer.sweep_serving`` and print p99 TTFT/TPOT per
     scenario;
  3. bisect replica count per system for a stated SLO
     (``CapacityPlanner``) and report the smallest feasible deployment.

With ``--num-seeds K`` (K > 1) stages 2b/3 switch to the seed-batched
Monte-Carlo simulator: tail latencies come back as cross-seed mean with a
95% confidence interval, and the capacity bisection only accepts a
configuration whose CI upper bound meets the SLO — one lucky traffic draw
can no longer size the fleet.

With ``--bundle NAME`` the best sweep scenario is re-run instrumented
with a ``repro.obs.Probe`` and a full per-run artifact bundle
(``runs/NAME/``: metrics.json, trace.json, summary.md) is written —
diffable against another run via ``python -m repro.obs.compare``.

Run:  PYTHONPATH=src python examples/serve_capacity_planning.py \
          [--smoke] [--num-seeds K] [--bundle NAME]
"""
import argparse
import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.avsm.model import annotate_system
from repro.core.config import LM_SHAPES, get_arch
from repro.core.dse import DesignSpaceExplorer
from repro.core.hw import SystemDescription, tpu_v5e_chip
from repro.core.sim.trace import serving_chrome_trace
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops
from repro.serve_sim import (SLO, BucketedPrefillScheduler, CapacityPlanner,
                             ClosedLoopWorkload, ContinuousBatchingScheduler,
                             LengthDist, ServingCostModelBuilder,
                             StaticBatchScheduler, bursty_workload,
                             monte_carlo_serving, poisson_workload,
                             poisson_workload_batch, simulate_serving)

ARCH = "qwen1.5-0.5b"
SLOTS = 8


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small request counts (CI)")
    p.add_argument("--num-seeds", type=int, default=1, metavar="K",
                   help="seed-batched Monte-Carlo: K traffic draws per "
                        "estimate, CI-aware capacity planning (default 1)")
    p.add_argument("--bundle", metavar="NAME",
                   help="re-run the best sweep scenario instrumented and "
                        "write a runs/NAME/ observability bundle")
    args = p.parse_args()
    n_req = 300 if args.smoke else 2000
    K = args.num_seeds
    if K < 1:
        p.error("--num-seeds must be >= 1")

    cfg = get_arch(ARCH).model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    systems = {
        "v5e": base,
        "v5e_2x_hbm": annotate_system(base, mem_bandwidth=1638e9),
    }

    print(f"--- per-request cost models ({ARCH}, analytic backend) ---")
    builder = ServingCostModelBuilder(cfg, shard=ShardPlan(data=1, model=1))
    for name, system in systems.items():
        c = builder.model_for(system)
        print(f"  {name:12s} prefill {c.prefill_fixed * 1e3:.2f}ms "
              f"+ {c.prefill_per_token * 1e6:.2f}us/tok   "
              f"decode {c.decode_fixed * 1e3:.2f}ms "
              f"+ {c.decode_per_token * 1e6:.2f}us/slot "
              f"+ {c.decode_per_ctx_token * 1e9:.2f}ns/ctx-tok")
    print(f"  ({builder.stats['compiles']} graph compiles, "
          f"{builder.stats['reannotations']} re-annotations)")

    prompt = LengthDist(mean=512, cv=0.6)
    output = LengthDist(mean=96, cv=0.5)
    traffics = {
        "poisson": lambda: poisson_workload(
            40.0, n_req, prompt=prompt, output=output, seed=0),
        "bursty": lambda: bursty_workload(
            15.0, 90.0, n_req, mean_dwell=5.0, prompt=prompt, output=output,
            seed=0),
        "closed_loop": lambda: ClosedLoopWorkload(
            n_users=24, requests_per_user=max(2, n_req // 24),
            think_time=0.4, prompt=prompt, output=output, seed=0),
    }
    schedulers = {
        "continuous": ContinuousBatchingScheduler,
        "bucketed": lambda: BucketedPrefillScheduler(bucket=128),
        "static": lambda: StaticBatchScheduler(batch_size=SLOTS,
                                               max_wait=0.25),
    }

    print(f"\n--- serving sweep: {len(systems)} systems x {len(traffics)} "
          f"traffic patterns x {len(schedulers)} schedulers "
          f"({n_req} requests each, 2 replicas x {SLOTS} slots) ---")
    dse = DesignSpaceExplorer({
        "decode": lm_step_ops(cfg, LM_SHAPES["decode_32k"],
                              ShardPlan(data=1, model=1))})
    t0 = time.perf_counter()
    results = dse.sweep_serving(systems, traffics, schedulers,
                                cost_builder=builder, replicas=2,
                                slots=SLOTS)
    wall = time.perf_counter() - t0
    print(f"  {'system':12s} {'traffic':12s} {'scheduler':11s} "
          f"{'p99 TTFT':>10s} {'p99 TPOT':>10s} {'req/s':>7s} {'util':>6s}")
    for r in results:
        rep = r.report
        print(f"  {r.system:12s} {r.traffic:12s} {r.scheduler:11s} "
              f"{rep.ttft.p99 * 1e3:8.0f}ms {rep.tpot.p99 * 1e3:8.2f}ms "
              f"{rep.throughput_rps:7.1f} {rep.replica_util:6.1%}")
    print(f"  ({len(results)} scenarios in {wall:.1f}s)")

    if K > 1:
        print(f"\n--- Monte-Carlo serving: {K} seeds x {n_req} requests "
              f"(poisson, continuous batching, 2 replicas x {SLOTS} "
              f"slots) ---")
        batch = poisson_workload_batch(40.0, n_req, prompt=prompt,
                                       output=output, seeds=K)
        t0 = time.perf_counter()
        for name, system in systems.items():
            mc = monte_carlo_serving(builder.model_for(system),
                                     ContinuousBatchingScheduler, batch,
                                     replicas=2, slots=SLOTS)
            t, d = mc.stat("ttft_p99"), mc.stat("tpot_p99")
            print(f"  {name:12s} p99 TTFT {t.mean * 1e3:7.1f}ms "
                  f"+/-{t.half_width * 1e3:5.1f}ms   "
                  f"p99 TPOT {d.mean * 1e3:6.2f}ms "
                  f"+/-{d.half_width * 1e3:4.2f}ms   (95% CI)")
        print(f"  ({K} seeds x {len(systems)} systems in "
              f"{time.perf_counter() - t0:.1f}s; one fused call per "
              f"system, not {K} scalar runs)")

    slo = SLO(ttft_p99=0.75, tpot_p99=0.012)
    mode = (f"CI upper bound over {K} seeds" if K > 1
            else "single seeded draw")
    print(f"\n--- capacity planning: smallest replicas meeting {slo} "
          f"(poisson traffic, continuous batching, {mode}) ---")
    for name, system in systems.items():
        wf = (functools.partial(poisson_workload_batch, 40.0, n_req,
                                prompt=prompt, output=output, seeds=K)
              if K > 1 else traffics["poisson"])
        planner = CapacityPlanner(builder.model_for(system),
                                  ContinuousBatchingScheduler,
                                  wf, slo, num_seeds=K)
        plan = planner.plan(axis="replicas", cap=32, slots=SLOTS)
        rep = plan.report
        status = "meets SLO" if plan.feasible else "infeasible at cap"
        if K > 1:
            t, d = rep.stat("ttft_p99"), rep.stat("tpot_p99")
            print(f"  {name:12s} -> {plan.value} replicas ({status}; "
                  f"p99 TTFT {t.mean * 1e3:.0f}"
                  f"+/-{t.half_width * 1e3:.0f}ms, "
                  f"p99 TPOT {d.mean * 1e3:.2f}"
                  f"+/-{d.half_width * 1e3:.2f}ms, "
                  f"{len(plan.probes)} probes x {K} seeds)")
        else:
            print(f"  {name:12s} -> {plan.value} replicas ({status}; "
                  f"p99 TTFT {rep.ttft.p99 * 1e3:.0f}ms, "
                  f"p99 TPOT {rep.tpot.p99 * 1e3:.2f}ms, "
                  f"{len(plan.probes)} probes)")

    # export one serving timeline for chrome://tracing / Perfetto
    best = results[0]
    out_dir = os.path.join(os.path.dirname(__file__), "..", "runs", "gantt")
    os.makedirs(out_dir, exist_ok=True)
    out = os.path.join(out_dir, "serve_sim.trace.json")
    serving_chrome_trace(best.report, out)
    print(f"\nwrote serving timeline ({best.system}/{best.traffic}/"
          f"{best.scheduler}) to {os.path.relpath(out)}")

    if args.bundle:
        # instrumented re-run of the best scenario -> runs/<name>/ bundle
        from repro.obs import Probe, write_bundle
        probe = Probe(args.bundle, sample_every=64)
        rep = simulate_serving(builder.model_for(systems[best.system]),
                               schedulers[best.scheduler],
                               traffics[best.traffic](),
                               replicas=2, slots=SLOTS, probe=probe)
        bundle = write_bundle(args.bundle, report=rep, probe=probe)
        print(f"wrote observability bundle ({best.system}/{best.traffic}/"
              f"{best.scheduler}) to {os.path.relpath(bundle)}")

    if not args.smoke:
        # scale check: >= 10k requests through the simulator, wall < 10 s
        cost = builder.model_for(base)
        t0 = time.perf_counter()
        rep = simulate_serving(
            cost, ContinuousBatchingScheduler,
            poisson_workload(120.0, 10_000, prompt=prompt, output=output,
                             seed=1),
            replicas=4, slots=SLOTS)
        wall = time.perf_counter() - t0
        print(f"\n10k-request scale check: {rep.n_requests} requests "
              f"({rep.output_tokens} tokens) simulated in {wall:.2f}s wall")


if __name__ == "__main__":
    main()
