"""Cluster resilience: a 3-zone fleet on virtual HW, faults included.

The resilience example hardens a *single* replica pool; production
deployments spread heterogeneous pools across failure domains behind a
routing tier.  This example drives a 3-zone cluster — two zones of the
baseline chip, one zone of a faster variant — through a diurnal traffic
cycle with per-zone outage processes, entirely on virtual models.

Four stages:

  1. router policy comparison: the same chaos scenario under
     round-robin, least-loaded, weighted and session-sticky routing —
     tail latency and failover counts are the discriminator;
  2. the full resilience stack: health-checked rotation (detection lag
     included), circuit breakers, p99-derived hedging and cross-pool
     failover vs the bare router;
  3. fault-aware autoscaling: reactive scale-up (with boot lag) against
     the diurnal cycle, reported as cost (replica-seconds) vs SLO;
  4. N+k redundancy planning: ``ClusterCapacityPlanner.plan_redundancy``
     decides N+1 vs N+2 from CI-conservative cross-seed availability.

A ``runs/<name>/`` observability bundle (counter tracks for rotation,
failovers, hedges; metrics.json) is written for the stage-2 run.

Run:  PYTHONPATH=src python examples/serve_cluster.py [--smoke]
"""
import argparse
import os
import sys
import time
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.config import get_arch
from repro.core.hw import SystemDescription, tpu_v5e_chip
from repro.core.taskgraph.builders import ShardPlan
from repro.obs import Probe, write_bundle
from repro.serve_sim import (SLO, AutoscalerPolicy, CircuitBreakerPolicy,
                             ClusterCapacityPlanner, ClusterSimulator,
                             FailureModel, HealthCheckPolicy, HedgePolicy,
                             ReplicaPool, RetryPolicy, RoundRobinRouter,
                             ServingCostModelBuilder, diurnal_workload,
                             diurnal_workload_batch, make_router)

ARCH = "qwen1.5-0.5b"


def _cost_models():
    cfg = get_arch(ARCH).model
    builder = ServingCostModelBuilder(cfg, shard=ShardPlan(data=1, model=1))
    base = builder.model_for(
        SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=()))
    # a faster chip variant for the heterogeneous zone: 1.6x compute and
    # HBM bandwidth — the kind of what-if the virtual-model flow exists for
    chip = tpu_v5e_chip()
    fast_chip = replace(
        chip, name="v5e_boost",
        compute=replace(chip.compute,
                        matrix_flops=chip.compute.matrix_flops * 1.6,
                        vector_flops=chip.compute.vector_flops * 1.6),
        memory=replace(chip.memory, bandwidth=chip.memory.bandwidth * 1.6))
    fast = builder.model_for(
        SystemDescription(name="v5e_boost", chip=fast_chip, torus=()))
    return base, fast


def _pools(base, fast, replicas):
    # correlated zone outages (a failure takes the whole zone with p=0.5)
    # longer than the retry deadline: stuck requests are *lost*, not just
    # late — that is what health-checked failover protects against
    mk = lambda z, cost: ReplicaPool(
        f"zone-{z}", cost, replicas, slots=8,
        failures=FailureModel(mtbf=25.0, mttr=12.0, seed=20 + ord(z),
                              zone_size=replicas, correlated_p=0.5,
                              horizon=600.0),
        retry=RetryPolicy(max_attempts=4, backoff=0.05, deadline=8.0))
    return [mk("a", base), mk("b", base), mk("c", fast)]


def _traffic(n, seed=0):
    return diurnal_workload(rate_mean=60.0, n_requests=n, period=120.0,
                            amplitude=0.8, seed=seed)


def _row(label, r):
    trips = sum(r.breaker_trips.values())
    print(f"  {label:13s} avail {r.availability:8.3%}   "
          f"p99 e2e {r.e2e.p99 * 1e3:7.0f}ms   "
          f"failovers {r.n_failovers:4d}   hedges {r.hedges_issued:4d}"
          f"/{r.hedges_won:<4d} trips {trips:2d}   "
          f"lost {r.n_lost_total:3d}")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small request counts (CI)")
    p.add_argument("--bundle", default="serve_cluster",
                   help="runs/<name>/ bundle name for the stage-2 run")
    args = p.parse_args()
    n_req = 4_000 if args.smoke else 20_000
    K = 3 if args.smoke else 8
    base, fast = _cost_models()
    reps = 3

    print(f"--- router policies under zone churn ({ARCH}, {n_req} diurnal "
          f"requests, 3 zones x {reps} replicas, correlated zone outages "
          f"MTBF=25s/MTTR=12s) ---")
    for name in ("round_robin", "least_loaded", "weighted", "sticky"):
        r = ClusterSimulator(_pools(base, fast, reps), _traffic(n_req),
                             make_router(name, retry_budget=4),
                             health=HealthCheckPolicy(interval=1.0)).run()
        _row(name, r)
    print("  (heterogeneous pools reward load/weight-aware policies; "
          "sticky trades tail latency for session locality)")

    print("\n--- resilience stack: bare router vs health+breaker+hedge ---")
    bare = ClusterSimulator(_pools(base, fast, reps), _traffic(n_req),
                            RoundRobinRouter(retry_budget=4)).run()
    _row("bare", bare)
    # decimate probe series so the bundle stays a few MB at 20k requests
    probe = Probe("serve_cluster", sample_every=max(1, n_req // 500))
    full = ClusterSimulator(
        _pools(base, fast, reps), _traffic(n_req),
        RoundRobinRouter(retry_budget=4),
        health=HealthCheckPolicy(interval=1.0, unhealthy_after=2),
        breaker=CircuitBreakerPolicy(error_threshold=6, window=10.0,
                                     cooldown=8.0),
        hedge=HedgePolicy(quantile=0.99, min_samples=64, max_fraction=0.05),
        probe=probe).run()
    _row("full_stack", full)
    print("  (health checks re-route around outages after a detection lag; "
          "hedges clip the p99 tail within a 5% duplicate budget)")
    bundle = write_bundle(args.bundle, probe=probe,
                          extra={"cluster": full.summary()})
    print(f"  wrote observability bundle -> {bundle}")

    print("\n--- fault-aware autoscaling over the diurnal cycle ---")
    for label, auto in (("static", None),
                        ("aggressive", AutoscalerPolicy(interval=2.0,
                                                        up_threshold=2.0,
                                                        down_threshold=0.3,
                                                        scale_up_lag=15.0)),
                        ("conservative", AutoscalerPolicy(interval=2.0,
                                                          up_threshold=1.0,
                                                          down_threshold=0.05,
                                                          scale_up_lag=10.0))):
        pools = [ReplicaPool(sp.name, sp.cost, sp.replicas, slots=sp.slots,
                             failures=sp.failures, retry=sp.retry,
                             max_replicas=sp.replicas * 2 if auto else None,
                             cost_rate=1.0)
                 for sp in _pools(base, fast, reps)]
        r = ClusterSimulator(pools, _traffic(n_req),
                             RoundRobinRouter(retry_budget=4),
                             health=HealthCheckPolicy(interval=1.0),
                             autoscaler=auto).run()
        print(f"  {label:12s} p99 e2e {r.e2e.p99 * 1e3:7.0f}ms   "
              f"cost {r.cost:8.0f} replica-s   "
              f"scale events {len(r.scale_events):3d}   "
              f"avail {r.availability:8.3%}")
    print("  (boot lag is what makes reactive scaling lose to faults: "
          "aggressive trough-draining saves replica-seconds but pays the "
          "tail back during ramps and outages)")

    n_plan = 4_000
    slo = SLO(e2e_p99=35.0, availability=0.995)
    print(f"\n--- N+k redundancy: {slo}, {K} seeds, CI-conservative ---")
    t0 = time.perf_counter()
    planner = ClusterCapacityPlanner(
        pools_factory=lambda n: _pools(base, fast, n),
        workload_factory=lambda: diurnal_workload_batch(
            rate_mean=60.0, n_requests=n_plan, period=120.0, amplitude=0.8,
            seeds=K),
        slo=slo, router_factory=RoundRobinRouter, num_seeds=K,
        health=HealthCheckPolicy(interval=1.0))
    plan = planner.plan_redundancy(base=2, extras=(0, 1, 2))
    wall = time.perf_counter() - t0
    print(f"  {plan}")
    if plan.choice is not None:
        a = plan.reports[plan.choice].stat("availability")
        print(f"  chosen N+{plan.choice}: availability CI "
              f"[{a.ci_lo:.3%}, {a.ci_hi:.3%}] over {K} seeds")
    print(f"  ({len(plan.options) * K} cluster-seed simulations "
          f"in {wall:.1f}s)")


if __name__ == "__main__":
    main()
