"""Resilience simulation: serving under replica failures, on virtual HW.

The capacity-planning example answers *how many replicas does the SLO
need?* under ideal hardware.  Production fleets are not ideal: replicas
crash and restart, racks brown out, whole zones fail together.  This
example sizes the same virtual deployment under a seeded fault process —
still with zero prototypes and bit-reproducible results.

Four stages:

  1. inject fault profiles (crash churn, slow brownout, zone-correlated
     outages) into the scalar ``ServingSimulator`` and compare
     availability / goodput / retry amplification / abandonment against
     the fault-free baseline;
  2. add graceful degradation: ``LoadSheddingScheduler`` drops
     low-priority queue overflow during outages instead of letting every
     request blow its deadline;
  3. Monte-Carlo the fault process itself: K seeds draw K independent
     failure schedules (fused fast path), giving availability and
     SLO-under-faults as cross-seed means with 95% CIs;
  4. N+1 planning: bisect replica count against the same SLO with and
     without the fault profile — the gap is the redundancy the churn
     costs you.

Run:  PYTHONPATH=src python examples/serve_resilience.py [--smoke]
"""
import argparse
import functools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.config import get_arch
from repro.core.hw import SystemDescription, tpu_v5e_chip
from repro.core.taskgraph.builders import ShardPlan
from repro.serve_sim import (SLO, CapacityPlanner, ContinuousBatchingScheduler,
                             FailureModel, LengthDist, LoadSheddingScheduler,
                             RetryPolicy, ServingCostModelBuilder,
                             monte_carlo_serving, poisson_workload,
                             poisson_workload_batch, simulate_serving)

ARCH = "qwen1.5-0.5b"
REPLICAS, SLOTS = 4, 8


def _row(name, rep):
    print(f"  {name:14s} avail {rep.availability:7.2%}   "
          f"goodput {rep.goodput_rps:6.1f}/s (offered {rep.attempt_rps:6.1f})"
          f"   p99 e2e {rep.e2e.p99 * 1e3:7.0f}ms   "
          f"fail/retry/aband/shed {rep.n_failures:3d}/{rep.n_retries:4d}/"
          f"{rep.n_abandoned:4d}/{rep.n_shed:4d}")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small request counts (CI)")
    args = p.parse_args()
    n_req = 400 if args.smoke else 3000
    K = 4 if args.smoke else 16

    cfg = get_arch(ARCH).model
    base = SystemDescription(name="v5e_chip", chip=tpu_v5e_chip(), torus=())
    builder = ServingCostModelBuilder(cfg, shard=ShardPlan(data=1, model=1))
    cost = builder.model_for(base)

    prompt = LengthDist(mean=512, cv=0.6)
    output = LengthDist(mean=96, cv=0.5)
    wl = lambda: poisson_workload(60.0, n_req, prompt=prompt, output=output,
                                  seed=0)
    retry = RetryPolicy(max_attempts=4, backoff=0.05, deadline=20.0)
    profiles = {
        "crash_churn": FailureModel(mtbf=8.0, mttr=1.0, seed=7,
                                    horizon=120.0),
        "brownout": FailureModel(mtbf=6.0, mttr=2.0, mode="slow",
                                 slow_factor=4.0, seed=7, horizon=120.0),
        "zone_outage": FailureModel(mtbf=10.0, mttr=1.5, zone_size=2,
                                    correlated_p=0.8, seed=7, horizon=120.0),
    }

    print(f"--- fault profiles vs fault-free baseline ({ARCH}, {n_req} "
          f"requests, {REPLICAS} replicas x {SLOTS} slots, retry "
          f"max_attempts={retry.max_attempts} deadline={retry.deadline}s) "
          f"---")
    _row("fault_free", simulate_serving(cost, ContinuousBatchingScheduler,
                                        wl(), replicas=REPLICAS, slots=SLOTS))
    for name, fm in profiles.items():
        rep = simulate_serving(cost, ContinuousBatchingScheduler, wl(),
                               replicas=REPLICAS, slots=SLOTS, failures=fm,
                               retry=retry)
        _row(name, rep)

    print("\n--- graceful degradation: load shedding during crash churn ---")
    churn = profiles["crash_churn"]
    _row("queue_all", simulate_serving(cost, ContinuousBatchingScheduler,
                                       wl(), replicas=REPLICAS, slots=SLOTS,
                                       failures=churn, retry=retry))
    shed = functools.partial(LoadSheddingScheduler, max_queue=16, shed_to=8)
    _row("shed_overflow", simulate_serving(cost, shed, wl(),
                                           replicas=REPLICAS, slots=SLOTS,
                                           failures=churn, retry=retry))
    print("  (shedding trades completed requests for tail latency: dropped "
          "work never occupies a slot)")

    print(f"\n--- Monte-Carlo failure scenarios: {K} seeds, per-seed "
          f"traffic AND failure draws (fused fast path) ---")
    batch = poisson_workload_batch(60.0, n_req, prompt=prompt, output=output,
                                   seeds=K)
    t0 = time.perf_counter()
    mc = monte_carlo_serving(cost, ContinuousBatchingScheduler, batch,
                             replicas=REPLICAS, slots=SLOTS, failures=churn,
                             retry=retry)
    wall = time.perf_counter() - t0
    for stat in ("availability", "throughput_rps", "abandonment_rate",
                 "e2e_p99"):
        s = mc.stat(stat)
        print(f"  {stat:17s} mean {s.mean:9.4f}   "
              f"95% CI [{s.ci_lo:9.4f}, {s.ci_hi:9.4f}]")
    print(f"  ({K} seeds x {n_req} requests in {wall:.2f}s, one fused call)")

    slo = SLO(e2e_p99=1.2, availability=0.5)
    print(f"\n--- N+1 planning: smallest replicas meeting {slo} "
          f"(CI upper bound over {K} seeds) ---")
    wf = functools.partial(poisson_workload_batch, 60.0, n_req,
                           prompt=prompt, output=output, seeds=K)
    for label, fm in (("clean", None), ("crash_churn", churn)):
        planner = CapacityPlanner(cost, ContinuousBatchingScheduler, wf, slo,
                                  num_seeds=K, failures=fm,
                                  retry=retry if fm else None)
        plan = planner.plan(axis="replicas", cap=16, slots=SLOTS)
        status = "meets SLO" if plan.feasible else "infeasible at cap"
        a = plan.report.stat("availability")
        e = plan.report.stat("e2e_p99")
        print(f"  {label:12s} -> {plan.value} replicas ({status}; "
              f"avail CI lo {a.ci_lo:.2%}, p99 e2e CI hi "
              f"{e.ci_hi * 1e3:.0f}ms)")
    print("  (the replica gap is the redundancy the fault process costs)")


if __name__ == "__main__":
    main()
