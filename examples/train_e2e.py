"""End-to-end training driver (deliverable b): train a ~100M-param model
for a few hundred steps on the synthetic pipeline, with checkpointing and
a mid-run simulated failure + restart (fault-tolerance demonstration).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Note: this container is a single CPU core — the default model here is a
~10M-param qwen1.5-family config so the example finishes in minutes; pass
--full100m for the ~100M-param variant (same code path, longer wall time).
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.config import AttentionConfig, ModelConfig, OptimizerConfig
from repro.launch.train import main as train_main


def model_100m():
    return ModelConfig(
        name="repro-100m", family="dense", num_layers=12, d_model=768,
        d_ff=2048, vocab_size=32768,
        attention=AttentionConfig(num_heads=12, num_kv_heads=4, head_dim=64),
        act="swiglu", param_dtype="float32", compute_dtype="float32")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--full100m", action="store_true")
    p.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = p.parse_args()

    # register a custom config on the fly through the registry
    from repro.core import config as C
    from repro.models import api

    if args.full100m:
        cfg = model_100m()
    else:
        cfg = dataclasses.replace(
            model_100m(), num_layers=4, d_model=256, d_ff=768,
            vocab_size=4096,
            attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                      head_dim=64))

    @C.register_arch("repro-e2e")
    def _spec():
        return C.ArchSpec(arch_id="repro-e2e", model=cfg, smoke=cfg,
                          shapes=())

    print(f"training {cfg.name}: {api.param_count(cfg):,} params")
    half = args.steps // 2
    # phase 1: train to the midpoint, checkpointing
    losses1 = train_main(["--arch", "repro-e2e", "--smoke",
                          "--steps", str(half), "--batch", "8",
                          "--seq", "256", "--ckpt-every", "50",
                          "--ckpt-dir", args.ckpt, "--log-every", "25"])
    print(f"\n--- simulated node failure at step {half}; "
          f"restarting from checkpoint ---\n")
    # phase 2: a 'new process' resumes from the latest checkpoint
    losses2 = train_main(["--arch", "repro-e2e", "--smoke",
                          "--steps", str(args.steps), "--batch", "8",
                          "--seq", "256", "--ckpt-every", "50",
                          "--ckpt-dir", args.ckpt, "--resume",
                          "--log-every", "25"])
    print(f"\nloss trajectory: {losses1[0]:.3f} -> {losses1[-1]:.3f} "
          f"(failure) -> {losses2[-1]:.3f}")
    assert losses2[-1] < losses1[0], "model did not learn"
    print("OK: survived failure, loss decreased end-to-end")


if __name__ == "__main__":
    main()
