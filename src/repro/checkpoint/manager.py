"""Distributed checkpointing: sharded npz + JSON manifest, atomic commit,
async writer, auto-resume, elastic reshard-on-restore.

Layout:
  <dir>/step_000123/
      manifest.json        (step, tree structure, shapes, dtypes, mesh)
      shard_<host>.npz     (this host's param/opt leaves, flattened keys)
  <dir>/LATEST             (atomic pointer file -> "step_000123")

Fault-tolerance contract:
  * a checkpoint directory is visible in LATEST only after all shards are
    fully written and fsync'd (write-to-temp + atomic rename);
  * restore accepts a *different* device count / mesh than the writer
    (elastic scaling): leaves are saved unsharded per-host (host 0 in this
    single-process container) and resharded on load via the current rules.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict] = None) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()                       # one outstanding write at a time
        host_state = jax.tree.map(np.asarray, state)   # device -> host copy
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, extra or {})

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_state, extra: Dict) -> None:
        try:
            name = f"step_{step:09d}"
            final_dir = os.path.join(self.directory, name)
            tmp_dir = tempfile.mkdtemp(prefix=f".{name}.",
                                       dir=self.directory)
            flat = _flatten(host_state)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": {k: {"shape": list(np.shape(v)),
                               "dtype": str(np.asarray(v).dtype)}
                           for k, v in flat.items()},
                "extra": extra,
            }
            np.savez(os.path.join(tmp_dir, "shard_0.npz"),
                     **{k.replace("/", "|"): np.asarray(v)
                        for k, v in flat.items()})
            with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.rename(tmp_dir, final_dir)                  # atomic commit
            self._write_latest(name)
            self._gc()
        except BaseException as e:        # surfaced on next wait()
            self._error = e

    def _write_latest(self, name: str) -> None:
        tmp = os.path.join(self.directory, ".LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.directory, "LATEST"))

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.directory, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Dict[str, Any]]:
        """Load a checkpoint; reshard onto `shardings` if given (elastic)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        name = f"step_{step:09d}"
        d = os.path.join(self.directory, name)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "shard_0.npz")) as z:
            flat = {k.replace("|", "/"): z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return manifest["step"], tree
