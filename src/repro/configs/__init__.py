"""Assigned architecture configs.  Importing this package registers every
architecture with repro.core.config's registry (``--arch <id>``)."""
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    dilated_vgg,
    granite_moe_1b_a400m,
    internvl2_2b,
    jamba_1_5_large_398b,
    minitron_8b,
    mistral_large_123b,
    qwen1_5_0_5b,
    qwen2_5_14b,
    rwkv6_1_6b,
    seamless_m4t_large_v2,
)
