"""deepseek-v2-236b [arXiv:2405.04434; hf].

60L d_model=5120 128H MLA (kv_lora=512) d_ff=1536/expert vocab=102400,
MoE 2 shared + 160 routed top-6; first layer dense (d_ff 12288).
"""
from repro.core.config import (ArchSpec, AttentionConfig, MoEConfig,
                               ModelConfig, register_arch)

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    d_ff=12288,
    vocab_size=102_400,
    attention=AttentionConfig(
        kind="mla", num_heads=128, num_kv_heads=128, head_dim=128,
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        rope_theta=10_000.0),
    moe=MoEConfig(num_experts=160, num_experts_per_tok=6,
                  num_shared_experts=2, d_ff_expert=1536, d_ff_shared=3072,
                  first_k_dense=1, d_ff_dense=12288),
    act="swiglu",
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(
        kind="mla", num_heads=4, num_kv_heads=4, head_dim=32,
        q_lora_rank=32, kv_lora_rank=32,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                  num_shared_experts=1, d_ff_expert=32, d_ff_shared=32,
                  first_k_dense=1, d_ff_dense=128),
    act="swiglu",
)


@register_arch("deepseek-v2-236b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepseek-v2-236b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="MLA compresses the cache but attention is still full "
                    "(quadratic); long_500k skipped per assignment rule",
        source="arXiv:2405.04434",
    )
