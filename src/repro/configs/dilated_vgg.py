"""DilatedVGG — the paper's evaluation workload (Yu & Koltun 2015 [6],
'slightly modified' per the paper).  VGG-16 front end with dilation in the
later stages instead of pooling, a Dense1 1x1 stage and bilinear Upscaling —
layer names follow the paper's Figures 5-7 (conv1_1 ... conv4_5, Dense1,
Upscaling).  Used by the AVSM validation benchmarks (not part of the 40
LM cells).
"""
from repro.core.config import (ArchSpec, ConvLayerConfig as C,
                               ConvNetConfig, ModelConfig, register_arch)


def _layers():
    # (name, kind, in_ch, out_ch, kernel, stride, dilation)
    spec = [
        ("conv1_0", "conv", 3, 64, 3, 1, 1),
        ("conv1_1", "conv", 64, 64, 3, 1, 1),
        ("pool1", "pool", 64, 64, 2, 2, 1),
        ("conv2_0", "conv", 64, 128, 3, 1, 1),
        ("conv2_1", "conv", 128, 128, 3, 1, 1),
        ("pool2", "pool", 128, 128, 2, 2, 1),
        ("conv3_0", "conv", 128, 256, 3, 1, 1),
        ("conv3_1", "conv", 256, 256, 3, 1, 1),
        ("conv3_2", "conv", 256, 256, 3, 1, 1),
        ("pool3", "pool", 256, 256, 2, 2, 1),
        # dilated stage: pooling removed, dilation grows (paper's Conv4_0-4_5)
        ("conv4_0", "conv", 256, 512, 3, 1, 1),
        ("conv4_1", "conv", 512, 512, 3, 1, 1),
        ("conv4_2", "conv", 512, 512, 3, 1, 2),
        ("conv4_3", "conv", 512, 512, 3, 1, 2),
        ("conv4_4", "conv", 512, 512, 3, 1, 4),
        ("conv4_5", "conv", 512, 512, 3, 1, 4),
        ("dense1", "dense", 512, 1024, 1, 1, 1),
        ("dense2", "dense", 1024, 19, 1, 1, 1),
        ("upscaling", "upsample", 19, 19, 8, 8, 1),
    ]
    return tuple(C(name=n, kind=k, in_ch=i, out_ch=o, kernel=ks, stride=s,
                   dilation=d) for n, k, i, o, ks, s, d in spec)


FULL = ModelConfig(
    name="dilated-vgg",
    family="convnet",
    convnet=ConvNetConfig(layers=_layers(), in_hw=(1024, 2048), in_ch=3,
                          num_classes=19),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="dilated-vgg-smoke",
    family="convnet",
    convnet=ConvNetConfig(layers=_layers(), in_hw=(64, 128), in_ch=3,
                          num_classes=19),
)


@register_arch("dilated-vgg")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dilated-vgg",
        model=FULL,
        smoke=SMOKE,
        shapes=(),          # paper-validation workload, not an LM cell
        source="arXiv:1511.07122 via the paper's FPGA prototype [4]",
    )
