"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
"""
from repro.core.config import (ArchSpec, AttentionConfig, MoEConfig,
                               ModelConfig, register_arch)

FULL = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    d_ff=512,
    vocab_size=49155,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=8,
                              head_dim=64, rope_theta=10_000.0),
    moe=MoEConfig(num_experts=32, num_experts_per_tok=8, d_ff_expert=512),
    act="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    d_ff=64,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16),
    moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_ff_expert=64),
    act="swiglu",
    tie_embeddings=True,
)


@register_arch("granite-moe-1b-a400m")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-moe-1b-a400m",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch: long_500k needs sub-quadratic "
                    "attention (assignment rule)",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
