"""internvl2-2b [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT
frontend is a STUB: ``input_specs`` provides precomputed patch embeddings
(1024 prefix positions) per the assignment; only the LM backbone runs.
"""
from repro.core.config import (ArchSpec, AttentionConfig, FrontendConfig,
                               ModelConfig, register_arch)

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92_553,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=8,
                              head_dim=128, rope_theta=1_000_000.0),
    frontend=FrontendConfig(kind="patch", num_prefix=1024),
    act="swiglu",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16),
    frontend=FrontendConfig(kind="patch", num_prefix=8),
    act="swiglu",
)


@register_arch("internvl2-2b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="internvl2-2b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch (assignment rule)",
        source="arXiv:2404.16821",
    )
