"""jamba-1.5-large-398b [arXiv:2403.19887; hf].

72L d_model=8192; Mamba:attention 7:1 interleave (one attention layer per
8, at offset 4), MoE 16e top-2 on every 2nd layer (offset 1); GQA kv=8,
d_ff=24576; vocab=65536.  398B total / ~94B active.
"""
from repro.core.config import (ArchSpec, AttentionConfig, MoEConfig,
                               ModelConfig, SSMConfig, register_arch)

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    d_ff=24_576,
    vocab_size=65_536,
    attention=AttentionConfig(kind="gqa", num_heads=64, num_kv_heads=8,
                              head_dim=128),
    moe=MoEConfig(num_experts=16, num_experts_per_tok=2, d_ff_expert=24_576,
                  moe_every=2, moe_offset=1, d_ff_dense=24_576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    act="swiglu",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,                      # one full period: attn@4, MoE on odds
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16),
    moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_ff_expert=128,
                  moe_every=2, moe_offset=1, d_ff_dense=128),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    attn_every=8,
    act="swiglu",
)


@register_arch("jamba-1.5-large-398b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="jamba-1.5-large-398b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        source="arXiv:2403.19887",
    )
