"""minitron-8b (pruned Nemotron-4) [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000; squared-ReLU FFN
(Nemotron family), untied embeddings.
"""
from repro.core.config import (ArchSpec, AttentionConfig, ModelConfig,
                               register_arch)

FULL = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=16_384,
    vocab_size=256_000,
    attention=AttentionConfig(kind="gqa", num_heads=32, num_kv_heads=8,
                              head_dim=128, rope_theta=10_000.0),
    act="relu2",
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=256,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16),
    act="relu2",
    norm="layernorm",
)


@register_arch("minitron-8b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="minitron-8b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch (assignment rule)",
        source="arXiv:2407.14679",
    )
