"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.core.config import (ArchSpec, AttentionConfig, ModelConfig,
                               register_arch)

FULL = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12_288,
    d_ff=28_672,
    vocab_size=32_768,
    attention=AttentionConfig(kind="gqa", num_heads=96, num_kv_heads=8,
                              head_dim=128, rope_theta=1_000_000.0),
    act="swiglu",
)

SMOKE = ModelConfig(
    name="mistral-large-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16),
    act="swiglu",
)


@register_arch("mistral-large-123b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mistral-large-123b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch (assignment rule)",
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
