"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=2816 vocab=151936, QKV bias,
tied embeddings.
"""
from repro.core.config import (ArchSpec, AttentionConfig, ModelConfig,
                               register_arch)

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    d_ff=2816,
    vocab_size=151_936,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=64, qkv_bias=True),
    act="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=4,
                              head_dim=16, qkv_bias=True),
    act="swiglu",
    tie_embeddings=True,
)


@register_arch("qwen1.5-0.5b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen1.5-0.5b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch (assignment rule)",
        source="hf:Qwen/Qwen1.5-0.5B",
    )
