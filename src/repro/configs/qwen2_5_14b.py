"""qwen2.5-14b [hf:Qwen/Qwen2.5 family; hf].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias.
"""
from repro.core.config import (ArchSpec, AttentionConfig, ModelConfig,
                               register_arch)

FULL = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    d_ff=13_824,
    vocab_size=152_064,
    attention=AttentionConfig(kind="gqa", num_heads=40, num_kv_heads=8,
                              head_dim=128, qkv_bias=True,
                              rope_theta=1_000_000.0),
    act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2,
                              head_dim=16, qkv_bias=True),
    act="swiglu",
)


@register_arch("qwen2.5-14b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen2.5-14b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="pure full-attention arch (assignment rule)",
        source="hf:Qwen/Qwen2.5-14B",
    )
