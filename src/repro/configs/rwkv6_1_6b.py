"""rwkv6-1.6b "Finch" [arXiv:2404.05892; unverified].

24L d_model=2048 attention-free (data-dependent decay) d_ff=7168 vocab=65536.
"""
from repro.core.config import (ArchSpec, ModelConfig, RWKVConfig,
                               register_arch)

FULL = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    d_ff=7168,
    vocab_size=65_536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8),
    norm="layernorm",
)


@register_arch("rwkv6-1.6b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="rwkv6-1.6b",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
        source="arXiv:2404.05892",
    )
