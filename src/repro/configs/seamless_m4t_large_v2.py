"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — encoder-decoder, audio.

24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16) d_ff=8192
vocab=256206.  The speech frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings to the encoder (assignment rule: backbone only).
Shape cells split seq_len as S/2 encoder frames + S/2 decoder tokens.
"""
from repro.core.config import (ArchSpec, AttentionConfig, FrontendConfig,
                               ModelConfig, register_arch)

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    d_ff=8192,
    vocab_size=256_206,
    attention=AttentionConfig(kind="gqa", num_heads=16, num_kv_heads=16,
                              head_dim=64),
    frontend=FrontendConfig(kind="frames", num_prefix=0),
    act="gelu",
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=512,
    attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=4,
                              head_dim=16),
    frontend=FrontendConfig(kind="frames", num_prefix=0),
    act="gelu",
    norm="layernorm",
)


@register_arch("seamless-m4t-large-v2")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="seamless-m4t-large-v2",
        model=FULL,
        smoke=SMOKE,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_shapes=("long_500k",),
        skip_reason="full-attention enc-dec (assignment rule)",
        source="arXiv:2308.11596",
    )
