"""AVSM: Abstract Virtual System Model — the paper's core artifact.

AVSM = virtual hardware models (SystemDescription) + hardware-adapted task
graph (compiled LayerOps), executable by the DES.  The model-generation
engine (`build_avsm`) is the analog of the paper's SystemC generation; the
what-if API re-annotates physical parameters (frequency, bandwidths) and
regenerates without re-deriving the task graph — the paper's
"click-of-a-button" design-space exploration.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.hw import SystemDescription
from repro.core.sim.engine import SimResult, Simulator
from repro.core.taskgraph.compiler import CompiledGraph, CompilePlan, compile_ops
from repro.core.taskgraph.ops import LayerOp


@dataclass
class LayerReport:
    name: str
    time: float                  # seconds (span in the schedule)
    flops: float
    hbm_bytes: float
    coll_bytes: float
    intensity: float             # flops / hbm byte
    achieved_flops: float        # flops / time
    bound: str                   # compute | memory | collective | latency


@dataclass
class AVSMReport:
    system: str
    step_time: float             # seconds end-to-end
    nce_util: float
    dma_util: float
    ici_util: float
    layers: List[LayerReport]
    build_seconds: float
    sim_seconds: float
    n_tasks: int
    sim_result: Optional[SimResult] = None

    def summary(self) -> str:
        lines = [
            f"AVSM[{self.system}] step={self.step_time * 1e3:.3f} ms  "
            f"tasks={self.n_tasks}  build={self.build_seconds:.2f}s "
            f"sim={self.sim_seconds:.2f}s",
            f"  utilization: nce={self.nce_util:.1%} dma={self.dma_util:.1%} "
            f"ici={self.ici_util:.1%}",
        ]
        return "\n".join(lines)


@dataclass
class AVSM:
    system: SystemDescription
    graph: CompiledGraph
    build_seconds: float = 0.0

    def simulate(self) -> AVSMReport:
        t0 = time.perf_counter()
        sim = Simulator(self.graph.tasks)
        result = sim.run()
        sim_s = time.perf_counter() - t0

        chip = self.system.chip
        # per-layer roofline classification
        per_layer: Dict[str, Dict[str, float]] = {}
        for op in self.graph.ops:
            d = per_layer.setdefault(op.layer, {"flops": 0.0, "bytes": 0.0,
                                                "coll": 0.0})
            if op.coll is not None:
                d["coll"] += op.coll.payload
            else:
                d["flops"] += op.flops
                d["bytes"] += op.total_bytes
        durations = result.layer_durations()
        layers = []
        peak = chip.compute.matrix_flops
        bw = chip.memory.bandwidth
        for name, vals in per_layer.items():
            t = durations.get(name, 0.0)
            t_c = vals["flops"] / peak
            t_m = vals["bytes"] / bw
            t_i = vals["coll"] / max(chip.link.bandwidth, 1.0)
            dominant = max(("compute", t_c), ("memory", t_m),
                           ("collective", t_i), key=lambda kv: kv[1])
            bound = dominant[0]
            if t > 0 and max(t_c, t_m, t_i) < 0.5 * t:
                bound = "latency"
            layers.append(LayerReport(
                name=name, time=t, flops=vals["flops"],
                hbm_bytes=vals["bytes"], coll_bytes=vals["coll"],
                intensity=vals["flops"] / max(vals["bytes"], 1.0),
                achieved_flops=vals["flops"] / t if t > 0 else 0.0,
                bound=bound))

        def util(prefix: str) -> float:
            busy = sum(v for k, v in result.resource_busy.items()
                       if k.startswith(prefix))
            n = max(1, len([k for k in result.resource_busy
                            if k.startswith(prefix)]))
            return busy / (n * result.makespan) if result.makespan else 0.0

        return AVSMReport(
            system=self.system.name, step_time=result.makespan,
            nce_util=util("nce"), dma_util=util("dma"), ici_util=util("ici"),
            layers=layers, build_seconds=self.build_seconds,
            sim_seconds=sim_s, n_tasks=len(self.graph.tasks),
            sim_result=result)

    def what_if(self, **annotations) -> "AVSM":
        """Re-annotate physical parameters and regenerate the model.

        Supported keys: matrix_flops, vector_flops, mem_bandwidth, link_bandwidth,
        vmem_capacity, launch_overhead, num_dma_engines — the paper's top-down
        requirement assessment ("what NCE frequency meets the target?").
        """
        chip = self.system.chip
        compute = dataclasses.replace(
            chip.compute,
            matrix_flops=annotations.get("matrix_flops",
                                         chip.compute.matrix_flops),
            vector_flops=annotations.get("vector_flops",
                                         chip.compute.vector_flops),
            launch_overhead=annotations.get("launch_overhead",
                                            chip.compute.launch_overhead))
        memory = dataclasses.replace(
            chip.memory,
            bandwidth=annotations.get("mem_bandwidth", chip.memory.bandwidth),
            num_dma_engines=annotations.get("num_dma_engines",
                                            chip.memory.num_dma_engines))
        onchip = dataclasses.replace(
            chip.onchip,
            capacity=annotations.get("vmem_capacity", chip.onchip.capacity))
        link = dataclasses.replace(
            chip.link,
            bandwidth=annotations.get("link_bandwidth", chip.link.bandwidth))
        new_sys = dataclasses.replace(
            self.system,
            chip=dataclasses.replace(chip, compute=compute, memory=memory,
                                     onchip=onchip, link=link))
        return build_avsm(self.graph.ops, new_sys, self.graph.plan)


def build_avsm(ops: List[LayerOp], system: SystemDescription,
               plan: Optional[CompilePlan] = None) -> AVSM:
    """Model-generation engine: description + task graph -> executable model."""
    t0 = time.perf_counter()
    graph = compile_ops(ops, system, plan)
    return AVSM(system=system, graph=graph,
                build_seconds=time.perf_counter() - t0)
