"""AVSM: Abstract Virtual System Model — the paper's core artifact.

AVSM = virtual hardware models (SystemDescription) + hardware-adapted task
graph (compiled LayerOps), executable by any registered estimator backend
(`repro.core.estimator`): ``roofline`` (closed-form), ``analytic`` (per-op
stacking), ``des`` (causal simulation).  The model-generation engine
(``build_avsm``) is the analog of the paper's SystemC generation; the
what-if API re-annotates physical parameters (frequency, bandwidths) and
rescales the existing task graph in O(n_tasks) — without re-tiling or
recompiling — the paper's "click-of-a-button" design-space exploration.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional

from repro.core.estimator import EstimateReport, LayerReport, get_backend
from repro.core.hw import SystemDescription
from repro.core.taskgraph.compiler import (CompiledGraph, CompilePlan,
                                           compile_ops, reannotate)
from repro.core.taskgraph.ops import LayerOp

# AVSMReport is a view over the common estimator report: the DES backend
# fills every field (including the SimResult for Gantt export); cheaper
# backends fill the shared subset.  Kept as an alias for callers written
# against the pre-estimator API.
AVSMReport = EstimateReport

__all__ = ["AVSM", "AVSMReport", "EstimateReport", "LayerReport",
           "annotate_system", "build_avsm"]

# what-if keys that only change service rates/latencies: handled by
# re-annotating the existing task graph.  Keys outside this set (on-chip
# capacity, alignment) change the tiling and force a recompile.
_RATE_KEYS = frozenset({
    "matrix_flops", "vector_flops", "launch_overhead", "mem_bandwidth",
    "mem_latency", "link_bandwidth", "link_latency", "num_dma_engines",
    "num_links", "dcn_bandwidth", "dcn_latency",
})


def annotate_system(system: SystemDescription,
                    **annotations) -> SystemDescription:
    """Replace physical annotations (``_RATE_KEYS`` + ``vmem_capacity``) on
    a system description — the shared builder for what-if variants."""
    unknown = set(annotations) - _RATE_KEYS - {"vmem_capacity"}
    if unknown:
        raise KeyError(f"unknown what-if keys: {sorted(unknown)}")
    chip = system.chip
    compute = dataclasses.replace(
        chip.compute,
        matrix_flops=annotations.get("matrix_flops",
                                     chip.compute.matrix_flops),
        vector_flops=annotations.get("vector_flops",
                                     chip.compute.vector_flops),
        launch_overhead=annotations.get("launch_overhead",
                                        chip.compute.launch_overhead))
    memory = dataclasses.replace(
        chip.memory,
        bandwidth=annotations.get("mem_bandwidth", chip.memory.bandwidth),
        latency=annotations.get("mem_latency", chip.memory.latency),
        num_dma_engines=annotations.get("num_dma_engines",
                                        chip.memory.num_dma_engines))
    onchip = dataclasses.replace(
        chip.onchip,
        capacity=annotations.get("vmem_capacity", chip.onchip.capacity))
    link = dataclasses.replace(
        chip.link,
        bandwidth=annotations.get("link_bandwidth", chip.link.bandwidth),
        latency=annotations.get("link_latency", chip.link.latency))
    return dataclasses.replace(
        system,
        chip=dataclasses.replace(
            chip, compute=compute, memory=memory, onchip=onchip, link=link,
            num_links=annotations.get("num_links", chip.num_links)),
        dcn_bandwidth=annotations.get("dcn_bandwidth", system.dcn_bandwidth),
        dcn_latency=annotations.get("dcn_latency", system.dcn_latency))


@dataclass
class AVSM:
    system: SystemDescription
    graph: CompiledGraph
    build_seconds: float = 0.0

    def estimate(self, backend: str = "des") -> EstimateReport:
        """Run a registered estimator backend on the compiled graph.

        ``backend``: ``roofline`` (closed-form bound), ``analytic``
        (per-op latency stacking) or ``des`` (causal simulation).
        """
        return get_backend(backend).estimate(
            self.graph, build_seconds=self.build_seconds)

    def simulate(self) -> AVSMReport:
        """Highest-fidelity estimate (the DES backend)."""
        return self.estimate("des")

    def what_if(self, **annotations) -> "AVSM":
        """Re-annotate physical parameters and regenerate the model.

        Supported keys: matrix_flops, vector_flops, launch_overhead,
        mem_bandwidth, mem_latency, link_bandwidth, link_latency,
        num_dma_engines, num_links, dcn_bandwidth, dcn_latency,
        vmem_capacity — the paper's top-down requirement assessment
        ("what NCE frequency meets the target?").

        Rate/latency/resource-count keys take the fast path: the existing
        tiling is kept and task durations are rescaled in O(n_tasks).
        ``vmem_capacity`` changes the tiling, so it falls back to a full
        recompile.
        """
        new_sys = annotate_system(self.system, **annotations)
        if set(annotations) <= _RATE_KEYS:
            t0 = time.perf_counter()
            graph = reannotate(self.graph, new_sys)
            return AVSM(system=new_sys, graph=graph,
                        build_seconds=time.perf_counter() - t0)
        return build_avsm(self.graph.ops, new_sys, self.graph.plan)


def build_avsm(ops: List[LayerOp], system: SystemDescription,
               plan: Optional[CompilePlan] = None) -> AVSM:
    """Model-generation engine: description + task graph -> executable model."""
    t0 = time.perf_counter()
    graph = compile_ops(ops, system, plan)
    return AVSM(system=system, graph=graph,
                build_seconds=time.perf_counter() - t0)
