"""Configuration system for the repro framework.

Plain dataclasses (JSON-loadable via ``dacite``) describing models, training,
serving, meshes and input shapes.  Every assigned architecture registers a
``ModelConfig`` through :func:`register_arch`; launchers select them with
``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Attention / FFN / family-specific blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head attention description (GQA or MLA)."""

    kind: str = "gqa"  # "gqa" | "mla"
    num_heads: int = 16
    num_kv_heads: int = 16
    head_dim: int = 64
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # MLA (DeepSeek-V2) parameters; only read when kind == "mla".
    q_lora_rank: int = 0          # 0 => no query compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_cache_dim_per_token(self) -> int:
        """Bytes-free cache width per token per layer (element count)."""
        if self.kind == "mla":
            # compressed kv latent + decoupled rope key
            return self.kv_lora_rank + self.qk_rope_head_dim
        return 2 * self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Token-choice top-k mixture-of-experts FFN."""

    num_experts: int = 8
    num_experts_per_tok: int = 2
    num_shared_experts: int = 0
    d_ff_expert: int = 512          # hidden dim of each routed expert
    d_ff_shared: int = 0            # hidden dim of the shared expert(s)
    moe_every: int = 1              # MoE FFN every k-th layer (others dense)
    moe_offset: int = 0             # phase of the MoE layers within the period
    first_k_dense: int = 0          # first k layers use a dense FFN
    d_ff_dense: int = 0             # dense-FFN hidden dim for non-MoE layers
    router_dtype: str = "float32"
    router_noise: float = 0.0
    aux_loss_coef: float = 0.001
    capacity_factor: float = 1.25   # <=0 means dropless (C = S*K)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective state-space block."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 => ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 ("Finch") time-mix / channel-mix block."""

    head_dim: int = 64
    decay_lora: int = 64            # rank of the data-dependent decay LoRA
    mix_lora: int = 32              # rank of the token-shift mix LoRA
    gate_lora: int = 64


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: precomputed embeddings fed to the backbone.

    ``input_specs`` produces ``(batch, num_prefix, d_model)`` embeddings; no
    vision/audio tower is instantiated (per assignment: backbone only).
    """

    kind: str = "none"              # "none" | "patch" (vlm) | "frames" (audio)
    num_prefix: int = 0             # prefix embeddings per example


# ---------------------------------------------------------------------------
# Convnet (DilatedVGG — the paper's own workload)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayerConfig:
    name: str
    kind: str                       # "conv" | "pool" | "dense" | "upsample"
    in_ch: int = 0
    out_ch: int = 0
    kernel: int = 3
    stride: int = 1
    dilation: int = 1
    # dense layers are 1x1 convs over the feature map in DilatedVGG-style nets


@dataclass(frozen=True)
class ConvNetConfig:
    layers: Tuple[ConvLayerConfig, ...] = ()
    in_hw: Tuple[int, int] = (1024, 2048)
    in_ch: int = 3
    num_classes: int = 19


# ---------------------------------------------------------------------------
# Top-level model config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio", "convnet")


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 128
    d_ff: int = 512
    vocab_size: int = 512
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: Optional[FrontendConfig] = None
    convnet: Optional[ConvNetConfig] = None
    # hybrid (jamba): one attention layer every `attn_every` layers, rest SSM
    attn_every: int = 0
    # encoder-decoder
    encoder_layers: int = 0
    # misc
    act: str = "swiglu"             # "swiglu" | "gelu" | "relu2"
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    max_seq_len: int = 4096

    # ---- derived quantities -------------------------------------------------
    def layer_kinds(self) -> List[str]:
        """Per-layer mixer kind for hybrid models: 'attn' or 'ssm'."""
        if self.family != "hybrid" or not self.attn_every:
            if self.family == "ssm" and self.rwkv is not None:
                return ["rwkv"] * self.num_layers
            if self.family == "ssm":
                return ["ssm"] * self.num_layers
            return ["attn"] * self.num_layers
        # Jamba: within each period of `attn_every`, exactly one attn layer
        # (at index attn_every//2, matching the released config).
        kinds = []
        for i in range(self.num_layers):
            kinds.append("attn" if i % self.attn_every == self.attn_every // 2 else "ssm")
        return kinds

    def ffn_kinds(self) -> List[str]:
        """Per-layer FFN kind: 'dense' or 'moe'."""
        if self.moe is None:
            return ["dense"] * self.num_layers
        kinds = []
        for i in range(self.num_layers):
            if i < self.moe.first_k_dense:
                kinds.append("dense")
            elif (i - self.moe.first_k_dense) % self.moe.moe_every \
                    == self.moe.moe_offset:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return kinds

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        from repro.models import api  # local import to avoid cycles

        return api.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import api

        return api.param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # "train" | "prefill" | "decode"


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Train / serve / mesh configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # "cosine" | "linear" | "constant"
    # distributed-optimization tricks
    grad_compression: str = "none"  # "none" | "int8_ef"
    grad_accum: int = 1


@dataclass(frozen=True)
class RematConfig:
    policy: str = "dots"            # "none" | "dots" | "full"


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 4096
    global_batch: int = 256
    steps: int = 100
    seed: int = 0
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    remat: RematConfig = field(default_factory=RematConfig)
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_seq_len: int = 32_768
    max_batch: int = 128
    prefill_chunk: int = 1024
    kv_cache_dtype: str = "bfloat16"


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_ARCH_REGISTRY: Dict[str, Callable[[], "ArchSpec"]] = {}


@dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: full config + reduced smoke config + shapes."""

    arch_id: str
    model: ModelConfig
    smoke: ModelConfig
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    skip_shapes: Tuple[str, ...] = ()       # e.g. long_500k for full-attention
    skip_reason: str = ""
    source: str = ""

    def shape_cells(self) -> List[ShapeConfig]:
        return [LM_SHAPES[s] for s in self.shapes]


def register_arch(arch_id: str):
    def deco(fn: Callable[[], ArchSpec]):
        _ARCH_REGISTRY[arch_id] = fn
        return fn

    return deco


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_configs_imported()
    if arch_id not in _ARCH_REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_ARCH_REGISTRY)}"
        )
    return _ARCH_REGISTRY[arch_id]()


def list_archs() -> List[str]:
    _ensure_configs_imported()
    return sorted(_ARCH_REGISTRY)


def _ensure_configs_imported() -> None:
    # Importing repro.configs registers every architecture module.
    import repro.configs  # noqa: F401


# ---------------------------------------------------------------------------
# JSON round-trip helpers (system-description-file style configs)
# ---------------------------------------------------------------------------


def to_json(cfg: Any) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2)


def from_json(cls, text: str):
    import dacite

    return dacite.from_dict(
        data_class=cls,
        data=json.loads(text),
        config=dacite.Config(cast=[tuple], strict=False),
    )
