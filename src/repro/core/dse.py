"""Fast what-if design-space exploration engine (paper Fig 1, right path).

Sweeps ``systems x CompilePlans x workloads`` through the pluggable
estimator backends with two accelerations:

  * **compiled-graph caching** — the tiling of a task graph depends only on
    the workload, the plan, and the *structural* chip parameters (on-chip
    capacity, array alignment).  Sweep points that differ only in physical
    annotations (frequencies, bandwidths, latencies, resource counts)
    reuse the cached graph via ``reannotate`` in O(n_tasks) instead of
    recompiling — the paper's "click-of-a-button" loop.
  * **backend escalation** — estimate every point with a cheap backend
    (``roofline`` by default), prune to the most promising candidates,
    and confirm only those with the causal DES.

Example::

    dse = DesignSpaceExplorer({"vgg": convnet_ops(cfg)})
    results = dse.sweep(systems={"a": sys_a, "b": sys_b})
    best = dse.explore(systems, keep=4)[0]
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from time import perf_counter
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.estimator import EstimateReport, get_backend
from repro.core.hw import SystemDescription
from repro.core.parallel import parallel_map
from repro.core.taskgraph.compiler import (CompiledGraph, CompilePlan,
                                           compile_ops, reannotate,
                                           structural_key)
from repro.core.taskgraph.ops import LayerOp


@dataclass
class SweepResult:
    """One evaluated (workload, system, plan) point."""

    workload: str
    system: str
    plan: CompilePlan
    report: EstimateReport
    confirmed: Optional[EstimateReport] = None   # DES escalation result

    @property
    def step_time(self) -> float:
        return (self.confirmed or self.report).step_time


@dataclass
class ServingSweepResult:
    """One evaluated (traffic, scheduler, system) serving scenario.

    ``report`` is a ``repro.serve_sim.simulator.ServingReport`` — or a
    ``repro.serve_sim.monte_carlo.MonteCarloServingReport`` when the
    sweep ran with ``num_seeds > 1`` (typed loosely: core.dse stays
    importable without the serving subsystem).  The p99 properties
    return the scalar draw in the first case and the cross-seed mean in
    the second, so ranking code works unchanged."""

    traffic: str
    scheduler: str
    system: str
    report: object

    @property
    def ttft_p99(self) -> float:
        r = self.report
        if hasattr(r, "stats"):             # MonteCarloServingReport
            return r.stat("ttft_p99").mean
        return r.ttft.p99

    @property
    def tpot_p99(self) -> float:
        r = self.report
        if hasattr(r, "stats"):
            return r.stat("tpot_p99").mean
        return r.tpot.p99


# Chip parameters that change the *tiling* (anything else is handled by
# re-annotation) — shared with the serving cost-model builder.
_structural_key = structural_key


def _serving_scenario(common, sc: Tuple[str, str, str]) -> "ServingSweepResult":
    """Worker-pool job for :meth:`DesignSpaceExplorer.sweep_serving`: one
    (system, traffic, scheduler) scenario.  Module-level and argument-
    explicit so it ships to the persistent pool when the factories are
    picklable; lambda factories transparently fall back to the one-shot
    fork pool (which inherits ``common`` by memory copy)."""
    from repro.serve_sim.simulator import simulate_serving

    costs, traffics, schedulers, replicas, slots = common
    sname, tname, kname = sc
    rep = simulate_serving(costs[sname], schedulers[kname],
                           traffics[tname](),
                           replicas=replicas, slots=slots)
    rep = dataclasses.replace(rep, sim_result=None)
    return ServingSweepResult(
        traffic=tname, scheduler=kname, system=sname, report=rep)


def _serving_scenario_seeds(common, job):
    """Worker-pool job for ``sweep_serving(num_seeds > 1)``: one seed
    chunk ``[lo, hi)`` of one (system, traffic, scheduler) scenario.
    The parent concatenates chunks back into one per-scenario
    ``MonteCarloServingReport``.  Returns per-seed ``ServingReport``\\ s
    with ``sim_result``/``events`` stripped; per-request columns ride
    along (they pickle as compact arrays and, over a persistent pool,
    ship via shared memory when large)."""
    from repro.serve_sim.monte_carlo import MonteCarloServingSimulator

    costs, batches, schedulers, replicas, slots = common
    sname, tname, kname, lo, hi = job
    sim = MonteCarloServingSimulator(
        costs[sname], schedulers[kname], batches[tname].rows(lo, hi),
        replicas=replicas, slots=slots)
    return [dataclasses.replace(sim._run_seed(k), sim_result=None,
                                events=[])
            for k in range(hi - lo)]


class DesignSpaceExplorer:
    """Sweeps named workloads over systems and plans with graph caching."""

    def __init__(self, workloads: Mapping[str, List[LayerOp]],
                 probe=None):
        if not workloads:
            raise ValueError("need at least one workload")
        self.workloads = dict(workloads)
        self._cache: Dict[Tuple, CompiledGraph] = {}
        self.stats = {"compiles": 0, "reannotations": 0, "estimates": 0}
        #: optional ``repro.obs.Probe``; DSE series use the probe's
        #: host-side clock (``elapsed()``), not a simulation clock.
        self.probe = probe

    # ---- compiled-graph cache -------------------------------------------

    def compiled(self, workload: str, system: SystemDescription,
                 plan: Optional[CompilePlan] = None) -> CompiledGraph:
        """Compiled graph for a sweep point, re-annotating a structurally
        identical cached graph when possible."""
        plan = plan or CompilePlan()
        key = (workload, plan, _structural_key(system))
        prb = self.probe
        hit = self._cache.get(key)
        if hit is None:
            self.stats["compiles"] += 1
            graph = compile_ops(self.workloads[workload], system, plan)
            self._cache[key] = graph
            if prb is not None:
                prb.counter("dse/compiles").add(prb.elapsed())
            return graph
        if hit.system is system:
            if prb is not None:
                prb.counter("dse/cache_hits").add(prb.elapsed())
            return hit
        self.stats["reannotations"] += 1
        if prb is not None:
            prb.counter("dse/reannotations").add(prb.elapsed())
        return reannotate(hit, system)

    def _pool_estimates(self, graphs: Sequence[CompiledGraph], backend: str,
                        workers: int) -> List[EstimateReport]:
        """Estimate ``graphs`` on the persistent worker pool: each unique
        structure is broadcast once (``ensure_shared``), each point ships
        only its duration vector + system annotations, and workers keep
        their structural caches across points *and across repeated
        sweep/explore calls* — no per-call pool startup after the first.
        Falls back to shipping whole graphs if a structure cannot be
        broadcast."""
        import numpy as np

        from repro.core.estimator import estimate_and_strip, estimate_variant
        from repro.core.parallel import ensure_shared

        items = []
        for g in graphs:
            key = g.pool_key()
            if not ensure_shared(workers, key, g):
                return parallel_map(estimate_and_strip, list(graphs),
                                    workers, common=backend)
            items.append((key, np.asarray(g.durations), g.system,
                          g.resources))
        return parallel_map(estimate_variant, items, workers,
                            common=backend)

    # ---- sweeping --------------------------------------------------------

    def sweep(self, systems: Mapping[str, SystemDescription],
              plans: Optional[Sequence[CompilePlan]] = None,
              workloads: Optional[Iterable[str]] = None,
              backend: str = "roofline",
              workers: int = 1) -> List[SweepResult]:
        """Estimate every (workload, system, plan) point with ``backend``,
        sorted fastest-first.

        ``workers > 1`` fans the points out over the persistent worker
        pool (results are deterministic and ordered; reports come back
        with ``sim_result=None``).  Structural compiles and re-annotation
        happen in the parent first — workers receive ready compiled
        graphs, and the pool is reused across repeated ``sweep`` /
        ``explore`` calls instead of re-forking per call.
        """
        plans = list(plans) if plans else [CompilePlan()]
        names = list(workloads) if workloads else list(self.workloads)
        est = get_backend(backend)
        points = [(w, sname, plan)
                  for w in names
                  for sname in systems
                  for plan in plans]
        self.stats["estimates"] += len(points)
        prb = self.probe
        t_sweep = prb.elapsed() if prb is not None else 0.0
        if workers > 1 and len(points) > 1:
            reports = self._pool_estimates(
                [self.compiled(w, systems[sname], plan)
                 for w, sname, plan in points], backend, workers)
            if prb is not None:
                prb.counter("dse/points_done").add(prb.elapsed(), len(points))
        elif prb is None:
            reports = [est.estimate(self.compiled(w, systems[sname], plan))
                       for w, sname, plan in points]
        else:
            hist = prb.histogram("dse/point_seconds", unit="s")
            done = prb.counter("dse/points_done")
            reports = []
            for w, sname, plan in points:
                tp = perf_counter()
                reports.append(
                    est.estimate(self.compiled(w, systems[sname], plan)))
                hist.observe(perf_counter() - tp)
                done.add(prb.elapsed())
        if prb is not None:
            prb.span(f"sweep[{backend}]", t_sweep, prb.elapsed(),
                     track="dse", points=len(points), workers=workers)
        out = [SweepResult(workload=w, system=sname, plan=plan, report=rep)
               for (w, sname, plan), rep in zip(points, reports)]
        out.sort(key=lambda r: r.step_time)
        return out

    def explore(self, systems: Mapping[str, SystemDescription],
                plans: Optional[Sequence[CompilePlan]] = None,
                workloads: Optional[Iterable[str]] = None,
                prune_backend: str = "roofline",
                confirm_backend: str = "des",
                keep: int = 4,
                workers: int = 1) -> List[SweepResult]:
        """Backend escalation: prune the sweep with a cheap backend, then
        confirm the ``keep`` most promising points per workload with the
        high-fidelity backend.  Returns confirmed points fastest-first.
        ``workers > 1`` parallelizes the confirmation stage (the pruning
        backend is µs-fast; the causal DES dominates)."""
        prb = self.probe
        t_explore = prb.elapsed() if prb is not None else 0.0
        ranked = self.sweep(systems, plans, workloads, backend=prune_backend)
        confirm = get_backend(confirm_backend)
        survivors: List[SweepResult] = []
        seen: Dict[str, int] = {}
        for r in ranked:
            if seen.get(r.workload, 0) >= keep:
                continue
            seen[r.workload] = seen.get(r.workload, 0) + 1
            survivors.append(r)
        if prb is not None:
            # prune rate: how much the cheap backend saved the DES
            prb.counter("dse/pruned").add(
                prb.elapsed(), len(ranked) - len(survivors))
        self.stats["estimates"] += len(survivors)
        if workers > 1 and len(survivors) > 1:
            confirmed = self._pool_estimates(
                [self.compiled(r.workload, systems[r.system], r.plan)
                 for r in survivors], confirm_backend, workers)
        elif prb is None:
            confirmed = [
                confirm.estimate(
                    self.compiled(r.workload, systems[r.system], r.plan))
                for r in survivors]
        else:
            hist = prb.histogram("dse/confirm_seconds", unit="s")
            confirmed = []
            for r in survivors:
                tp = perf_counter()
                confirmed.append(confirm.estimate(
                    self.compiled(r.workload, systems[r.system], r.plan)))
                hist.observe(perf_counter() - tp)
        if prb is not None:
            prb.counter("dse/confirmed").add(prb.elapsed(), len(survivors))
            prb.span(f"explore[{prune_backend}->{confirm_backend}]",
                     t_explore, prb.elapsed(), track="dse",
                     ranked=len(ranked), confirmed=len(survivors))
        for r, rep in zip(survivors, confirmed):
            r.confirmed = rep
        survivors.sort(key=lambda r: r.step_time)
        return survivors

    # ---- serving scenarios (systems x traffic x schedulers) -------------

    def sweep_serving(self, systems: Mapping[str, SystemDescription],
                      traffics: Mapping[str, Callable[[], object]],
                      schedulers: Mapping[str, Callable[[], object]],
                      cost_builder, replicas: int = 1,
                      slots: int = 8,
                      workers: int = 1,
                      num_seeds: int = 1) -> List[ServingSweepResult]:
        """Traffic-driven serving axis: every (system, traffic, scheduler)
        scenario is simulated with ``repro.serve_sim`` on a cost model the
        ``cost_builder`` derives from this explorer's compiled-graph fast
        path (re-annotation per system, no recompiles for physical
        variants).  ``traffics``/``schedulers`` map names to zero-arg
        factories returning fresh seeded instances per run.  Results are
        sorted by p99 TTFT (best first).

        ``num_seeds > 1`` turns every design point into a seed-batched
        Monte-Carlo estimate: the traffic factories must then return a
        ``repro.serve_sim.workload.RequestBatch`` with ``num_seeds``
        rows, one ``MonteCarloServingSimulator`` call evaluates all seeds
        per scenario, and each result carries a
        ``MonteCarloServingReport`` (cross-seed mean/CI per percentile)
        instead of a single-draw ``ServingReport`` — ranking properties
        transparently switch to the cross-seed mean.

        ``workers > 1`` runs the scenarios on the persistent worker pool
        (fork once, reused across repeated sweeps) when the traffic and
        scheduler factories are picklable — e.g. classes, module-level
        functions, or ``functools.partial`` — and falls back to a
        one-shot fork pool for lambda factories.  Seed-batched sweeps fan
        out seed *chunks*, so a single design point parallelizes too.
        Each scenario builds its workload/scheduler from its own seeded
        factories, so results are bit-identical to a serial run —
        asserted by ``tests/test_engine_parity.py`` — except that reports
        come back with ``sim_result=None`` (traces stay in the worker).
        """
        from repro.serve_sim.simulator import simulate_serving

        scenarios = [(sname, tname, kname)
                     for sname in systems
                     for tname in traffics
                     for kname in schedulers]
        self.stats["estimates"] += len(scenarios)
        costs: Dict[str, object] = {}     # one cost model per system
        prb = self.probe
        t_sweep = prb.elapsed() if prb is not None else 0.0

        if num_seeds > 1:
            out = self._sweep_serving_mc(
                systems, traffics, schedulers, cost_builder, replicas,
                slots, workers, num_seeds, scenarios)
            if prb is not None:
                prb.counter("dse/serving_scenarios").add(
                    prb.elapsed(), len(scenarios))
                prb.span("sweep_serving[mc]", t_sweep, prb.elapsed(),
                         track="dse", scenarios=len(scenarios),
                         num_seeds=num_seeds)
            return out

        def run_one(sc: Tuple[str, str, str]) -> ServingSweepResult:
            sname, tname, kname = sc
            cost = costs.get(sname)
            if cost is None:
                cost = costs[sname] = cost_builder.model_for(systems[sname])
            rep = simulate_serving(cost, schedulers[kname],
                                   traffics[tname](),
                                   replicas=replicas, slots=slots)
            return ServingSweepResult(
                traffic=tname, scheduler=kname, system=sname, report=rep)

        if workers > 1 and len(scenarios) > 1:
            for sname, system in systems.items():   # cost models up front
                costs[sname] = cost_builder.model_for(system)
            out = parallel_map(
                _serving_scenario, scenarios, workers,
                common=(costs, dict(traffics), dict(schedulers),
                        replicas, slots))
            if prb is not None:
                prb.counter("dse/serving_scenarios").add(
                    prb.elapsed(), len(scenarios))
        elif prb is None:
            out = [run_one(sc) for sc in scenarios]
        else:
            hist = prb.histogram("dse/serving_scenario_seconds", unit="s")
            done = prb.counter("dse/serving_scenarios")
            out = []
            for sc in scenarios:
                tp = perf_counter()
                out.append(run_one(sc))
                hist.observe(perf_counter() - tp)
                done.add(prb.elapsed())
        if prb is not None:
            prb.span("sweep_serving", t_sweep, prb.elapsed(), track="dse",
                     scenarios=len(scenarios), workers=workers)
        out.sort(key=lambda r: r.ttft_p99)
        return out

    def _sweep_serving_mc(self, systems, traffics, schedulers, cost_builder,
                          replicas, slots, workers, num_seeds,
                          scenarios) -> List[ServingSweepResult]:
        """Seed-batched serving sweep: one Monte-Carlo evaluation per
        scenario, optionally fanned out over the pool in seed chunks."""
        from repro.serve_sim.monte_carlo import (MonteCarloServingReport,
                                                 MonteCarloServingSimulator,
                                                 _cross_seed_stats)
        from repro.serve_sim.workload import RequestBatch

        costs = {sname: cost_builder.model_for(system)
                 for sname, system in systems.items()}
        batches: Dict[str, RequestBatch] = {}
        for tname, factory in traffics.items():
            batch = factory()
            if not isinstance(batch, RequestBatch):
                raise TypeError(
                    "num_seeds > 1 needs traffic factories returning "
                    f"RequestBatch, got {type(batch)!r} for {tname!r}")
            if batch.num_seeds != num_seeds:
                raise ValueError(f"traffic {tname!r} has {batch.num_seeds} "
                                 f"seed rows, sweep wants {num_seeds}")
            batches[tname] = batch

        if workers > 1 and num_seeds * len(scenarios) > 1:
            chunk = max(1, -(-num_seeds // workers))
            jobs = [(sname, tname, kname, lo, min(lo + chunk, num_seeds))
                    for sname, tname, kname in scenarios
                    for lo in range(0, num_seeds, chunk)]
            parts = parallel_map(
                _serving_scenario_seeds, jobs, workers,
                common=(costs, batches, dict(schedulers), replicas, slots))
            out = []
            i = 0
            for sname, tname, kname in scenarios:
                reports = []
                while i < len(jobs) and jobs[i][:3] == (sname, tname, kname):
                    reports.extend(parts[i])
                    i += 1
                batch = batches[tname]
                mc = MonteCarloServingReport(
                    workload=batch.name, scheduler=schedulers[kname]().name,
                    cost_model=costs[sname].name, replicas=replicas,
                    slots=slots, seeds=batch.seeds, reports=reports,
                    stats=_cross_seed_stats(reports))
                out.append(ServingSweepResult(
                    traffic=tname, scheduler=kname, system=sname, report=mc))
        else:
            out = [ServingSweepResult(
                       traffic=tname, scheduler=kname, system=sname,
                       report=MonteCarloServingSimulator(
                           costs[sname], schedulers[kname], batches[tname],
                           replicas=replicas, slots=slots).run())
                   for sname, tname, kname in scenarios]
        out.sort(key=lambda r: r.ttft_p99)
        return out

    # ---- what-if sweeps over one annotated parameter --------------------

    def what_if_sweep(self, workload: str, base: SystemDescription,
                      key: str, values: Sequence[float],
                      plan: Optional[CompilePlan] = None,
                      backend: str = "des",
                      workers: int = 1) -> List[Tuple[float, EstimateReport]]:
        """Sweep one physical annotation (e.g. ``link_bandwidth``) through
        ``values`` on the fast re-annotation path.

        All values are evaluated in one batch: the re-annotated variants
        share the cached graph's task structure, so the roofline/analytic
        backends reduce the whole sweep to vectorized operations over one
        duration matrix (n_values x n_tasks), and the DES backend reuses
        one dependency-CSR cache across values (optionally fanned out over
        ``workers`` forked processes).  Parity with the per-value loop is
        asserted by ``tests/test_engine_parity.py``.
        """
        from repro.core.avsm.model import AVSM

        values = list(values)
        plan = plan or CompilePlan()
        prb = self.probe
        t_sweep = prb.elapsed() if prb is not None else 0.0
        graph = self.compiled(workload, base, plan)
        avsm = AVSM(system=base, graph=graph)
        variants = [avsm.what_if(**{key: v}) for v in values]
        est = get_backend(backend)
        reports = est.estimate_many([a.graph for a in variants],
                                    workers=workers)
        self.stats["reannotations"] += len(values)
        self.stats["estimates"] += len(values)
        if prb is not None:
            prb.counter("dse/points_done").add(prb.elapsed(), len(values))
            prb.span(f"what_if[{key}:{backend}]", t_sweep, prb.elapsed(),
                     track="dse", values=len(values), workers=workers)
        return list(zip(values, reports))
