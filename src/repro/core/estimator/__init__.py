"""Unified estimator-backend architecture.

The paper's methodology needs performance estimates at several points of
the design flow, at different fidelity/cost trade-offs (ANNETTE makes the
same argument for stacked/mixed models; SMAUG for one entry point across
fidelity levels).  This package makes the estimation fidelity a pluggable
axis: every backend consumes the same hardware-adapted
:class:`~repro.core.taskgraph.compiler.CompiledGraph` and emits a common
:class:`EstimateReport`.

Registered backends (cheapest first):

  * ``roofline`` — closed-form three-term bound (µs per estimate); no
    queueing, no overheads: a lower bound used to prune sweeps.
  * ``analytic`` — per-op latency stacking over the compiled tasks
    (launch overheads + padding efficiency included, DMA/compute overlap
    per op, link-occupancy lower bound); ~100µs per estimate.
  * ``des``      — the causal discrete-event simulation on the
    multi-server, bandwidth-shared resource model; exact contention.

Usage::

    graph = compile_ops(ops, system)
    report = get_backend("roofline").estimate(graph)
    confirmed = get_backend("des").estimate(graph)
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.sim.engine import SimResult
from repro.core.taskgraph.compiler import CompiledGraph


@dataclass
class LayerReport:
    name: str
    time: float                  # seconds (span in the schedule)
    flops: float
    hbm_bytes: float
    coll_bytes: float
    intensity: float             # flops / hbm byte
    achieved_flops: float        # flops / time
    bound: str                   # compute | memory | collective | latency


@dataclass
class EstimateReport:
    """Common output of every estimator backend.

    ``AVSMReport`` (repro.core.avsm.model) is a view over this class: the
    DES backend fills every field; cheaper backends leave ``sim_result``
    empty and report model-derived utilizations.
    """

    system: str
    backend: str
    step_time: float             # seconds end-to-end
    t_compute: float             # three-term breakdown (lower bounds)
    t_memory: float
    t_collective: float
    nce_util: float
    dma_util: float
    ici_util: float
    layers: List[LayerReport]
    build_seconds: float
    estimate_seconds: float
    n_tasks: int
    sim_result: Optional[SimResult] = None

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    # Backwards-compatible AVSM spelling.
    @property
    def sim_seconds(self) -> float:
        return self.estimate_seconds

    def summary(self) -> str:
        lines = [
            f"AVSM[{self.system}|{self.backend}] "
            f"step={self.step_time * 1e3:.3f} ms  "
            f"tasks={self.n_tasks}  build={self.build_seconds:.2f}s "
            f"sim={self.estimate_seconds:.2f}s",
            f"  utilization: nce={self.nce_util:.1%} dma={self.dma_util:.1%} "
            f"ici={self.ici_util:.1%}",
        ]
        return "\n".join(lines)


class EstimatorBackend(abc.ABC):
    """One fidelity level of the estimation stack."""

    name: str = "abstract"
    fidelity: int = 0            # higher = more faithful, more expensive

    @abc.abstractmethod
    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        """Estimate one step of ``graph`` on its system description."""

    def estimate_many(self, graphs: List[CompiledGraph],
                      workers: int = 1) -> List[EstimateReport]:
        """Estimate a batch of graphs — typically re-annotated what-if
        variants of one structure (``DesignSpaceExplorer.what_if_sweep``).

        The base implementation loops (optionally across the persistent
        worker pool — the job is a module-level function with the backend
        name broadcast once, so it ships pickled instead of re-forking a
        pool per call); the roofline/analytic backends override it with
        vectorized paths that evaluate every variant as one duration
        matrix, and the DES backend with a shared-memory duration matrix.
        When ``workers > 1`` the returned reports carry
        ``sim_result=None`` (traces do not cross the process boundary).
        """
        graphs = list(graphs)
        if workers > 1 and len(graphs) > 1:
            from repro.core.parallel import parallel_map

            return parallel_map(estimate_and_strip, graphs, workers,
                                common=self.name)
        return [self.estimate(g) for g in graphs]


def estimate_and_strip(backend_name: str,
                       graph: CompiledGraph) -> EstimateReport:
    """Worker-pool job: estimate one graph with the named backend and
    strip the simulation trace (module-level so it pickles by name)."""
    rep = get_backend(backend_name).estimate(graph)
    rep.sim_result = None
    return rep


def estimate_variant(backend_name: str, item) -> EstimateReport:
    """Worker-pool job for sweep points that are re-annotated variants of
    a broadcast structural graph: ``item = (pool key, durations, system,
    resources)``.  The heavy task list was shipped once per pool via
    ``repro.core.parallel.ensure_shared`` (``CompiledGraph.pool_key``);
    each sweep point reassembles its variant around the stored structure,
    so the worker's lazily built caches (dependency CSR, per-op arrays)
    are reused across every point *and every subsequent sweep call*."""
    from repro.core.parallel import WORKER_STORE

    key, durations, system, resources = item
    g0: CompiledGraph = WORKER_STORE[key]
    work, ridx, fidx, _ = g0.anno_arrays()
    variant = CompiledGraph(
        tasks=g0.tasks, ops=g0.ops, system=system, plan=g0.plan,
        resources=resources, _anno_arrays=(work, ridx, fidx, durations),
        _shared=g0._shared)
    rep = get_backend(backend_name).estimate(variant)
    rep.sim_result = None
    return rep


_REGISTRY: Dict[str, Callable[[], EstimatorBackend]] = {}
_INSTANCES: Dict[str, EstimatorBackend] = {}


def register_backend(factory: Callable[[], EstimatorBackend]):
    """Class decorator: register an EstimatorBackend under its ``name``."""
    name = factory.name
    if not isinstance(name, str) or not name:
        raise ValueError("backend class must define a non-empty `name`")
    _REGISTRY[name] = factory
    return factory


def get_backend(name: str) -> EstimatorBackend:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown estimator backend {name!r}; "
            f"available: {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def available_backends() -> List[str]:
    return sorted(_REGISTRY, key=lambda n: _REGISTRY[n].fidelity)


def layer_static(graph: CompiledGraph) -> List[tuple]:
    """System-independent per-layer footprints ``(name, flops, hbm_bytes,
    coll_bytes)`` in first-op order — computed once per task-graph
    structure and shared across re-annotated what-if variants (they alias
    the same op list)."""
    rows = graph._shared.get("layer_static")
    if rows is None:
        per_layer: Dict[str, List[float]] = {}
        for op in graph.ops:
            d = per_layer.setdefault(op.layer, [0.0, 0.0, 0.0])
            if op.coll is not None:
                d[2] += op.coll.payload
            else:
                d[0] += op.flops
                d[1] += op.total_bytes
        rows = [(name, v[0], v[1], v[2]) for name, v in per_layer.items()]
        graph._shared["layer_static"] = rows
    return rows


def layer_reports(graph: CompiledGraph,
                  durations: Dict[str, float]) -> List[LayerReport]:
    """Per-layer roofline classification shared by all backends."""
    chip = graph.system.chip
    peak = chip.compute.matrix_flops
    bw = chip.memory.bandwidth
    lbw = max(chip.link.bandwidth, 1.0)
    layers = []
    for name, flops, hbm_bytes, coll_bytes in layer_static(graph):
        t = durations.get(name, 0.0)
        t_c = flops / peak
        t_m = hbm_bytes / bw
        t_i = coll_bytes / lbw
        dominant = max(("compute", t_c), ("memory", t_m),
                       ("collective", t_i), key=lambda kv: kv[1])
        bound = dominant[0]
        if t > 0 and max(t_c, t_m, t_i) < 0.5 * t:
            bound = "latency"
        layers.append(LayerReport(
            name=name, time=t, flops=flops,
            hbm_bytes=hbm_bytes, coll_bytes=coll_bytes,
            intensity=flops / max(hbm_bytes, 1.0),
            achieved_flops=flops / t if t > 0 else 0.0,
            bound=bound))
    return layers


# Import for side effect: registers the built-in backends.
from repro.core.estimator import analytic as _analytic   # noqa: E402,F401
from repro.core.estimator import des as _des             # noqa: E402,F401
from repro.core.estimator import roofline as _roofline   # noqa: E402,F401
