"""Analytic backend: per-op latency stacking (ANNETTE-style mixed model).

Walks the compiled tasks (so launch overheads, padding efficiency, and the
tiling are all included — the same annotations the DES sees) but replaces
event-driven contention with a two-bound stack:

  * per op: DMA and compute are double-buffered, so the op's latency is
    ``max(Σ dma, Σ compute) + one pipeline-fill DMA``;
  * activation collectives gate the next op (serial); gradient collectives
    marked overlappable ride the link concurrently with compute;
  * the step is ``max(serial critical path, per-link occupancy)``.

~100x cheaper than the DES, typically within a few percent on graphs
without heavy cross-resource contention.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.estimator import (EstimateReport, EstimatorBackend,
                                  layer_reports, register_backend)
from repro.core.taskgraph.compiler import CompiledGraph


@register_backend
class AnalyticBackend(EstimatorBackend):
    name = "analytic"
    fidelity = 1

    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        t0 = time.perf_counter()
        # accumulate per-op compute/dma time and per-resource link time
        op_comp: Dict[int, float] = {}
        op_dma: Dict[int, float] = {}
        op_dma_first: Dict[int, float] = {}
        op_coll: Dict[int, float] = {}
        link_busy: Dict[str, float] = {}
        t_c = t_m = t_i = 0.0
        for t, dur in zip(graph.tasks, graph.durations):
            if t.kind == "compute":
                op_comp[t.op_id] = op_comp.get(t.op_id, 0.0) + dur
                t_c += dur
            elif t.kind == "dma":
                op_dma[t.op_id] = op_dma.get(t.op_id, 0.0) + dur
                op_dma_first.setdefault(t.op_id, dur)
                t_m += dur
            elif t.kind == "collective":
                op_coll[t.op_id] = op_coll.get(t.op_id, 0.0) + dur
                link_busy[t.resource] = (link_busy.get(t.resource, 0.0)
                                         + dur)
                t_i += dur

        serial = 0.0
        per_layer: Dict[str, float] = {}
        overlappable = 0.0
        for op_id, op in enumerate(graph.ops):
            if op.coll is not None:
                dt = op_coll.get(op_id, 0.0)
                if graph.plan.overlap_grad_comm and \
                        op.name.endswith(("grad_rs", "grad_rs_bwd")):
                    overlappable += dt
                else:
                    serial += dt
                    per_layer[op.layer] = per_layer.get(op.layer, 0.0) + dt
                continue
            comp = op_comp.get(op_id, 0.0)
            dma = op_dma.get(op_id, 0.0)
            # double-buffered: overlap DMA with compute, pay one fill
            dt = max(comp, dma) + op_dma_first.get(op_id, 0.0)
            serial += dt
            per_layer[op.layer] = per_layer.get(op.layer, 0.0) + dt

        # link occupancy bound: overlapped collectives still occupy the
        # wire; a per-channel sum (scaled by channel width) bounds below
        specs = graph.resources
        occupancy = 0.0
        for res, busy in link_busy.items():
            width = specs[res].servers if res in specs else 1
            occupancy = max(occupancy, busy / max(1, width))
        step = max(serial, occupancy, overlappable)

        return EstimateReport(
            system=graph.system.name, backend=self.name, step_time=step,
            t_compute=t_c, t_memory=t_m, t_collective=t_i,
            nce_util=t_c / step if step > 0 else 0.0,
            dma_util=t_m / step if step > 0 else 0.0,
            ici_util=t_i / step if step > 0 else 0.0,
            layers=layer_reports(graph, per_layer),
            build_seconds=build_seconds,
            estimate_seconds=time.perf_counter() - t0,
            n_tasks=len(graph.tasks))

    # ---- vectorized what-if sweep path ----------------------------------

    def _task_arrays(self, graph: CompiledGraph):
        """Per-task grouping arrays, cached per task-graph structure."""
        arrs = graph._shared.get("analytic_arrays")
        if arrs is None:
            n_ops = len(graph.ops)
            idx_c, op_c = [], []
            idx_d, op_d = [], []
            idx_x, op_x, res_x = [], [], []
            res_index: Dict[str, int] = {}
            first_dma = np.full(n_ops, -1, dtype=np.int64)
            for i, t in enumerate(graph.tasks):
                if t.kind == "compute":
                    idx_c.append(i)
                    op_c.append(t.op_id)
                elif t.kind == "dma":
                    idx_d.append(i)
                    op_d.append(t.op_id)
                    if first_dma[t.op_id] < 0:
                        first_dma[t.op_id] = i
                elif t.kind == "collective":
                    idx_x.append(i)
                    op_x.append(t.op_id)
                    res_x.append(
                        res_index.setdefault(t.resource, len(res_index)))
            lay_index: Dict[str, int] = {}
            lay_of = np.zeros(n_ops, dtype=np.int64)
            is_coll = np.zeros(n_ops, dtype=bool)
            overlap = np.zeros(n_ops, dtype=bool)
            for oi, op in enumerate(graph.ops):
                lay_of[oi] = lay_index.setdefault(op.layer, len(lay_index))
                if op.coll is not None:
                    is_coll[oi] = True
                    if graph.plan.overlap_grad_comm and \
                            op.name.endswith(("grad_rs", "grad_rs_bwd")):
                        overlap[oi] = True
            arrs = (np.asarray(idx_c, dtype=np.int64),
                    np.asarray(op_c, dtype=np.int64),
                    np.asarray(idx_d, dtype=np.int64),
                    np.asarray(op_d, dtype=np.int64),
                    np.asarray(idx_x, dtype=np.int64),
                    np.asarray(op_x, dtype=np.int64),
                    np.asarray(res_x, dtype=np.int64),
                    list(res_index), first_dma, is_coll, overlap,
                    lay_of, list(lay_index))
            graph._shared["analytic_arrays"] = arrs
        return arrs

    def estimate_many(self, graphs: List[CompiledGraph],
                      workers: int = 1) -> List[EstimateReport]:
        """Vectorized sweep: the variants share one task structure, so the
        per-value loop reduces to numpy segment sums over one duration
        matrix (n_variants x n_tasks)."""
        graphs = list(graphs)
        if len(graphs) < 2 or any(g.ops is not graphs[0].ops
                                  for g in graphs):
            return super().estimate_many(graphs, workers)
        t0 = time.perf_counter()
        g0 = graphs[0]
        (idx_c, op_c, idx_d, op_d, idx_x, op_x, res_x, res_names,
         first_dma, is_coll, overlap, lay_of, lay_names) = \
            self._task_arrays(g0)
        n_ops = len(g0.ops)
        n_res = len(res_names)
        n_layers = len(lay_names)
        has_dma = first_dma >= 0
        fd_safe = np.where(has_dma, first_dma, 0)
        out = []
        for graph in graphs:
            d = np.asarray(graph.durations)
            comp_op = np.bincount(op_c, weights=d[idx_c], minlength=n_ops)
            dma_op = np.bincount(op_d, weights=d[idx_d], minlength=n_ops)
            coll_op = np.bincount(op_x, weights=d[idx_x], minlength=n_ops)
            fill = np.where(has_dma, d[fd_safe], 0.0)
            op_nc = np.maximum(comp_op, dma_op) + fill
            serial_op = np.where(
                is_coll, np.where(overlap, 0.0, coll_op), op_nc)
            serial = float(serial_op.sum())
            overlappable = float(coll_op[overlap].sum())
            occupancy = 0.0
            if n_res:
                link_busy = np.bincount(res_x, weights=d[idx_x],
                                        minlength=n_res)
                specs = graph.resources
                widths = np.array([
                    max(1, specs[r].servers) if r in specs else 1
                    for r in res_names], dtype=np.float64)
                occupancy = float((link_busy / widths).max())
            step = max(serial, occupancy, overlappable)
            lay_t = np.bincount(lay_of, weights=serial_op,
                                minlength=n_layers)
            per_layer = dict(zip(lay_names, lay_t.tolist()))
            t_c = float(d[idx_c].sum())
            t_m = float(d[idx_d].sum())
            t_i = float(d[idx_x].sum())
            out.append(EstimateReport(
                system=graph.system.name, backend=self.name, step_time=step,
                t_compute=t_c, t_memory=t_m, t_collective=t_i,
                nce_util=t_c / step if step > 0 else 0.0,
                dma_util=t_m / step if step > 0 else 0.0,
                ici_util=t_i / step if step > 0 else 0.0,
                layers=layer_reports(graph, per_layer),
                build_seconds=0.0, estimate_seconds=0.0,
                n_tasks=len(graph.tasks)))
        dt = (time.perf_counter() - t0) / len(graphs)
        for rep in out:
            rep.estimate_seconds = dt
        return out
