"""Analytic backend: per-op latency stacking (ANNETTE-style mixed model).

Walks the compiled tasks (so launch overheads, padding efficiency, and the
tiling are all included — the same annotations the DES sees) but replaces
event-driven contention with a two-bound stack:

  * per op: DMA and compute are double-buffered, so the op's latency is
    ``max(Σ dma, Σ compute) + one pipeline-fill DMA``;
  * activation collectives gate the next op (serial); gradient collectives
    marked overlappable ride the link concurrently with compute;
  * the step is ``max(serial critical path, per-link occupancy)``.

~100x cheaper than the DES, typically within a few percent on graphs
without heavy cross-resource contention.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.core.estimator import (EstimateReport, EstimatorBackend,
                                  layer_reports, register_backend)
from repro.core.taskgraph.compiler import CompiledGraph


@register_backend
class AnalyticBackend(EstimatorBackend):
    name = "analytic"
    fidelity = 1

    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        t0 = time.perf_counter()
        # accumulate per-op compute/dma time and per-resource link time
        op_comp: Dict[int, float] = {}
        op_dma: Dict[int, float] = {}
        op_dma_first: Dict[int, float] = {}
        op_coll: Dict[int, float] = {}
        link_busy: Dict[str, float] = {}
        t_c = t_m = t_i = 0.0
        for t, dur in zip(graph.tasks, graph.durations):
            if t.kind == "compute":
                op_comp[t.op_id] = op_comp.get(t.op_id, 0.0) + dur
                t_c += dur
            elif t.kind == "dma":
                op_dma[t.op_id] = op_dma.get(t.op_id, 0.0) + dur
                op_dma_first.setdefault(t.op_id, dur)
                t_m += dur
            elif t.kind == "collective":
                op_coll[t.op_id] = op_coll.get(t.op_id, 0.0) + dur
                link_busy[t.resource] = (link_busy.get(t.resource, 0.0)
                                         + dur)
                t_i += dur

        serial = 0.0
        per_layer: Dict[str, float] = {}
        overlappable = 0.0
        for op_id, op in enumerate(graph.ops):
            if op.coll is not None:
                dt = op_coll.get(op_id, 0.0)
                if graph.plan.overlap_grad_comm and \
                        op.name.endswith(("grad_rs", "grad_rs_bwd")):
                    overlappable += dt
                else:
                    serial += dt
                    per_layer[op.layer] = per_layer.get(op.layer, 0.0) + dt
                continue
            comp = op_comp.get(op_id, 0.0)
            dma = op_dma.get(op_id, 0.0)
            # double-buffered: overlap DMA with compute, pay one fill
            dt = max(comp, dma) + op_dma_first.get(op_id, 0.0)
            serial += dt
            per_layer[op.layer] = per_layer.get(op.layer, 0.0) + dt

        # link occupancy bound: overlapped collectives still occupy the
        # wire; a per-channel sum (scaled by channel width) bounds below
        specs = graph.resources
        occupancy = 0.0
        for res, busy in link_busy.items():
            width = specs[res].servers if res in specs else 1
            occupancy = max(occupancy, busy / max(1, width))
        step = max(serial, occupancy, overlappable)

        return EstimateReport(
            system=graph.system.name, backend=self.name, step_time=step,
            t_compute=t_c, t_memory=t_m, t_collective=t_i,
            nce_util=t_c / step if step > 0 else 0.0,
            dma_util=t_m / step if step > 0 else 0.0,
            ici_util=t_i / step if step > 0 else 0.0,
            layers=layer_reports(graph, per_layer),
            build_seconds=build_seconds,
            estimate_seconds=time.perf_counter() - t0,
            n_tasks=len(graph.tasks))
