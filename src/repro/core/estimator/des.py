"""DES backend: causal discrete-event simulation (highest fidelity).

Runs the compiled task graph on the multi-server, bandwidth-shared
resource model (``repro.core.sim.engine``): DMA engines are concurrent
servers, collectives sharing an ICI channel split its bandwidth, and every
dependency blocks causally.  The report keeps the full ``SimResult`` so
Gantt/trace exports still work.
"""
from __future__ import annotations

import time

from repro.core.estimator import (EstimateReport, EstimatorBackend,
                                  layer_reports, register_backend)
from repro.core.taskgraph.compiler import CompiledGraph
from repro.core.sim.engine import simulate_static


@register_backend
class DesBackend(EstimatorBackend):
    name = "des"
    fidelity = 2

    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        t0 = time.perf_counter()
        # Array-backed fast path: compiled graphs are static (no callbacks,
        # no injection), so the dependency CSR is precomputed once per
        # structure (shared across re-annotated what-if variants) and the
        # event loop runs over flat duration arrays with records
        # materialized lazily — several times faster than the general
        # dict-based engine, with exact parity (tests/test_engine_parity).
        result = simulate_static(graph.tasks, graph.resources,
                                 graph.durations, cache=graph.sim_cache())

        def util(prefix: str) -> float:
            if result.makespan <= 0:
                return 0.0
            busy = 0.0
            capacity = 0
            for name, b in result.resource_busy.items():
                if not name.startswith(prefix):
                    continue
                busy += b
                spec = graph.resources.get(name)
                capacity += spec.servers if spec is not None else 1
            return busy / (max(1, capacity) * result.makespan)

        t_c = sum(b for k, b in result.resource_busy.items()
                  if k in ("nce", "vpu"))
        t_m = result.resource_busy.get("dma", 0.0)
        t_i = sum(b for k, b in result.resource_busy.items()
                  if k.startswith("ici"))
        return EstimateReport(
            system=graph.system.name, backend=self.name,
            step_time=result.makespan,
            t_compute=t_c, t_memory=t_m, t_collective=t_i,
            nce_util=util("nce"), dma_util=util("dma"), ici_util=util("ici"),
            layers=layer_reports(graph, result.layer_durations()),
            build_seconds=build_seconds,
            estimate_seconds=time.perf_counter() - t0,
            n_tasks=len(graph.tasks),
            sim_result=result)
