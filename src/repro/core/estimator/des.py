"""DES backend: causal discrete-event simulation (highest fidelity).

Runs the compiled task graph on the multi-server, bandwidth-shared
resource model (``repro.core.sim.engine``): DMA engines are concurrent
servers, collectives sharing an ICI channel split its bandwidth, and every
dependency blocks causally.  The report keeps the full ``SimResult`` so
Gantt/trace exports still work.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List

import numpy as np

from repro.core.estimator import (EstimateReport, EstimatorBackend,
                                  layer_reports, register_backend)
from repro.core.taskgraph.compiler import CompiledGraph
from repro.core.sim.engine import simulate_static


def _simulate_variant(common, item) -> EstimateReport:
    """Worker-pool job for :meth:`DesBackend.estimate_many`: one what-if
    variant = (row of the shared duration matrix, its system/resources).

    The structural graph is broadcast once per map; the duration matrix
    arrives as a shared-memory memmap token (or inline ndarray fallback)
    attached once per worker and cached in ``WORKER_STATE`` for the rest
    of the map.  The variant's ``CompiledGraph`` is reassembled around the
    shared task list, so the worker's dependency-CSR cache (rebuilt on
    the first row) is reused for every subsequent row it simulates.
    """
    from repro.core.estimator import get_backend
    from repro.core.parallel import WORKER_STATE, WORKER_STORE

    key, mat = common
    graph = WORKER_STORE[key]
    i, system, resources = item
    if isinstance(mat, tuple):                  # ("mmap", path, shape)
        _, path, shape = mat
        arr = WORKER_STATE.get(path)            # keyed by path: a serial
        if arr is None:                         # fallback in the parent
            arr = np.memmap(path, dtype=np.float64, mode="r", shape=shape)
            WORKER_STATE[path] = arr            # can't see a stale matrix
        mat = arr
    work, ridx, fidx, _ = graph.anno_arrays()
    variant = CompiledGraph(
        tasks=graph.tasks, ops=graph.ops, system=system, plan=graph.plan,
        resources=resources,
        _anno_arrays=(work, ridx, fidx, np.asarray(mat[i])),
        _shared=graph._shared)
    rep = get_backend("des").estimate(variant)
    rep.sim_result = None
    return rep


@register_backend
class DesBackend(EstimatorBackend):
    name = "des"
    fidelity = 2

    def estimate_many(self, graphs: List[CompiledGraph],
                      workers: int = 1) -> List[EstimateReport]:
        """Parallel what-if fan-out over the persistent worker pool.

        Re-annotated variants of one structure share their task list, so
        only one structural graph is broadcast; the per-variant duration
        vectors are stacked into one matrix placed in shared memory (a
        ``/dev/shm`` memmap when available) instead of being pickled into
        every worker.  Falls back to the generic path for unrelated
        graphs and to inline shipping if the memmap cannot be created.
        """
        graphs = list(graphs)
        if workers <= 1 or len(graphs) <= 1:
            return [self.estimate(g) for g in graphs]
        first = graphs[0]
        if any(g.tasks is not first.tasks for g in graphs):
            return super().estimate_many(graphs, workers)
        from repro.core.parallel import ensure_shared, parallel_map

        key = first.pool_key()
        if not ensure_shared(workers, key, first):
            return super().estimate_many(graphs, workers)
        mat = np.ascontiguousarray(
            [np.asarray(g.durations, dtype=np.float64) for g in graphs])
        items = [(i, g.system, g.resources) for i, g in enumerate(graphs)]
        payload = mat
        path = None
        try:
            try:
                shm = "/dev/shm" if os.path.isdir("/dev/shm") else None
                fd, path = tempfile.mkstemp(prefix="repro_durs_", dir=shm)
                with os.fdopen(fd, "wb") as f:
                    f.write(mat.tobytes())
                payload = ("mmap", path, mat.shape)
            except OSError:
                path = None                   # ship the matrix inline
            return parallel_map(_simulate_variant, items, workers,
                                common=(key, payload))
        finally:
            if path is not None:
                try:
                    os.unlink(path)           # workers keep their mapping
                except OSError:
                    pass
                # a serial fallback in *this* process may have attached
                # the memmap; drop it so the unlinked file's pages are
                # released (workers clear theirs on the next broadcast)
                from repro.core.parallel import WORKER_STATE
                WORKER_STATE.pop(path, None)

    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        t0 = time.perf_counter()
        # Array-backed fast path: compiled graphs are static (no callbacks,
        # no injection), so the dependency CSR is precomputed once per
        # structure (shared across re-annotated what-if variants) and the
        # event loop runs over flat duration arrays with records
        # materialized lazily — several times faster than the general
        # dict-based engine, with exact parity (tests/test_engine_parity).
        result = simulate_static(graph.tasks, graph.resources,
                                 graph.durations, cache=graph.sim_cache())

        def util(prefix: str) -> float:
            if result.makespan <= 0:
                return 0.0
            busy = 0.0
            capacity = 0
            for name, b in result.resource_busy.items():
                if not name.startswith(prefix):
                    continue
                busy += b
                spec = graph.resources.get(name)
                capacity += spec.servers if spec is not None else 1
            return busy / (max(1, capacity) * result.makespan)

        t_c = sum(b for k, b in result.resource_busy.items()
                  if k in ("nce", "vpu"))
        t_m = result.resource_busy.get("dma", 0.0)
        t_i = sum(b for k, b in result.resource_busy.items()
                  if k.startswith("ici"))
        return EstimateReport(
            system=graph.system.name, backend=self.name,
            step_time=result.makespan,
            t_compute=t_c, t_memory=t_m, t_collective=t_i,
            nce_util=util("nce"), dma_util=util("dma"), ici_util=util("ici"),
            layers=layer_reports(graph, result.layer_durations()),
            build_seconds=build_seconds,
            estimate_seconds=time.perf_counter() - t0,
            n_tasks=len(graph.tasks),
            sim_result=result)
