"""Closed-form roofline backend (µs-fast, prune-grade fidelity).

Three lower-bound terms over the whole per-device graph:

  compute    = Σ flops       / peak engine rate
  memory     = Σ hbm bytes   / memory bandwidth
  collective = Σ ring time over link bandwidth

``step_time = max`` of the three — no queueing, launch overheads, or
padding losses, so it is a true lower bound on the DES result; the DSE
engine uses it to prune sweeps before escalating to ``des``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.estimator import (EstimateReport, EstimatorBackend,
                                  layer_reports, register_backend)
from repro.core.hw import SystemDescription
from repro.core.taskgraph.compiler import CompiledGraph, CompilePlan, rate_table
from repro.core.taskgraph.ops import CollectiveSpec


def ring_bytes_on_wire(coll: CollectiveSpec) -> float:
    """Bytes one device puts on the link for a ring execution of ``coll``."""
    n = coll.axis_size
    if n <= 1:
        return 0.0
    if coll.kind == "all_reduce":
        return 2.0 * (n - 1) * coll.payload / n
    if coll.kind in ("all_gather", "reduce_scatter", "all_to_all"):
        return (n - 1) * coll.payload / n
    return float(coll.payload)        # permute: one hop


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   system: SystemDescription,
                   plan: CompilePlan = CompilePlan(),
                   ) -> Tuple[float, float, float]:
    """(t_compute, t_memory, t_collective) seconds for aggregate footprints
    on one chip of ``system`` — the three-term roofline as a function of
    the system description rather than hard-wired constants."""
    rates = rate_table(system, plan)
    return (flops / rates["matrix"],
            hbm_bytes / rates["mem"],
            coll_bytes / rates["ici"])


@register_backend
class RooflineBackend(EstimatorBackend):
    name = "roofline"
    fidelity = 0

    def estimate(self, graph: CompiledGraph,
                 build_seconds: float = 0.0) -> EstimateReport:
        t0 = time.perf_counter()
        rates = rate_table(graph.system, graph.plan)
        t_c = t_m = t_i = 0.0
        per_layer: Dict[str, float] = {}

        def add(layer: str, dt: float):
            per_layer[layer] = per_layer.get(layer, 0.0) + dt

        for op in graph.ops:
            if op.coll is not None:
                rate = rates["dcn" if op.coll.axis == "pod" else "ici"]
                dt = ring_bytes_on_wire(op.coll) / rate
                t_i += dt
                add(op.layer, dt)
                continue
            rate = rates["matrix" if op.matrix else "vector"]
            dt_c = op.flops / rate
            dt_m = op.total_bytes / rates["mem"]
            t_c += dt_c
            t_m += dt_m
            add(op.layer, max(dt_c, dt_m))

        step = max(t_c, t_m, t_i)
        return EstimateReport(
            system=graph.system.name, backend=self.name, step_time=step,
            t_compute=t_c, t_memory=t_m, t_collective=t_i,
            nce_util=t_c / step if step > 0 else 0.0,
            dma_util=t_m / step if step > 0 else 0.0,
            ici_util=t_i / step if step > 0 else 0.0,
            layers=layer_reports(graph, per_layer),
            build_seconds=build_seconds,
            estimate_seconds=time.perf_counter() - t0,
            n_tasks=len(graph.tasks))

    # ---- vectorized what-if sweep path ----------------------------------

    def _op_arrays(self, graph: CompiledGraph):
        """Per-op footprint arrays, cached per task-graph structure."""
        arrs = graph._shared.get("roofline_arrays")
        if arrs is None:
            n = len(graph.ops)
            flops = np.zeros(n)
            hbm = np.zeros(n)
            wire = np.zeros(n)
            pod = np.zeros(n, dtype=bool)
            matrix = np.zeros(n, dtype=bool)
            is_coll = np.zeros(n, dtype=bool)
            lay_index: Dict[str, int] = {}
            lay_of = np.zeros(n, dtype=np.int64)
            for i, op in enumerate(graph.ops):
                li = lay_index.setdefault(op.layer, len(lay_index))
                lay_of[i] = li
                if op.coll is not None:
                    is_coll[i] = True
                    wire[i] = ring_bytes_on_wire(op.coll)
                    pod[i] = op.coll.axis == "pod"
                else:
                    flops[i] = op.flops
                    hbm[i] = op.total_bytes
                    matrix[i] = op.matrix
            arrs = (flops, hbm, wire, pod, matrix, is_coll, lay_of,
                    list(lay_index))
            graph._shared["roofline_arrays"] = arrs
        return arrs

    def estimate_many(self, graphs: List[CompiledGraph],
                      workers: int = 1) -> List[EstimateReport]:
        """Vectorized sweep: all variants share one op structure, so the
        per-op footprints are computed once and every variant is a few
        numpy reductions over (rates-per-variant x ops)."""
        graphs = list(graphs)
        if len(graphs) < 2 or any(g.ops is not graphs[0].ops
                                  for g in graphs):
            return super().estimate_many(graphs, workers)
        t0 = time.perf_counter()
        (flops, hbm, wire, pod, matrix, is_coll, lay_of,
         lay_names) = self._op_arrays(graphs[0])
        n_layers = len(lay_names)
        out = []
        for graph in graphs:
            rates = rate_table(graph.system, graph.plan)
            dt_c = flops / np.where(matrix, rates["matrix"], rates["vector"])
            dt_m = hbm / rates["mem"]
            dt_i = wire / np.where(pod, rates["dcn"], rates["ici"])
            t_c = float(dt_c.sum())
            t_m = float(dt_m.sum())
            t_i = float(dt_i.sum())
            contrib = np.where(is_coll, dt_i, np.maximum(dt_c, dt_m))
            lay_t = np.bincount(lay_of, weights=contrib, minlength=n_layers)
            per_layer = dict(zip(lay_names, lay_t.tolist()))
            step = max(t_c, t_m, t_i)
            out.append(EstimateReport(
                system=graph.system.name, backend=self.name, step_time=step,
                t_compute=t_c, t_memory=t_m, t_collective=t_i,
                nce_util=t_c / step if step > 0 else 0.0,
                dma_util=t_m / step if step > 0 else 0.0,
                ici_util=t_i / step if step > 0 else 0.0,
                layers=layer_reports(graph, per_layer),
                build_seconds=0.0, estimate_seconds=0.0,
                n_tasks=len(graph.tasks)))
        dt = (time.perf_counter() - t0) / len(graphs)
        for rep in out:
            rep.estimate_seconds = dt
        return out
