"""XLA artifact adapter: compiled-HLO parsing for roofline terms.

``compiled.cost_analysis()`` visits a ``while`` body ONCE, so scan-over-
layers programs (all of ours) would be undercounted by the layer count.
This module walks the HLO text recursively instead:

  * FLOPs: every ``dot``/``convolution`` (2 * prod(out) * contracted dims),
    including inside fused computations, multiplied by enclosing
    ``known_trip_count`` factors;
  * HBM bytes (estimate): per top-level instruction, operand + output sizes
    (fusion internals excluded — they stay in registers/VMEM);
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (sync or async -start),
    scaled by trip counts, bucketed by kind.

Validated against cost_analysis() on unrolled graphs (tests/test_hlo.py).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# header params may contain nested parens (tuple-typed scan carries)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"[\{]?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)[\}]?")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_NAME_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?))")


@dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    operands_str: str
    attrs: str

    def operand_names(self) -> List[str]:
        return _NAME_RE.findall(self.operands_str)

    def out_bytes(self) -> int:
        return shape_bytes(self.out_type)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)  # name -> type str

    def type_of(self, name: str) -> str:
        return self.symtab.get(name, "")

    def operand_bytes(self, ins: Instr) -> int:
        inline = shape_bytes(ins.operands_str)
        if inline:
            return inline
        return sum(shape_bytes(self.type_of(n)) for n in ins.operand_names())

    def operand_shapes(self, ins: Instr) -> List[Tuple[str, str]]:
        inline = _SHAPE_RE.findall(ins.operands_str)
        if inline:
            return inline
        out: List[Tuple[str, str]] = []
        for n in ins.operand_names():
            out.extend(_SHAPE_RE.findall(self.type_of(n)))
        return out


_OPCODE_RE = re.compile(
    r"^([a-z0-9\-]+)(?:\()")


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # rest: "bf16[2,4]{1,0} opcode(operands...), attrs"
    # find the opcode: first token after the type that looks like `op(`
    tm = re.match(r"^(\([^)]*\)|[\w\[\]\{\},\.\/ ]+?)\s+([a-z0-9\-]+)\(", rest)
    if not tm:
        return None
    out_type, opcode = tm.group(1), tm.group(2)
    body = rest[tm.end() - 1:]
    # operands: up to matching close paren
    depth = 0
    end = 0
    for i, ch in enumerate(body):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = body[1:end] if end else ""
    attrs = body[end + 1:] if end else ""
    return Instr(name=name, opcode=opcode, out_type=out_type,
                 operands_str=operands, attrs=attrs)


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        hdr = _COMP_HDR_RE.match(stripped)
        if hdr and stripped.endswith("{"):
            current = Computation(name=hdr.group(1))
            comps[current.name] = current
            # header parameters carry types: "(p0: f32[2,3], p1: ...)"
            for pname, ptype in _PARAM_RE.findall(stripped):
                current.symtab[pname] = ptype
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            ins = _parse_instr(stripped)
            if ins is not None:
                current.instrs.append(ins)
                current.symtab[ins.name] = ins.out_type
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 * prod(out dims) * prod(contracted dims)."""
    out_elems = 1
    m = _SHAPE_RE.search(ins.out_type)
    if not m:
        return 0.0
    for d in m.group(2).split(","):
        if d:
            out_elems *= int(d)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    shapes = comp.operand_shapes(ins)
    if not cm or not shapes:
        return 2.0 * out_elems     # fallback: unknown K
    lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    k = 1
    for ci in cm.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(ins.out_type)
    if m:
        for d in m.group(2).split(","):
            if d:
                out_elems *= int(d)
    shapes = comp.operand_shapes(ins)
    if len(shapes) < 2:
        return 2.0 * out_elems
    rhs_dims = [int(d) for d in shapes[1][1].split(",") if d]
    # kernel spatial * input features: everything except output-feature dim.
    # dim labels from dnums attr are fiddly; approximate with prod(rhs)/max_dim
    if rhs_dims:
        k = 1
        for d in rhs_dims:
            k *= d
        k //= max(rhs_dims)        # divide out the output-feature dim
        return 2.0 * out_elems * max(k, 1)
    return 2.0 * out_elems


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_bytes_f32: float = 0.0
    collective_count: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "while", "conditional", "call",
}


def analyze_hlo(hlo_text: str, entry: Optional[str] = None) -> HloCost:
    comps = parse_computations(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))

    memo: Dict[str, HloCost] = {}

    def fused_flops(comp_name: str) -> float:
        """Dot/conv FLOPs anywhere inside a fused computation."""
        c = comps.get(comp_name)
        if c is None:
            return 0.0
        f = 0.0
        for ins in c.instrs:
            if ins.opcode == "dot":
                f += _dot_flops(ins, c)
            elif ins.opcode == "convolution":
                f += _conv_flops(ins, c)
            elif ins.opcode == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if cm:
                    f += fused_flops(cm.group(1))
        return f

    def _stacked_discount(t: str, body_trips: int) -> int:
        """Bytes of one type, discounted if it is a stacked scan buffer
        (leading dim == trip count): each iteration touches one slice."""
        b = shape_bytes(t)
        if body_trips > 1:
            m = _SHAPE_RE.search(t)
            if m:
                dims = [int(d) for d in m.group(2).split(",") if d]
                if dims and dims[0] == body_trips:
                    b //= body_trips
        return b

    def fusion_bytes(c: Computation, ins: Instr, body_trips: int) -> int:
        """Fusion HBM traffic.  Inside a while body (scan), operands/outputs
        whose leading dim equals the trip count are *stacked xs/ys* — each
        iteration reads/writes one slice (the slicing/DUS happens inside
        the fusion)."""
        total = _stacked_discount(ins.out_type, body_trips)
        for nm in ins.operand_names():
            total += _stacked_discount(c.type_of(nm), body_trips)
        return total

    def walk(comp_name: str, body_trips: int = 1) -> HloCost:
        if comp_name in memo:
            return memo[comp_name]
        cost = HloCost()
        memo[comp_name] = cost       # cycle guard
        c = comps.get(comp_name)
        if c is None:
            return cost
        for ins in c.instrs:
            op = ins.opcode
            base_kind = op.replace("-start", "")
            if base_kind in COLLECTIVE_KINDS:
                b = c.operand_bytes(ins)
                cost.collective_bytes[base_kind] += b
                # f32 collective payloads are CPU-legalization artifacts for
                # bf16 models (TPU reduces the bf16 dot outputs directly);
                # track them so the roofline can report a TPU-adjusted term.
                if "f32[" in (ins.operands_str + c.type_of(
                        (ins.operand_names() or [""])[0])):
                    cost.collective_bytes_f32 += b
                cost.collective_count += 1
                cost.hbm_bytes += b + ins.out_bytes()
                continue
            if op == "while":
                cm = _CALL_ATTR_RE.findall(ins.attrs)
                trip_m = _TRIP_RE.search(ins.attrs)
                trips = int(trip_m.group(1)) if trip_m else 1
                body_re = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cond_re = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                if body_re:
                    sub = walk(body_re.group(1), body_trips=trips)
                    cost.flops += sub.flops * trips
                    cost.hbm_bytes += sub.hbm_bytes * trips
                    for k, v in sub.collective_bytes.items():
                        cost.collective_bytes[k] += v * trips
                    cost.collective_bytes_f32 += sub.collective_bytes_f32 * trips
                    cost.collective_count += sub.collective_count * trips
                if cond_re:
                    walk(cond_re.group(1))   # negligible; evaluated for memo
                continue
            if op in ("call", "conditional"):
                cm = re.search(r"(?:to_apply|branch_computations)="
                               r"[\{]?%?([\w\.\-]+)", ins.attrs)
                if cm:
                    sub = walk(cm.group(1))
                    cost.flops += sub.flops
                    cost.hbm_bytes += sub.hbm_bytes
                    for k, v in sub.collective_bytes.items():
                        cost.collective_bytes[k] += v
                    cost.collective_count += sub.collective_count
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if cm:
                    cost.flops += fused_flops(cm.group(1))
                # XLA:CPU wraps nearly every elementwise op in its own
                # trivial kLoop fusion ("wrapped_*"); a TPU build fuses those
                # into neighbours, so counting their traffic would overstate
                # HBM bytes ~40x.  Count only real multi-op fusions.
                if not ins.name.startswith(("wrapped_", "convert")):
                    cost.hbm_bytes += fusion_bytes(c, ins, body_trips)
                continue
            if op == "dot":
                cost.flops += _dot_flops(ins, c)
                cost.hbm_bytes += c.operand_bytes(ins) + ins.out_bytes()
                continue
            if op == "convolution":
                cost.flops += _conv_flops(ins, c)
                cost.hbm_bytes += c.operand_bytes(ins) + ins.out_bytes()
                continue
            if op == "custom-call":
                cost.hbm_bytes += c.operand_bytes(ins) + ins.out_bytes()
                continue
            if op in _SKIP_BYTES_OPS or op.endswith("-done"):
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the slice, not the full operand
                cost.hbm_bytes += 2 * ins.out_bytes()
                continue
            if op == "dynamic-update-slice":
                names = ins.operand_names()
                upd = shape_bytes(c.type_of(names[1])) if len(names) > 1 else 0
                cost.hbm_bytes += 2 * upd
                continue
            # Everything else (convert/copy/broadcast/transpose/elementwise/
            # reduce) fuses into neighbours on TPU: counting it would model
            # XLA:CPU's fusion granularity, not the target's.  Skipped.
        return cost

    total = walk(entry)
    # normalize defaultdict for stable serialisation
    total.collective_bytes = dict(total.collective_bytes)
    return total


def top_contributors(hlo_text: str, k: int = 20,
                     metric: str = "bytes") -> List[Tuple[float, int, str, str, str]]:
    """Top-k (value, trips, computation, opcode, name) contributors to HBM
    bytes or FLOPs — the dry-run 'profile' used by the perf iteration loop."""
    comps = parse_computations(hlo_text)
    trips: Dict[str, int] = {}
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "while":
                m = _TRIP_RE.search(ins.attrs)
                b = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                if b:
                    trips[b.group(1)] = int(m.group(1)) if m else 1
    # propagate nesting (wide loops): one level is enough for our scans
    rows = []
    for cname, c in comps.items():
        mult = trips.get(cname, 1)
        for ins in c.instrs:
            if metric == "bytes":
                if ins.opcode in ("dot", "convolution", "custom-call"):
                    val = c.operand_bytes(ins) + ins.out_bytes()
                elif ins.opcode == "fusion" and not ins.name.startswith(
                        ("wrapped_", "convert")):
                    val = c.operand_bytes(ins) + ins.out_bytes()
                elif ins.opcode.replace("-start", "") in COLLECTIVE_KINDS:
                    val = c.operand_bytes(ins) + ins.out_bytes()
                else:
                    continue
            else:
                if ins.opcode == "dot":
                    val = _dot_flops(ins, c)
                elif ins.opcode == "convolution":
                    val = _conv_flops(ins, c)
                else:
                    continue
            rows.append((val * mult, mult, cname, ins.opcode,
                         ins.name + " " + ins.out_type[:40]))
    rows.sort(reverse=True)
    return rows[:k]


def analyze_compiled(compiled) -> Dict[str, float]:
    """Full report for a jax ``compiled`` object (dry-run artifact)."""
    text = compiled.as_text()
    cost = analyze_hlo(text)
    ca = {}
    try:
        ca = compiled.cost_analysis() or {}
        # older jax returns a one-element list of per-program dicts
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
    except Exception:
        pass
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                           + getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception:
        pass
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.total_collective_bytes,
        "collective_bytes_f32": cost.collective_bytes_f32,
        "collective_bytes_tpu_adjusted": cost.total_collective_bytes
        - 0.5 * cost.collective_bytes_f32,
        "collective_breakdown": cost.collective_bytes,
        "collective_count": cost.collective_count,
        "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
        "xla_bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        **mem,
    }
