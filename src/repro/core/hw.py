"""Virtual hardware component library — the paper's "system description file".

A :class:`SystemDescription` is the AVSM analog of the paper's Figure 2: a
topology of non-functional virtual hardware models (compute engines, memories,
DMA engines, interconnect links) plus physical annotations (frequencies,
bandwidths).  The model-generation engine (``repro.core.avsm``) turns a
SystemDescription + a hardware-adapted task graph into an executable
discrete-event model.

Built-in descriptions:
  * ``tpu_v5e_chip`` / ``tpu_v5e_pod``   — the TPU target of this repro
  * ``virtex7_nce_system``              — the paper's FPGA prototype
    (NCE with a 32x64 multiplier array @ 250 MHz, Fig 2 / Section 3)
  * ``container_cpu_system``            — this container's CPU, calibrated by
    microbenchmark; serves as the *physical prototype* for the Fig-5-style
    accuracy validation.
"""
from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Component models (all non-functional: timing + transactions only)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ComputeEngineModel:
    """A matrix/vector compute engine (NCE in the paper, MXU+VPU on TPU)."""

    name: str = "nce"
    # peak MACs/s for the matrix unit (1 MAC = 2 FLOPs)
    matrix_flops: float = 197e12        # bf16 FLOP/s
    vector_flops: float = 4e12          # elementwise FLOP/s
    # dims must be multiples of `align` for full efficiency; misaligned tiles
    # are padded (paper: arrangement of the multiplier array)
    align: int = 128
    # fixed per-task launch overhead, seconds (HKP dispatch / XLA op launch)
    launch_overhead: float = 1.2e-6
    dtype_scale: Dict[str, float] = field(
        default_factory=lambda: {"bfloat16": 1.0, "float32": 0.5, "int8": 2.0}
    )

    def flops_for(self, dtype: str, matrix: bool = True) -> float:
        base = self.matrix_flops if matrix else self.vector_flops
        return base * self.dtype_scale.get(dtype, 1.0)


@dataclass(frozen=True)
class MemoryModel:
    """External memory + DMA (HBM on TPU, DDR on the FPGA prototype)."""

    name: str = "hbm"
    bandwidth: float = 819e9            # bytes/s
    latency: float = 1.0e-6             # per-transaction latency, seconds
    capacity: int = 16 * 1024**3        # bytes
    num_dma_engines: int = 2            # concurrent outstanding DMA streams


@dataclass(frozen=True)
class OnChipMemoryModel:
    """Scratchpad the compiler tiles against (VMEM on TPU, BRAM on FPGA)."""

    name: str = "vmem"
    capacity: int = 128 * 1024**2       # bytes
    bandwidth: float = 8e12             # effectively not the bottleneck


@dataclass(frozen=True)
class LinkModel:
    """One interconnect link (ICI on TPU; the AXI bus on the FPGA)."""

    name: str = "ici"
    bandwidth: float = 50e9             # bytes/s per direction per link
    latency: float = 1.0e-6


@dataclass(frozen=True)
class ChipModel:
    """One chip: compute + memory hierarchy + links to neighbours."""

    name: str = "tpu_v5e"
    compute: ComputeEngineModel = field(default_factory=ComputeEngineModel)
    memory: MemoryModel = field(default_factory=MemoryModel)
    onchip: OnChipMemoryModel = field(default_factory=OnChipMemoryModel)
    link: LinkModel = field(default_factory=LinkModel)
    num_links: int = 4                  # 2-D torus: +x, -x, +y, -y


@dataclass(frozen=True)
class SystemDescription:
    """Topology + physical annotations (the paper's system description file)."""

    name: str = "tpu_v5e_pod"
    chip: ChipModel = field(default_factory=ChipModel)
    # torus dims inside a pod; () => single chip
    torus: Tuple[int, ...] = (16, 16)
    num_pods: int = 1
    # data-center network between pods
    dcn_bandwidth: float = 25e9         # bytes/s per host
    dcn_latency: float = 10e-6

    @property
    def num_chips(self) -> int:
        n = 1
        for t in self.torus:
            n *= t
        return n * self.num_pods

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @staticmethod
    def from_json(text: str) -> "SystemDescription":
        return load_dataclass(SystemDescription, json.loads(text))


def _coerce(tp, val):
    """Coerce a JSON value to the annotated field type (nested dataclasses,
    tuples, numeric widening); unknown shapes pass through unchanged."""
    if dataclasses.is_dataclass(tp):
        return load_dataclass(tp, val)     # raises on non-dict values
    origin = typing.get_origin(tp)
    if origin is tuple and isinstance(val, (list, tuple)):
        args = typing.get_args(tp)
        elem = args[0] if args and args[-1] is Ellipsis else None
        return tuple(_coerce(elem, v) if elem is not None else v for v in val)
    if origin is dict and isinstance(val, dict):
        return dict(val)
    if tp is float and isinstance(val, int):
        return float(val)
    return val


def load_dataclass(cls, data: Dict):
    """Hand-rolled nested-dataclass loader (replaces the dacite dependency).

    Ignores unknown keys and missing fields (defaults apply), recursing
    into dataclass-typed fields — exactly the subset ``from_json`` needs.
    """
    if not isinstance(data, dict):
        raise TypeError(f"expected a dict for {cls.__name__}, got "
                        f"{type(data).__name__}")
    hints = typing.get_type_hints(cls)
    kwargs = {f.name: _coerce(hints[f.name], data[f.name])
              for f in dataclasses.fields(cls) if f.name in data}
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Built-in system descriptions
# ---------------------------------------------------------------------------

# TPU v5e hardware constants — the assignment's grading constants:
#   197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E_PEAK_FLOPS = 197e12
TPU_V5E_HBM_BW = 819e9
TPU_V5E_ICI_BW = 50e9
TPU_V5E_HBM_BYTES = 16 * 1024**3
TPU_V5E_VMEM_BYTES = 128 * 1024**2


def tpu_v5e_chip() -> ChipModel:
    return ChipModel(
        name="tpu_v5e",
        compute=ComputeEngineModel(
            name="mxu",
            matrix_flops=TPU_V5E_PEAK_FLOPS,
            vector_flops=3.94e12,        # 8 VPU lanes ~ peak/50
            align=128,
            launch_overhead=1.2e-6,
        ),
        memory=MemoryModel(
            name="hbm", bandwidth=TPU_V5E_HBM_BW, latency=1.0e-6,
            capacity=TPU_V5E_HBM_BYTES, num_dma_engines=2,
        ),
        onchip=OnChipMemoryModel(name="vmem", capacity=TPU_V5E_VMEM_BYTES),
        link=LinkModel(name="ici", bandwidth=TPU_V5E_ICI_BW, latency=1.0e-6),
        num_links=4,
    )


def tpu_v5e_pod(torus: Tuple[int, int] = (16, 16), num_pods: int = 1) -> SystemDescription:
    return SystemDescription(
        name=f"tpu_v5e_{'x'.join(map(str, torus))}" + (f"_{num_pods}pods" if num_pods > 1 else ""),
        chip=tpu_v5e_chip(),
        torus=torus,
        num_pods=num_pods,
    )


def virtex7_nce_system() -> SystemDescription:
    """The paper's physical prototype (Section 3):

    Xilinx Virtex-7, NCE with a 32x64 multiplier array @ 250 MHz
    => 32*64 MACs * 250 MHz * 2 FLOP/MAC = 1.024 TFLOP/s peak.
    DDR3-class external memory behind an AXI interconnect; the paper does not
    print the memory bandwidth, we annotate 12.8 GB/s (DDR3-1600, 64-bit) —
    a documented assumption, revisit with the [4] prototype details.
    """
    return SystemDescription(
        name="virtex7_nce",
        chip=ChipModel(
            name="virtex7",
            compute=ComputeEngineModel(
                name="nce_32x64",
                matrix_flops=32 * 64 * 250e6 * 2,   # 1.024 TFLOP/s
                vector_flops=64 * 250e6 * 2,
                align=32,                            # array rows
                launch_overhead=2.0e-6,              # HKP dispatch per task
                dtype_scale={"int8": 1.0, "bfloat16": 1.0, "float32": 0.5,
                             "int16": 1.0},
            ),
            memory=MemoryModel(
                name="ddr3", bandwidth=12.8e9, latency=0.3e-6,
                capacity=4 * 1024**3, num_dma_engines=1,
            ),
            onchip=OnChipMemoryModel(name="bram", capacity=4 * 1024**2),
            link=LinkModel(name="axi", bandwidth=8e9, latency=0.2e-6),
            num_links=1,
        ),
        torus=(),
    )


def container_cpu_system(
    flops: float = 5e10, mem_bw: float = 1.2e10, launch_overhead: float = 15e-6
) -> SystemDescription:
    """Virtual model of this container's CPU (the 'physical prototype' we can
    actually measure).  Default annotations are placeholders; the calibration
    benchmark (`benchmarks/bench_accuracy.py`) measures achieved GEMM FLOP/s
    and STREAM-style bandwidth and re-annotates this description — the
    paper's top-down 'import physical annotations' step.
    """
    return SystemDescription(
        name="container_cpu",
        chip=ChipModel(
            name="cpu",
            compute=ComputeEngineModel(
                name="cpu_fma",
                matrix_flops=flops,
                vector_flops=flops / 4,
                align=8,
                launch_overhead=launch_overhead,
                dtype_scale={"float32": 1.0, "bfloat16": 0.9, "int8": 1.0},
            ),
            memory=MemoryModel(
                name="dram", bandwidth=mem_bw, latency=0.1e-6,
                capacity=8 * 1024**3, num_dma_engines=1,
            ),
            onchip=OnChipMemoryModel(name="llc", capacity=24 * 1024**2),
            link=LinkModel(name="none", bandwidth=1e12, latency=0.0),
            num_links=0,
        ),
        torus=(),
    )


BUILTIN_SYSTEMS = {
    "tpu_v5e_pod": lambda: tpu_v5e_pod((16, 16), 1),
    "tpu_v5e_multipod": lambda: tpu_v5e_pod((16, 16), 2),
    "virtex7_nce": virtex7_nce_system,
    "container_cpu": container_cpu_system,
}


def get_system(name: str) -> SystemDescription:
    if name not in BUILTIN_SYSTEMS:
        raise KeyError(f"unknown system {name!r}; available: {sorted(BUILTIN_SYSTEMS)}")
    return BUILTIN_SYSTEMS[name]()
