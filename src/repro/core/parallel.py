"""Deterministic process-parallel map for sweep fan-out.

The DSE and serving sweeps are embarrassingly parallel — every point is an
independent, seeded simulation — but the payloads (explorers with compiled
graph caches, cost-model builders, lambda scheduler factories) are not
picklable.  ``parallel_map`` therefore uses the fork start method: the
work function and item list are stashed in a module global *before* the
pool forks, children inherit them by memory copy, and only the item
*index* crosses the process boundary.  Results come back pickled in item
order, so output is deterministic and bit-identical to a serial run
(each item's computation is self-contained and seeded).

Falls back to a serial map when ``workers <= 1``, when fork is
unavailable (non-POSIX platforms), or when the pool fails for any reason
— parallelism is a pure accelerator, never a semantic change.

Constraint: the work function must not call into multithreaded native
runtimes (JAX/XLA) inside the child — forked children inherit the
parent's thread state without its threads.  The sweep workloads here are
pure-Python/numpy simulations, which is why the fork warning CPython
emits when JAX is merely *imported* in the parent is suppressed.
"""
from __future__ import annotations

import warnings
from typing import Callable, List, Sequence, TypeVar

T = TypeVar("T")
R = TypeVar("R")

# (fn, items) visible to forked children; only valid while a pool is live.
_PAYLOAD = None


def _call_indexed(i: int):
    fn, items = _PAYLOAD
    return fn(items[i])


def parallel_map(fn: Callable[[T], R], items: Sequence[T],
                 workers: int = 1) -> List[R]:
    """``[fn(x) for x in items]``, fanned out over ``workers`` forked
    processes when ``workers > 1``.  ``fn``'s return values must be
    picklable; ``fn`` and the items themselves need not be."""
    items = list(items)
    if workers <= 1 or len(items) <= 1:
        return [fn(x) for x in items]
    try:
        import multiprocessing as mp

        ctx = mp.get_context("fork")
    except (ImportError, ValueError):        # platform without fork
        return [fn(x) for x in items]
    global _PAYLOAD
    if _PAYLOAD is not None:                 # no nested pools
        return [fn(x) for x in items]
    _PAYLOAD = (fn, items)
    try:
        from concurrent.futures import ProcessPoolExecutor

        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*os.fork.*", category=RuntimeWarning)
            with ProcessPoolExecutor(max_workers=min(workers, len(items)),
                                     mp_context=ctx) as pool:
                return list(pool.map(_call_indexed, range(len(items))))
    except Exception:                        # pool/pickling failure
        return [fn(x) for x in items]
    finally:
        _PAYLOAD = None
