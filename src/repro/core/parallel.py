"""Deterministic process-parallel map for sweep fan-out.

The DSE and serving sweeps are embarrassingly parallel — every point is an
independent, seeded simulation — and a sweep-heavy ``explore()`` loop
calls :func:`parallel_map` many times in quick succession.  Three
execution paths, fastest first:

  * **Persistent worker pool** (:class:`WorkerPool`) — ``workers`` forked
    processes spawned lazily on first use and *reused across calls*, so
    the ~0.5 s per-call pool startup of the legacy path is paid once per
    process instead of once per sweep.  Jobs cross the process boundary
    pickled: the work function (by qualified name) and an optional
    ``common`` payload are broadcast once per map, then items stream to
    workers one-in-flight each and results stream back in index order
    (large results via ``/dev/shm`` shared-memory files rather than the
    pipe — see :func:`_ship_result`).  At most a couple of pools stay
    alive at once; distinct ``workers`` counts evict LRU-style.
    Requires ``fn``/``common``/items to be picklable — module-level
    functions with explicit arguments, which is how ``repro.core.dse``
    and the estimator backends submit their work.
  * **Legacy per-call fork pool** — for unpicklable payloads (closures,
    lambda factories): the function and item list are stashed in a module
    global *before* the pool forks, children inherit them by memory copy,
    and only the item index crosses the boundary.
  * **Serial** — ``workers <= 1``, single item, platforms without fork,
    or any pool failure.

All paths return results in item order, computed by pure seeded
functions, so output is deterministic and bit-identical to a serial run.

Failure containment: if a pool worker dies mid-map (killed, OOM, crashed
native code), the parent sees EOF on the result pipe instead of hanging,
disposes the pool, and finishes the remaining items serially —
parallelism is a pure accelerator, never a semantic change.  Workers set
``REPRO_POOL_WORKER=1`` in their environment, and nested ``parallel_map``
calls inside a worker run serially.

Constraint: the work function must not call into multithreaded native
runtimes (JAX/XLA) inside a child — forked children inherit the parent's
thread state without its threads.  The sweep workloads here are
pure-Python/numpy simulations, which is why the fork warning CPython
emits when JAX is merely *imported* in the parent is suppressed.
"""
from __future__ import annotations

import atexit
import os
import pickle
import selectors
import signal
import sys
import tempfile
import warnings
from time import perf_counter, sleep
from typing import Callable, Dict, List, Optional, Sequence, TypeVar

T = TypeVar("T")
R = TypeVar("R")

#: set in pool workers' environments; lets work functions (and tests)
#: detect that they run inside a forked worker.
WORKER_ENV = "REPRO_POOL_WORKER"

#: per-map scratch space for work functions running inside a pool worker
#: (e.g. an attached shared-memory duration matrix); cleared when the
#: worker receives the next map's broadcast.
WORKER_STATE: Dict = {}

#: sticky per-process object store: :func:`ensure_shared` broadcasts a
#: heavy payload (e.g. a structural compiled graph) to every pool worker
#: *once*; subsequent maps ship only a small key per item.  The parent
#: keeps the object too (by reference, no copy), so a serial fallback
#: resolves the same keys.  Lives until the pool is closed.
WORKER_STORE: Dict = {}

_PICKLE_PROTO = pickle.HIGHEST_PROTOCOL

#: results whose pickle exceeds this ship as a shared-memory file
#: (``/dev/shm``) instead of streaming through the result pipe — large
#: sweep reports (per-request metric columns, event traces) transfer at
#: memcpy speed and never stall the pipe's ~64 KiB kernel buffer.
_SHM_MIN_BYTES = 1 << 18
_SHM_DIR = "/dev/shm"


class _Unpicklable(Exception):
    """The payload cannot cross a persistent-pool pipe."""


def _active_probe():
    """The process-global ``repro.obs`` probe, if observability is both
    *imported* and *enabled* — resolved through ``sys.modules`` so this
    module never imports ``repro.obs`` itself (the pool must stay
    dependency-free for uninstrumented runs and forked workers)."""
    mod = sys.modules.get("repro.obs.probe")
    return mod.get_probe() if mod is not None else None


def _serial(fn, items, common) -> List:
    if common is None:
        return [fn(x) for x in items]
    return [fn(common, x) for x in items]


def _ship_result(out, res_f) -> None:
    """Send one ("ok" | "err", index, value) response: small pickles go
    down the pipe, large ones via an unlinked-after-read ``/dev/shm``
    file referenced by a ("shm", index, path) message.  Falls back to
    the pipe if the shared-memory write fails."""
    try:
        blob = pickle.dumps(out, protocol=_PICKLE_PROTO)
    except Exception as e:                      # unpicklable result
        blob = pickle.dumps(("err", out[1], repr(e)),
                            protocol=_PICKLE_PROTO)
    if len(blob) >= _SHM_MIN_BYTES and os.path.isdir(_SHM_DIR):
        path = None
        try:
            fd, path = tempfile.mkstemp(prefix="repro-pool-", dir=_SHM_DIR)
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            pickle.dump(("shm", out[1], path), res_f,
                        protocol=_PICKLE_PROTO)
            res_f.flush()
            return
        except Exception:
            if path is not None:
                try:
                    os.unlink(path)
                except OSError:
                    pass
    res_f.write(blob)
    res_f.flush()


def _load_result(res_f):
    """Parent-side twin of :func:`_ship_result`: resolve a ("shm", ...)
    indirection (read + unlink the file) into the plain response."""
    msg = pickle.load(res_f)
    if msg[0] != "shm":
        return msg
    _, idx, path = msg
    try:
        with open(path, "rb") as f:
            blob = f.read()
    finally:
        try:
            os.unlink(path)
        except OSError:
            pass
    prb = _active_probe()
    if prb is not None:
        prb.counter("pool/shm_bytes", unit="bytes").add(
            prb.elapsed(), len(blob))
        prb.counter("pool/shm_results").add(prb.elapsed())
    return pickle.loads(blob)


def _worker_loop(job_f, res_f) -> None:
    """Child main loop: consume (begin | item | quit) messages, stream
    ("ok" | "err", index, value) responses (large values via
    :func:`_ship_result`'s shared-memory path)."""
    fn = common = None
    while True:
        try:
            msg = pickle.load(job_f)
        except (EOFError, OSError):
            return
        tag = msg[0]
        if tag == "begin":
            _, fn, common = msg
            WORKER_STATE.clear()
        elif tag == "store":
            _, key, payload = msg
            WORKER_STORE[key] = payload
        elif tag == "item":
            _, idx, item = msg
            try:
                val = fn(item) if common is None else fn(common, item)
                out = ("ok", idx, val)
            except BaseException as e:          # noqa: BLE001
                out = ("err", idx, repr(e))
            _ship_result(out, res_f)
        else:                                   # "quit"
            return


class _WorkerFailure(Exception):
    """A worker died or a job failed inside it."""


class PoolTimeout(Exception):
    """A job exceeded the pool's ``job_timeout`` on every allowed attempt.

    Deliberately *not* swallowed by :func:`parallel_map`'s serial
    fallback: re-running a hung job in the parent would hang the parent —
    the one failure mode the timeout exists to prevent."""


class WorkerPool:
    """Persistent fork-based worker pool (see the module docstring).

    Lifecycle: construction is free; ``workers`` processes fork lazily on
    the first :meth:`map` and are reused by every subsequent call until
    :meth:`close` (or interpreter exit — an ``atexit`` hook closes the
    module-level pools).  A pool that loses a worker marks itself
    ``broken``; :func:`get_pool` then replaces it transparently.

    Hardening (fault-injection serving runs fan out through this pool, so
    it gets the same resilience treatment as the fleet it simulates):

    * ``job_timeout`` (seconds per job) arms a liveness check — the
      result-pipe select doubles as the heartbeat, so a worker that
      neither answers nor dies is detected, SIGKILLed, reaped, and
      replaced by a freshly forked worker that replays the pool's
      ``begin`` payload and every broadcast store key;
    * a timed-out or crashed job is retried on the fresh worker up to
      ``job_retries`` times, after ``retry_backoff * 2**(attempt-1)``
      seconds;
    * a job that exhausts its retries is *quarantined*: a repeat crasher
      runs once serially in the parent (surfacing a genuine error exactly
      as a serial run would), while a repeat hanger aborts the map with
      :class:`PoolTimeout` — the parent must never run it inline.

    Probe counters: ``pool/timeouts``, ``pool/retries``,
    ``pool/respawns``, ``pool/quarantined``.
    """

    def __init__(self, workers: int, job_timeout: Optional[float] = None,
                 job_retries: int = 1, retry_backoff: float = 0.05):
        if workers < 2:
            raise ValueError("a pool needs workers >= 2")
        if job_timeout is not None and job_timeout <= 0:
            raise ValueError("job_timeout must be > 0 (or None)")
        if job_retries < 0 or retry_backoff < 0:
            raise ValueError("need job_retries >= 0 and retry_backoff >= 0")
        self.workers = workers
        self.job_timeout = job_timeout
        self.job_retries = job_retries
        self.retry_backoff = retry_backoff
        self.broken = False
        self._procs: List[List] = []    # [pid, job file(w), result file(r)]
        self._stored: Dict = {}         # key -> pickled store blob

    @property
    def spawned(self) -> bool:
        return bool(self._procs)

    @property
    def pids(self) -> List[int]:
        return [p[0] for p in self._procs]

    def _fork_one(self) -> List:
        """Fork one worker; returns its ``[pid, job file, result file]``."""
        job_r, job_w = os.pipe()
        res_r, res_w = os.pipe()
        pid = os.fork()
        if pid == 0:                        # ---- child ----
            try:
                os.close(job_w)
                os.close(res_r)
                # drop inherited ends of the other workers' pipes so
                # their EOF-based shutdown still works (a respawn may
                # inherit already-closed files — ignore those)
                for p in self._procs:
                    for fobj in (p[1], p[2]):
                        try:
                            fobj.close()
                        except Exception:
                            pass
                os.environ[WORKER_ENV] = "1"
                _worker_loop(os.fdopen(job_r, "rb"),
                             os.fdopen(res_w, "wb"))
            finally:
                os._exit(0)
        os.close(job_r)                     # ---- parent ----
        os.close(res_w)
        return [pid, os.fdopen(job_w, "wb"), os.fdopen(res_r, "rb")]

    def _spawn(self) -> None:
        prb = _active_probe()
        t0 = perf_counter() if prb is not None else 0.0
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*fork.*", category=DeprecationWarning)
            warnings.filterwarnings(
                "ignore", message=".*os.fork.*", category=RuntimeWarning)
            for _ in range(self.workers):
                self._procs.append(self._fork_one())
        if prb is not None:                     # children never reach here
            prb.histogram("pool/spawn_seconds", unit="s").observe(
                perf_counter() - t0)
            prb.counter("pool/forks").add(prb.elapsed(), self.workers)

    def _kill_worker(self, w: int) -> None:
        """SIGKILL and reap worker ``w`` (its files are closed first so a
        blocked write in the child cannot outlive the reap)."""
        pid, job_f, res_f = self._procs[w]
        for fobj in (job_f, res_f):
            try:
                fobj.close()
            except Exception:
                pass
        try:
            os.kill(pid, signal.SIGKILL)
        except (ProcessLookupError, OSError):
            pass
        try:
            os.waitpid(pid, 0)
        except (ChildProcessError, OSError):
            pass

    def _replace_worker(self, w: int, begin: bytes) -> None:
        """Fork a replacement into slot ``w`` and replay the session
        state it missed: every broadcast store key, then the current
        map's ``begin`` payload."""
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*fork.*", category=DeprecationWarning)
            warnings.filterwarnings(
                "ignore", message=".*os.fork.*", category=RuntimeWarning)
            self._procs[w] = self._fork_one()
        job_f = self._procs[w][1]
        for blob in self._stored.values():
            job_f.write(blob)
        job_f.write(begin)
        job_f.flush()
        prb = _active_probe()
        if prb is not None:
            prb.counter("pool/respawns").add(prb.elapsed())

    def ensure(self, key, payload) -> None:
        """Broadcast ``payload`` under ``key`` to every worker, once per
        pool lifetime (pipes are FIFO, so a later map's items may safely
        reference the key).  Raises :class:`_Unpicklable` if it cannot be
        shipped."""
        if key in self._stored:
            return
        try:
            blob = pickle.dumps(("store", key, payload),
                                protocol=_PICKLE_PROTO)
        except Exception as e:
            raise _Unpicklable(str(e)) from e
        prb = _active_probe()
        t0 = perf_counter() if prb is not None else 0.0
        if not self._procs:
            self._spawn()
        try:
            for _, job_f, _ in self._procs:
                job_f.write(blob)
                job_f.flush()
        except Exception:
            self.broken = True
            self.close()
            raise _WorkerFailure("broadcast failed")
        self._stored[key] = blob    # kept for respawned-worker replay
        if prb is not None:
            prb.counter("pool/broadcast_bytes", unit="bytes").add(
                prb.elapsed(), len(blob) * len(self._procs))
            prb.histogram("pool/broadcast_seconds", unit="s").observe(
                perf_counter() - t0)

    def map(self, fn: Callable, items: Sequence, common=None) -> List:
        """``[fn(x) for x in items]`` (or ``fn(common, x)``), fanned out
        over the persistent workers.  Raises :class:`_Unpicklable` if the
        payload cannot be shipped; recovers from dying workers by
        finishing the remaining items serially."""
        if self.broken:
            raise _WorkerFailure("pool is broken")
        try:
            begin = pickle.dumps(("begin", fn, common),
                                 protocol=_PICKLE_PROTO)
        except Exception as e:
            raise _Unpicklable(str(e)) from e
        if not self._procs:
            self._spawn()
        n = len(items)
        nw = min(self.workers, n)
        results: List = [None] * n
        done = [False] * n
        # Static round-robin assignment, one item in flight per worker:
        # deterministic, deadlock-free (a worker never has more than one
        # response buffered), and load-balanced within each queue.
        queues = [list(range(w, n, nw))[::-1] for w in range(nw)]
        prb = _active_probe()
        t_map = perf_counter() if prb is not None else 0.0
        h_job = (prb.histogram("pool/job_seconds", unit="s")
                 if prb is not None else None)
        sent = [0.0] * nw
        cur: List[Optional[int]] = [None] * nw  # worker -> in-flight idx
        deadline = [0.0] * nw        # per-worker heartbeat (job_timeout)
        tries: Dict[int, int] = {}   # item idx -> failed attempts

        def send_item(w: int, idx: int) -> None:
            # pickle to bytes first: a payload that cannot be pickled is
            # the *caller's* problem (fall back to the fork pool), not a
            # pool failure — the workers stay healthy
            try:
                blob = pickle.dumps(("item", idx, items[idx]),
                                    protocol=_PICKLE_PROTO)
            except Exception as e:
                raise _Unpicklable(str(e)) from e
            job_f = self._procs[w][1]
            job_f.write(blob)
            job_f.flush()
            cur[w] = idx
            if self.job_timeout is not None:
                deadline[w] = perf_counter() + self.job_timeout
            if h_job is not None:
                sent[w] = perf_counter()

        sel = selectors.DefaultSelector()
        in_flight: set = set()       # workers with an unanswered item

        def revive(w: int, kind: str) -> None:
            """Worker ``w`` hung ("timeout") or died ("crash") mid-job:
            kill + replace it, then retry its item on the fresh worker —
            or quarantine the item once its retries are spent."""
            idx = cur[w]
            try:
                sel.unregister(self._procs[w][2])
            except (KeyError, ValueError):
                pass
            self._kill_worker(w)
            self._replace_worker(w, begin)
            sel.register(self._procs[w][2], selectors.EVENT_READ, w)
            in_flight.discard(w)
            cur[w] = None
            if prb is not None and kind == "timeout":
                prb.counter("pool/timeouts").add(prb.elapsed())
            t = tries.get(idx, 0) + 1
            tries[idx] = t
            if t <= self.job_retries:
                if self.retry_backoff > 0:
                    sleep(self.retry_backoff * 2 ** (t - 1))
                if prb is not None:
                    prb.counter("pool/retries").add(prb.elapsed())
                send_item(w, idx)
                in_flight.add(w)
                return
            # quarantine: the item failed on job_retries + 1 fresh workers
            if prb is not None:
                prb.counter("pool/quarantined").add(prb.elapsed())
            if kind == "timeout":
                raise PoolTimeout(
                    f"item {idx} exceeded job_timeout={self.job_timeout}s "
                    f"on {t} attempts")
            # a repeat crasher reproduces serially in the parent: a
            # genuine error surfaces exactly as a serial run would
            results[idx] = (fn(items[idx]) if common is None
                            else fn(common, items[idx]))
            done[idx] = True
            q = queues[w]
            if q:
                send_item(w, q.pop())
                in_flight.add(w)

        try:
            try:
                for w in range(nw):
                    self._procs[w][1].write(begin)
                    send_item(w, queues[w].pop())
                    sel.register(self._procs[w][2], selectors.EVENT_READ, w)
                    in_flight.add(w)
                while in_flight:
                    if self.job_timeout is not None:
                        now = perf_counter()
                        events = sel.select(timeout=max(
                            0.0, min(deadline[w] for w in in_flight) - now))
                        if not events:          # heartbeat expired
                            now = perf_counter()
                            for w in [w for w in in_flight
                                      if deadline[w] <= now]:
                                revive(w, "timeout")
                            continue
                    else:
                        events = sel.select()
                    for key, _ in events:
                        w = key.data
                        try:
                            tag, idx, val = _load_result(self._procs[w][2])
                        except (EOFError, OSError, pickle.PickleError):
                            revive(w, "crash")
                            break   # registrations changed: re-select
                        if tag == "err":
                            raise _WorkerFailure(val)
                        if h_job is not None:
                            h_job.observe(perf_counter() - sent[w])
                        results[idx] = val
                        done[idx] = True
                        in_flight.discard(w)
                        cur[w] = None
                        q = queues[w]
                        if q:
                            send_item(w, q.pop())
                            in_flight.add(w)
                        else:
                            sel.unregister(key.fileobj)
                sel.close()
            except _Unpicklable:
                # drain in-flight responses so the pool stays reusable,
                # then let parallel_map retry on the legacy fork path
                for w in list(in_flight):
                    try:
                        tag, idx, val = _load_result(self._procs[w][2])
                        if tag == "ok":
                            results[idx] = val
                            done[idx] = True
                    except Exception:
                        self.broken = True
                sel.close()
                if self.broken:
                    self.close()
                raise
        except _Unpicklable:
            raise
        except PoolTimeout:
            # the surviving workers may still hold unanswered jobs whose
            # late responses would desynchronise the next map: dispose
            self.broken = True
            try:
                sel.close()
            except Exception:
                pass
            self.close()
            raise
        except Exception:
            # A worker died (EOF/BrokenPipe) or a job failed inside one:
            # dispose the pool and finish every unfinished item serially
            # in the parent — same results, and a genuine fn error
            # surfaces exactly as a serial run would raise it.
            self.broken = True
            self.close()
            for i in range(n):
                if not done[i]:
                    results[i] = (fn(items[i]) if common is None
                                  else fn(common, items[i]))
        if prb is not None:
            prb.counter("pool/jobs").add(prb.elapsed(), n)
            prb.histogram("pool/map_seconds", unit="s").observe(
                perf_counter() - t_map)
        return results

    def close(self) -> None:
        """Terminate the workers (EOF on their job pipes, then SIGKILL as
        a backstop) and reap them.  Idempotent."""
        procs, self._procs = self._procs, []
        for pid, job_f, res_f in procs:
            try:
                pickle.dump(("quit",), job_f, protocol=_PICKLE_PROTO)
                job_f.flush()
            except Exception:
                pass
            for f in (job_f, res_f):
                try:
                    f.close()
                except Exception:
                    pass
        for pid, _, _ in procs:
            try:
                if os.waitpid(pid, os.WNOHANG)[0] == 0:
                    os.kill(pid, signal.SIGKILL)
                    os.waitpid(pid, 0)
            except (ChildProcessError, ProcessLookupError, OSError):
                pass


_POOLS: Dict[int, WorkerPool] = {}

#: live persistent pools are capped: callers that sweep with varying
#: ``workers`` counts would otherwise accumulate one forked pool (and
#: its resident workers) per distinct count for the process lifetime.
_MAX_POOLS = 2


def get_pool(workers: int) -> WorkerPool:
    """The shared persistent pool for ``workers`` (created lazily,
    replaced transparently if broken).  At most :data:`_MAX_POOLS` pools
    stay alive; requesting a new count evicts and closes the
    least-recently-used pool."""
    pool = _POOLS.pop(workers, None)
    if pool is not None and pool.broken:
        pool.close()
        pool = None
    if pool is None:
        pool = WorkerPool(workers)
    _POOLS[workers] = pool              # reinsert: most-recently-used last
    while len(_POOLS) > _MAX_POOLS:
        lru = next(k for k in _POOLS if k != workers)
        _POOLS.pop(lru).close()
    return pool


def ensure_shared(workers: int, key, payload) -> bool:
    """Make ``payload`` resolvable as ``WORKER_STORE[key]`` both in this
    process (by reference — serial paths and fallbacks see the original
    object) and in every persistent-pool worker for ``workers`` (pickled
    and broadcast once per pool).  Returns False when the payload cannot
    reach the workers; callers may then skip key-based jobs."""
    WORKER_STORE[key] = payload
    if workers <= 1 or not hasattr(os, "fork") \
            or os.environ.get(WORKER_ENV):
        return True                     # serial-only: parent store suffices
    try:
        get_pool(workers).ensure(key, payload)
        return True
    except Exception:
        return False


def close_pools() -> None:
    """Explicitly shut down every module-level pool (also runs atexit)."""
    for pool in list(_POOLS.values()):
        pool.close()
    _POOLS.clear()
    WORKER_STORE.clear()


atexit.register(close_pools)


# ---------------------------------------------------------------------------
# Legacy per-call fork pool (unpicklable payloads inherit by memory copy)
# ---------------------------------------------------------------------------

# (fn, items) visible to forked children; only valid while a pool is live.
_PAYLOAD = None


def _call_indexed(i: int):
    fn, items = _PAYLOAD
    return fn(items[i])


def _forked_map(fn, items: List, workers: int) -> Optional[List]:
    """One-shot fork pool; returns None if it cannot run here."""
    try:
        import multiprocessing as mp

        ctx = mp.get_context("fork")
    except (ImportError, ValueError):        # platform without fork
        return None
    global _PAYLOAD
    if _PAYLOAD is not None:                 # no nested pools
        return None
    _PAYLOAD = (fn, items)
    try:
        from concurrent.futures import ProcessPoolExecutor

        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*os.fork.*", category=RuntimeWarning)
            with ProcessPoolExecutor(max_workers=min(workers, len(items)),
                                     mp_context=ctx) as pool:
                return list(pool.map(_call_indexed, range(len(items))))
    except Exception:                        # pool/pickling failure
        return None
    finally:
        _PAYLOAD = None


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def parallel_map(fn: Callable, items: Sequence, workers: int = 1,
                 common=None) -> List:
    """``[fn(x) for x in items]`` (or ``[fn(common, x) for x in items]``
    when ``common`` is given), fanned out over ``workers`` processes when
    ``workers > 1``.

    Picklable payloads (module-level ``fn``, picklable ``common``/items)
    run on the persistent :class:`WorkerPool` — fork once, reuse across
    calls; ``common`` is broadcast once per map.  Unpicklable payloads
    fall back to the legacy one-shot fork pool; any failure falls back to
    serial.  Return values must always be picklable.
    """
    items = items if isinstance(items, list) else list(items)
    if workers <= 1 or len(items) <= 1 or not hasattr(os, "fork") \
            or os.environ.get(WORKER_ENV):
        return _serial(fn, items, common)
    try:
        return get_pool(workers).map(fn, items, common)
    except _Unpicklable:
        pass
    except PoolTimeout:
        raise               # never re-run a hung job in the parent
    except Exception:
        return _serial(fn, items, common)
    wrapped = fn if common is None else (lambda x: fn(common, x))
    out = _forked_map(wrapped, items, workers)
    if out is None:
        return _serial(fn, items, common)
    return out
