"""Three-term roofline model (assignment §Roofline + the paper's Fig 6/7).

  compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory term     = HLO_bytes   / (chips * HBM_bw)
  collective term = coll_bytes  / (chips * link_bw)

FLOPs/bytes are *global* (whole program over all chips), so each term is a
lower-bound execution time; the dominant term is the bottleneck.  Also
reports MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) and the useful-FLOP
ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.hw import (TPU_V5E_HBM_BW, TPU_V5E_ICI_BW,
                           TPU_V5E_PEAK_FLOPS)


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                  # global HLO FLOPs per step
    hbm_bytes: float              # global HLO bytes per step (estimate)
    collective_bytes: float       # global collective bytes per step
    model_flops: float            # 6*N*D / 2*N*D
    bytes_per_device: float = 0.0 # peak live bytes per device (memory_analysis)
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    note: str = ""

    peak_flops: float = TPU_V5E_PEAK_FLOPS
    hbm_bw: float = TPU_V5E_HBM_BW
    link_bw: float = TPU_V5E_ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.link_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the bound time achieves: how
        close the *bottleneck* lets us get to peak MFU."""
        if self.bound_time <= 0:
            return 0.0
        return (self.model_flops / (self.chips * self.peak_flops)) \
            / self.bound_time

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "note": self.note,
        }


def cell_from_report(arch: str, shape: str, mesh: str, chips: int,
                     hlo_report: Dict, model_flops: float,
                     note: str = "") -> RooflineCell:
    """Build a cell from a dry-run artifact (analyze_compiled dict).

    The dry-run lowers the per-device SPMD program on the full mesh; the HLO
    is the per-device program, so FLOPs/bytes are per-device — multiply by
    chips for the global terms used here.
    """
    # prefer the TPU-adjusted collective payload (f32 all-reduces of bf16
    # dot outputs are a CPU-legalization artifact; bf16 on the target)
    coll = hlo_report.get("collective_bytes_tpu_adjusted",
                          hlo_report["collective_bytes"])
    return RooflineCell(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops=hlo_report["flops"] * chips,
        hbm_bytes=hlo_report["hbm_bytes"] * chips,
        collective_bytes=coll * chips,
        model_flops=model_flops,
        bytes_per_device=hlo_report.get("peak_bytes", 0.0),
        collective_breakdown=hlo_report.get("collective_breakdown", {}),
        note=note)


def format_table(cells) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':10s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s}  note")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        lines.append(
            f"{c.arch:26s} {c.shape:12s} {c.mesh:10s} "
            f"{c.t_compute * 1e3:10.2f} {c.t_memory * 1e3:10.2f} "
            f"{c.t_collective * 1e3:10.2f} {c.dominant:>10s} "
            f"{c.useful_ratio:7.2f} {c.roofline_fraction * 100:6.1f}%  "
            f"{c.note}")
    return "\n".join(lines)
