"""Discrete-event simulation engine (the SystemC / Platform Architect analog).

Executes a hardware-adapted task graph on named resources while preserving
causality — the property the paper argues distinguishes simulation from
statistical estimation: a DMA that a compute task depends on *blocks* it,
and transfers sharing a link contend for its bandwidth.

Resources come in two flavours (:class:`ResourceSpec`):

  * ``fifo``   — a ``servers``-wide FIFO station: up to ``servers`` tasks
    run concurrently, each at full rate; excess tasks queue in ready order
    (tie-broken by task id for determinism).  A single-server FIFO is the
    classic exclusive resource.
  * ``shared`` — a bandwidth-shared channel (generalized processor
    sharing): every admitted task progresses at rate
    ``min(1, servers / n_active)``, so total throughput never exceeds
    ``servers`` times the annotated full rate.  Two collectives sharing an
    ICI link each see half the bandwidth instead of strictly serializing.

Task durations are pre-annotated at *full rate* by the virtual hardware
models (repro.core.taskgraph.compiler); contention stretches them.
Unknown resources default to a single-server FIFO, so plain task lists
behave exactly as the original exclusive-resource engine.

Beyond static graphs, the engine supports **dynamic event injection** — the
foundation of the traffic-driven serving simulator (``repro.serve_sim``):

  * :meth:`Simulator.at` schedules a timed callback (e.g. a request
    arrival) that runs inside the event loop and may inject new work;
  * :meth:`Simulator.inject` adds a task *while the simulation runs*; its
    dependencies may already be satisfied or still in flight;
  * ``on_complete`` observers fire as tasks finish, letting a scheduler
    react causally (free a slot, admit the next request, issue the next
    decode step);
  * :meth:`Simulator.lane` opens a :class:`ServiceLane` — the express path
    for the dominant serving pattern (one task at a time on a dedicated
    single-server resource, submitted only when idle) that skips Task
    construction and dependency bookkeeping entirely.

Static task graphs are simply the special case with no callbacks — and
for them :func:`simulate_static` runs the same causal semantics over
precomputed dependency arrays (:class:`StaticCache`) with deferred record
materialization, several times faster than the dict-based general loop.

Complexity: shared-link contention is O(log n) per event via virtual-time
generalized processor sharing — each admitted task gets a fixed virtual
finish time, completions pop from a heap, and real-to-virtual conversion
happens only at rate-change boundaries.  (The seed engine decremented
every active task's remaining work on every event: O(n) per event,
O(n^2) per burst of n concurrent transfers.)
"""
from __future__ import annotations

import heapq
import math
from bisect import bisect_left
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


def _schedule_every(sim, interval: float, fn: Callable[[], bool],
                    start: Optional[float]) -> None:
    """Shared implementation behind ``Simulator.every`` /
    ``DynamicSimulator.every``.

    Exactly one pending tick lives on the event heap at a time; the
    chain self-extends only while ``fn()`` returns a truthy value, so a
    draining run (the event loop stops when the heap empties) always
    terminates: the caller's ``fn`` is responsible for returning False
    once the condition it monitors (outstanding requests, open probes,
    ...) is resolved.
    """
    if not (interval > 0.0) or not math.isfinite(interval):
        raise ValueError(f"every(): interval must be finite and > 0, "
                         f"got {interval!r}")
    t0 = sim.now + interval if start is None else start

    def _tick() -> None:
        if fn():
            sim.at(sim.now + interval, _tick)

    sim.at(t0, _tick)


@dataclass(frozen=True)
class ResourceSpec:
    """How a named resource serves tasks."""

    name: str
    servers: int = 1
    mode: str = "fifo"           # fifo | shared

    def __post_init__(self):
        if self.servers < 1:
            raise ValueError(f"resource {self.name}: servers must be >= 1")
        if self.mode not in ("fifo", "shared"):
            raise ValueError(f"resource {self.name}: unknown mode {self.mode}")


@dataclass(slots=True)
class Task:
    tid: int
    name: str
    layer: str                  # grouping key for per-layer stats
    resource: str               # e.g. "nce", "dma", "ici_model"
    duration: float             # seconds at full rate
    deps: Tuple[int, ...] = ()
    kind: str = "compute"       # compute | dma | collective | launch | host
    nbytes: int = 0
    flops: int = 0
    op_id: int = -1             # index of the originating LayerOp (-1: none)
    anno: Optional[object] = None   # RateAnno re-annotation rule (what-if)


@dataclass(slots=True)
class TaskRecord:
    task: Task
    start: float
    end: float


class SimResult:
    """Outcome of one simulation run.

    ``records`` may be materialized lazily: the static fast path and the
    serving lanes keep start/end arrays and only build ``TaskRecord``
    objects when a trace/Gantt export actually reads them.
    """

    __slots__ = ("makespan", "resource_busy", "layer_time", "_records",
                 "_records_thunk")

    def __init__(self, makespan: float,
                 records: Optional[List[TaskRecord]] = None,
                 resource_busy: Optional[Dict[str, float]] = None,
                 layer_time: Optional[Dict[str, Tuple[float, float]]] = None,
                 records_thunk: Optional[Callable[[], List[TaskRecord]]] = None):
        self.makespan = makespan
        self.resource_busy = resource_busy if resource_busy is not None else {}
        self.layer_time = layer_time if layer_time is not None else {}
        self._records = records
        self._records_thunk = records_thunk

    @property
    def records(self) -> List[TaskRecord]:
        if self._records is None:
            thunk = self._records_thunk
            self._records = thunk() if thunk is not None else []
            self._records_thunk = None
        return self._records

    def utilization(self, resource: str) -> float:
        return (self.resource_busy.get(resource, 0.0) / self.makespan
                if self.makespan > 0 else 0.0)

    def layer_durations(self) -> Dict[str, float]:
        return {k: e - s for k, (s, e) in self.layer_time.items()}

    def __repr__(self) -> str:
        n = "lazy" if self._records is None else len(self._records)
        return (f"SimResult(makespan={self.makespan!r}, "
                f"n_records={n}, "
                f"resources={sorted(self.resource_busy)})")


class _SharedChannel:
    """Virtual-time generalized processor sharing for one ``shared`` resource.

    All active tasks progress at the common rate ``min(1, servers / n)``,
    so completion order equals admission-virtual-finish order: a task
    admitted with ``work`` full-rate seconds at virtual time ``v`` finishes
    at fixed virtual time ``v + work``.  The virtual clock advances at the
    common rate and is converted to real time only at rate-change
    boundaries (admit / complete), making each channel event O(log n) in
    active tasks instead of the O(n) per-event remaining-work sweep of the
    seed engine.  ``epoch`` invalidates stale completion events.
    """

    __slots__ = ("servers", "heap", "work", "start", "vnow", "last_t",
                 "epoch", "n")

    #: near-tie completion tolerance, *relative* to each task's own
    #: full-rate duration.  (The seed engine used an absolute 1e-15 s
    #: cutoff, which completed genuinely unfinished tasks early whenever
    #: durations were themselves O(1e-15).)
    REL_EPS = 1e-12

    def __init__(self, servers: int):
        self.servers = servers
        self.heap: List[Tuple[float, int]] = []   # (virtual finish, tid)
        self.work: Dict[int, float] = {}
        self.start: Dict[int, float] = {}
        self.vnow = 0.0
        self.last_t = 0.0
        self.epoch = 0
        self.n = 0

    @property
    def rate(self) -> float:
        n = self.n
        return min(1.0, self.servers / n) if n else 1.0

    def advance(self, now: float) -> None:
        dt = now - self.last_t
        if dt > 0.0:
            if self.n:
                self.vnow += dt * self.rate
            self.last_t = now

    def admit(self, tid: int, work: float, now: float) -> None:
        self.advance(now)
        self.n += 1
        heapq.heappush(self.heap, (self.vnow + work, tid))
        self.work[tid] = work
        self.start[tid] = now

    def next_completion(self, now: float) -> Optional[float]:
        if not self.n:
            return None
        vf = self.heap[0][0]
        return now + max(vf - self.vnow, 0.0) / self.rate

    def pop_done(self, now: float) -> List[int]:
        """Pop the head task plus any near-ties.

        Called when the completion event scheduled for the current head
        fires (``epoch`` guarantees no admission or completion intervened),
        so the head is complete by construction — no absolute epsilon is
        needed.  Near-ties complete together only when within
        ``REL_EPS * work`` of the head's virtual finish.
        """
        self.advance(now)
        if not self.n:
            return []
        vf0, tid0 = heapq.heappop(self.heap)
        if vf0 > self.vnow:                # absorb scheduling round-off
            self.vnow = vf0
        self.n -= 1
        del self.work[tid0]
        done = [tid0]
        heap = self.heap
        while heap:
            vf, tid = heap[0]
            if vf - vf0 > self.REL_EPS * self.work[tid]:
                break
            heapq.heappop(heap)
            self.n -= 1
            del self.work[tid]
            done.append(tid)
        done.sort()
        return done


class ServiceLane:
    """Express path for dynamic service on one single-server FIFO resource.

    The traffic-driven serving simulator issues one prefill/decode task at
    a time per replica, always from an idle state — so the general
    inject/enqueue/drain machinery (Task construction, dependency and
    duration dicts, ready queues) is pure overhead.  A lane keeps plain
    start/end/kind arrays, schedules the completion event directly, and
    materializes ``TaskRecord``s lazily only when a trace is requested.

    ``name_fn(kind, info) -> str`` builds record names at materialization
    time, so per-step f-string formatting is also deferred.
    """

    __slots__ = ("sim", "resource", "busy", "busy_time", "starts", "ends",
                 "kinds", "infos", "name_fn", "epoch", "_handler")

    def __init__(self, sim: "Simulator", resource: str,
                 name_fn: Optional[Callable[[str, object], str]] = None):
        self.sim = sim
        self.resource = resource
        self.busy = False
        self.busy_time = 0.0
        self.starts: List[float] = []
        self.ends: List[float] = []
        self.kinds: List[str] = []
        self.infos: List[object] = []
        self.name_fn = name_fn
        # ``epoch`` invalidates the scheduled completion of a task whose
        # end moved (speculative decode-leap rollback, :meth:`truncate`).
        self.epoch = 0
        self._handler: Optional[Callable[[float], None]] = None

    def submit(self, duration: float, handler: Callable[[float], None],
               kind: str = "task", info: object = None) -> None:
        """Start a task now; ``handler(now)`` runs when it completes."""
        if self.busy:
            raise RuntimeError(f"lane {self.resource!r} is busy")
        sim = self.sim
        self.busy = True
        start = sim._now
        end = start + duration
        self.starts.append(start)
        self.ends.append(end)
        self.kinds.append(kind)
        self.infos.append(info)
        self.busy_time += duration
        self._handler = handler
        sim._seq += 1
        heapq.heappush(sim._events,
                       (end, sim._seq, "lane", (self, handler, self.epoch)))

    def truncate(self, new_end: float, info: object = None) -> None:
        """Shorten the in-flight task to end at ``new_end``.

        The speculative decode-leap submits a fused task optimistically
        and rolls it back to a step boundary when the scheduler must be
        consulted earlier (an arrival landed mid-leap): the recorded span
        shrinks, the stale completion event is invalidated via ``epoch``,
        and the completion is rescheduled at the truncated end.
        """
        if not self.busy:
            raise RuntimeError(f"lane {self.resource!r} has no task to "
                               f"truncate")
        old_end = self.ends[-1]
        if new_end >= old_end:
            return
        if new_end < self.starts[-1]:
            raise ValueError(f"cannot truncate before the task start "
                             f"({new_end} < {self.starts[-1]})")
        self.ends[-1] = new_end
        self.busy_time -= old_end - new_end
        if info is not None:
            self.infos[-1] = info
        self.epoch += 1
        sim = self.sim
        sim._seq += 1
        heapq.heappush(
            sim._events,
            (new_end, sim._seq, "lane", (self, self._handler, self.epoch)))

    def cancel(self, new_end: float, info: object = None) -> None:
        """Abort the in-flight task at ``new_end`` (a replica crash).

        Like :meth:`truncate`, the recorded span shrinks to the abort
        time and the stale completion event is invalidated via ``epoch``
        — but no completion is rescheduled and the handler never fires:
        the lane simply goes idle.  The partial span stays recorded
        (work the replica really did before dying)."""
        if not self.busy:
            raise RuntimeError(f"lane {self.resource!r} has no task to "
                               f"cancel")
        old_end = self.ends[-1]
        if new_end < self.starts[-1]:
            raise ValueError(f"cannot cancel before the task start "
                             f"({new_end} < {self.starts[-1]})")
        if new_end < old_end:
            self.ends[-1] = new_end
            self.busy_time -= old_end - new_end
        if info is not None:
            self.infos[-1] = info
        self.epoch += 1
        self.busy = False
        self._handler = None

    def _nonempty(self) -> bool:
        return bool(self.starts)

    def _merge(self, resource_busy: Dict[str, float],
               layer_time: Dict[str, Tuple[float, float]]) -> float:
        """Fold this lane's busy time and layer span into the run-level
        aggregates; returns the lane's makespan contribution."""
        res = self.resource
        resource_busy[res] = resource_busy.get(res, 0.0) + self.busy_time
        span = (self.starts[0], self.ends[-1])
        cur = layer_time.get(res)
        if cur is not None:
            span = (min(cur[0], span[0]), max(cur[1], span[1]))
        layer_time[res] = span
        return self.ends[-1]

    def _materialize(self, tid0: int) -> List[TaskRecord]:
        name_fn = self.name_fn
        res = self.resource
        out = []
        for i, (s, e, k, info) in enumerate(zip(self.starts, self.ends,
                                                self.kinds, self.infos)):
            name = name_fn(k, info) if name_fn is not None else f"{res}/{k}"
            out.append(TaskRecord(
                Task(tid=tid0 + i, name=name, layer=res, resource=res,
                     duration=e - s, kind=k), s, e))
        return out


class TemplateLane:
    """Graph-structured service lane: full per-task template records at
    ServiceLane speed.

    The serving simulator's task-graph mode submits one phase template
    instance (chunked compute with KV/DMA sidecars) per scheduler
    decision.  Running each chunk through the engine's event loop costs
    O(chunks) heap events per phase — the entire gap between graph mode
    and the express :class:`ServiceLane`.  But a phase template on
    *dedicated* single-server FIFO resources is deterministic at
    submission time: chunk chains serialize on the phase resource, and
    sidecar tasks (KV writes) serialize in template order on theirs.  So
    a TemplateLane schedules exactly **one completion event per phase**
    (at the precomputed tail end) and stores the phase as a compact
    entry; the full per-task schedule — including real DMA/compute
    overlap across chunks and phases — is replayed lazily when the run's
    aggregates or ``TaskRecord``s are read.

    Speculative decode-leap support (the GraphTemplate epoch-snapshot
    mechanism): :meth:`submit_burst` books ``K`` chained step instances
    as one entry whose per-step boundary times are the snapshot points,
    and :meth:`truncate` rolls the burst back to a boundary — the stale
    completion event is invalidated via ``epoch`` exactly like
    :meth:`ServiceLane.truncate`, and the tasks of every step after the
    boundary are dropped before they ever materialize.

    Contract (validated once per template): template tasks are
    topologically ordered by local id, every task's resources are
    dedicated to this lane, and the tail's dependency closure determines
    the phase end (the caller precomputes it with the same left-to-right
    chunk accumulation the general engines' chained events produce, so
    parity with the dict engine is bit-exact).
    """

    __slots__ = ("sim", "resource", "busy", "epoch", "entries", "end",
                 "step_durs", "_handler", "_fin", "_sched", "_checked",
                 "_prev_end")

    def __init__(self, sim, resource: str,
                 step_durs: Optional[Callable] = None):
        """``step_durs(tpl, dur) -> per-task durations`` splits one burst
        step's total duration at materialization time (bursts store only
        their boundary times)."""
        self.sim = sim
        self.resource = resource
        self.busy = False
        self.epoch = 0
        #: (template, t0, per-task durations | None, burst bounds | None)
        self.entries: List[Tuple] = []
        self.end = 0.0
        self._prev_end = 0.0     # lane end excluding the in-flight entry
        self.step_durs = step_durs
        self._handler: Optional[Callable[[float], None]] = None
        self._fin = None
        self._sched = None
        #: template id -> (compute_res, sidecar_res) | None (see _chain_key)
        self._checked: Dict[int, Optional[Tuple[str, str]]] = {}

    def _check(self, tpl: GraphTemplate) -> None:
        if id(tpl) in self._checked:
            return
        for i, dd in enumerate(tpl.deps):
            for d in dd:
                if d >= i:
                    raise ValueError(
                        "TemplateLane templates must be topologically "
                        f"ordered by local id (task {i} depends on {d})")
        self._checked[id(tpl)] = self._chain_key(tpl)

    def submit(self, tpl: GraphTemplate, durations: Sequence[float],
               end: float, handler: Callable[[float], None]) -> None:
        """Start one instance of ``tpl`` now; ``end`` is the precomputed
        absolute completion time of its tail and ``handler(now)`` runs
        there."""
        if self.busy:
            raise RuntimeError(f"template lane {self.resource!r} is busy")
        self._check(tpl)
        sim = self.sim
        self._fin = self._sched = None
        self.entries.append((tpl, sim._now, durations, None))
        self._prev_end = self.end
        self.end = end
        self.busy = True
        self._handler = handler
        sim._seq += 1
        heapq.heappush(sim._events,
                       (end, sim._seq, "lane", (self, handler, self.epoch)))

    def submit_burst(self, tpl: GraphTemplate, bounds,
                     handler: Callable[[float], None]) -> None:
        """Start ``len(bounds)`` chained step instances of ``tpl`` as one
        entry — the speculative decode leap in graph mode.  ``bounds``
        are the absolute per-step boundary (snapshot) times; step ``i``
        spans ``(bounds[i-1], bounds[i]]`` and its per-task durations are
        recovered at materialization via ``step_durs``.  One completion
        event is scheduled at ``bounds[-1]``; ``handler`` fires there (or
        at the truncated boundary after a rollback)."""
        if self.busy:
            raise RuntimeError(f"template lane {self.resource!r} is busy")
        self._check(tpl)
        sim = self.sim
        self._fin = self._sched = None
        self.entries.append((tpl, sim._now, None, bounds))
        self._prev_end = self.end
        self.end = end = float(bounds[-1])
        self.busy = True
        self._handler = handler
        sim._seq += 1
        heapq.heappush(sim._events,
                       (end, sim._seq, "lane", (self, handler, self.epoch)))

    def truncate(self, new_end: float, info: object = None) -> None:
        """Roll the in-flight burst back to the snapshot boundary at
        ``new_end``: the steps before it ran exactly as fused, the steps
        after it are invalidated before they materialize, and the stale
        completion event is superseded via ``epoch`` (mirroring
        :meth:`ServiceLane.truncate`).  ``info`` is accepted for
        signature compatibility with the express lane (template records
        carry their own structure)."""
        if not self.busy:
            raise RuntimeError(f"template lane {self.resource!r} has no "
                               f"task to truncate")
        if new_end >= self.end:
            return
        tpl, t0, durs, bounds = self.entries[-1]
        if bounds is None:
            raise RuntimeError("only burst submissions can be truncated")
        j = bisect_left(bounds, new_end)
        if j >= len(bounds) - 1:
            return
        self._fin = self._sched = None
        self.entries[-1] = (tpl, t0, None, bounds[:j + 1])
        self.end = end = float(bounds[j])
        self.epoch += 1
        sim = self.sim
        sim._seq += 1
        heapq.heappush(
            sim._events,
            (end, sim._seq, "lane", (self, self._handler, self.epoch)))

    def cancel(self, new_end: float, info: object = None) -> None:
        """Abort the in-flight phase or burst (a replica crash).

        A burst keeps the steps whose boundary precedes ``new_end`` —
        they ran exactly as the per-step baseline would have run them —
        and drops the rest; a plain phase entry is dropped whole before
        it materializes (template entries are step-granular at best, so
        graph mode records no partial-step work — the express
        :class:`ServiceLane` keeps the truncated span instead; the
        serving parity tests under faults therefore compare request
        metrics, not task records).  The stale completion event is
        invalidated via ``epoch`` and the lane goes idle."""
        if not self.busy:
            raise RuntimeError(f"template lane {self.resource!r} has no "
                               f"task to cancel")
        self._fin = self._sched = None
        tpl, t0, durs, bounds = self.entries[-1]
        j = bisect_left(bounds, new_end) if bounds is not None else 0
        if j >= 1:
            self.entries[-1] = (tpl, t0, None, bounds[:j])
            self.end = float(bounds[j - 1])
        else:
            self.entries.pop()
            self.end = self._prev_end
        self.epoch += 1
        self.busy = False
        self._handler = None

    # ---- lazy schedule replay -------------------------------------------

    def _run_instance(self, tpl: GraphTemplate, t0: float,
                      durs: Sequence[float], starts: List[float],
                      ends: List[float], free: Dict[str, float],
                      busy: Dict[str, float],
                      lay: Dict[str, List[float]]) -> float:
        """Schedule one instance: template order is the dispatch order on
        each (dedicated, single-server FIFO) resource, so every start is
        ``max(dep ends, resource free)``.  Returns the max end."""
        deps = tpl.deps
        res_of = tpl.res_of
        lay_of = tpl.layer_of
        res_names = tpl.res_names
        lay_names = tpl.layer_names
        base = len(ends)
        mk = t0
        for i in range(tpl.n):
            ready = t0
            for d in deps[i]:
                e = ends[base + d]
                if e > ready:
                    ready = e
            rn = res_names[res_of[i]]
            rf = free.get(rn, 0.0)
            start = ready if ready > rf else rf
            dur = durs[i]
            end = start + dur
            free[rn] = end
            starts.append(start)
            ends.append(end)
            busy[rn] = busy.get(rn, 0.0) + dur
            name = lay_names[lay_of[i]]
            span = lay.get(name)
            if span is None:
                lay[name] = [start, end]
            else:
                if start < span[0]:
                    span[0] = start
                if end > span[1]:
                    span[1] = end
            if end > mk:
                mk = end
        return mk

    def _chain_key(self, tpl: GraphTemplate):
        """(compute_res, sidecar_res) if ``tpl`` is the serving chunk
        chain + sidecar shape — compute chunks 0,2,4,... chained on one
        resource, each feeding a sidecar task on a second — else None.
        The shape admits closed-form aggregates: the compute chain is a
        pure cumulative sum from ``t0`` and the sidecar serializes in
        chunk order, so :meth:`_finalize` runs O(chunks) float ops per
        instance with no per-task dict lookups."""
        n = tpl.n
        if (n < 2 or n % 2 or len(tpl.res_names) != 2
                or tpl.tail != n - 2
                or tpl.layer_names != tpl.res_names
                or tpl.layer_of != tpl.res_of):
            return None
        for i in range(0, n, 2):
            if (tpl.res_of[i] != 0 or tpl.res_of[i + 1] != 1
                    or tpl.deps[i] != ((i - 2,) if i else ())
                    or tpl.deps[i + 1] != (i,)):
                return None
        return tpl.res_names[0], tpl.res_names[1]

    def _agg_chain(self, key: Tuple[str, str]):
        """Closed-form aggregates for all-chain entries: one pass over
        chunk durations, no per-task schedule arrays."""
        r0, r1 = key
        comp_busy = 0.0
        dma_busy = 0.0
        kvf = 0.0
        kv_first = None
        end = t0_first = self.entries[0][1]
        step_durs = self.step_durs
        for tpl, t0, durs, bounds in self.entries:
            if bounds is None:
                spans = ((t0, durs),)
            else:
                prev = t0
                spans = []
                for b in bounds:
                    b = float(b)
                    spans.append((prev, step_durs(tpl, b - prev)))
                    prev = b
            for s0, dd in spans:
                e = s0
                for i in range(0, len(dd), 2):
                    d = dd[i]
                    e += d
                    comp_busy += d   # per-chunk, matching the general
                    dk = dd[i + 1]   # engines' per-task accumulation
                    s = e if e > kvf else kvf
                    if kv_first is None:
                        kv_first = s
                    kvf = s + dk
                    dma_busy += dk
                end = e
        busy = {r0: comp_busy, r1: dma_busy}
        lay = {r0: [t0_first, end]}
        if kv_first is not None:
            lay[r1] = [kv_first, kvf]
        return busy, lay, end if end > kvf else kvf

    def _finalize(self):
        """Cached run-level aggregates: (resource busy, layer spans,
        makespan).  Chain-shaped lanes take the closed-form path; the
        generic path replays the full schedule (and caches it for
        :meth:`_schedule`)."""
        fin = self._fin
        if fin is None:
            checked = self._checked
            key = chain = checked[id(self.entries[0][0])]
            if chain is not None:
                for tpl, _, _, _ in self.entries:
                    if checked[id(tpl)] != key:
                        chain = None
                        break
            if chain is not None:
                busy, lay, mk = self._agg_chain(chain)
            else:
                starts, ends, busy, lay, mk = self._replay()
                self._sched = (starts, ends)
            fin = self._fin = (busy, lay, mk)
        return fin

    def _replay(self):
        """Full generic schedule replay over every entry."""
        starts: List[float] = []
        ends: List[float] = []
        free: Dict[str, float] = {}
        busy: Dict[str, float] = {}
        lay: Dict[str, List[float]] = {}
        mk = 0.0
        run = self._run_instance
        step_durs = self.step_durs
        for tpl, t0, durs, bounds in self.entries:
            if bounds is None:
                e = run(tpl, t0, durs, starts, ends, free, busy, lay)
            else:
                prev = t0
                e = t0
                for b in bounds:
                    b = float(b)
                    e = run(tpl, prev, step_durs(tpl, b - prev),
                            starts, ends, free, busy, lay)
                    prev = b
            if e > mk:
                mk = e
        return starts, ends, busy, lay, mk

    def _schedule(self):
        """Cached per-task (starts, ends) — the records path; computed on
        demand so aggregate-only runs never pay the per-task replay."""
        sched = self._sched
        if sched is None:
            starts, ends, _, _, _ = self._replay()
            sched = self._sched = (starts, ends)
        return sched

    def _nonempty(self) -> bool:
        return bool(self.entries)

    def _merge(self, resource_busy: Dict[str, float],
               layer_time: Dict[str, Tuple[float, float]]) -> float:
        busy, lay, mk = self._finalize()
        for rn, b in busy.items():
            resource_busy[rn] = resource_busy.get(rn, 0.0) + b
        for name, (s, e) in lay.items():
            cur = layer_time.get(name)
            if cur is not None:
                s, e = min(cur[0], s), max(cur[1], e)
            layer_time[name] = (s, e)
        return mk if mk > self.end else self.end

    def _materialize(self, tid0: int) -> List[TaskRecord]:
        starts, ends = self._schedule()
        out = []
        k = 0
        tid = tid0
        for tpl, t0, durs, bounds in self.entries:
            reps = 1 if bounds is None else len(bounds)
            names, kinds = tpl.names, tpl.kinds
            res_names, lay_names = tpl.res_names, tpl.layer_names
            res_of, lay_of = tpl.res_of, tpl.layer_of
            nbytes, flops = tpl.nbytes, tpl.flops
            deps = tpl.deps
            n = tpl.n
            tail = tpl.tail
            for r in range(reps):
                base = tid
                for i in range(n):
                    dd = tuple(base + d for d in deps[i])
                    if r and not dd:
                        # burst steps chain: this step's roots follow the
                        # previous step's tail
                        dd = (base - n + tail,)
                    s = starts[k]
                    e = ends[k]
                    out.append(TaskRecord(
                        Task(tid=tid, name=names[i],
                             layer=lay_names[lay_of[i]],
                             resource=res_names[res_of[i]],
                             duration=e - s, deps=dd, kind=kinds[i],
                             nbytes=nbytes[i], flops=flops[i]), s, e))
                    tid += 1
                    k += 1
        return out


class Simulator:
    """Event-driven scheduler over FIFO and bandwidth-shared resources.

    The event loop is instance-level state, so timed callbacks
    (:meth:`at`) and completion observers (``on_complete``) can inject
    new tasks (:meth:`inject`) while the simulation is running — dynamic
    arrivals preempting a static task graph.
    """

    def __init__(self, tasks: Iterable[Task] = (),
                 resources: Optional[Dict[str, ResourceSpec]] = None,
                 durations=None,
                 on_complete: Optional[Callable[[Task, float], None]] = None,
                 probe=None):
        """``durations`` optionally overrides each task's annotated duration
        (aligned with ``tasks``); the what-if fast path re-annotates a graph
        by swapping this array, leaving the Task objects untouched.
        ``probe`` (a :class:`repro.obs.probe.Probe`) enables event-loop
        instrumentation: per-kind event counters plus active/share gauges
        on bandwidth-shared channels.  Probes only read simulation state —
        results are bit-identical with or without one."""
        tasks = list(tasks)
        self.tasks = {t.tid: t for t in tasks}
        if len(self.tasks) != len(tasks):
            raise ValueError("duplicate task ids")
        if durations is None:
            self.durations = {t.tid: t.duration for t in tasks}
        else:
            if len(durations) != len(tasks):
                raise ValueError("durations must align with tasks")
            self.durations = {t.tid: float(d)
                              for t, d in zip(tasks, durations)}
        self.resources = dict(resources or {})
        self.on_complete = on_complete
        self.probe = probe
        self._chan_gauges: Dict[str, Tuple] = {}
        self._validate(tasks)
        self._next_tid = max(self.tasks, default=-1) + 1
        # ---- event-loop state (live during run()) ----
        self._now = 0.0
        self._seq = 0
        self._running = False
        self._completed_ids: set = set()
        self._n_deps: Dict[int, int] = {}
        self._dependents: Dict[int, List[int]] = {}
        # per-FIFO-resource ready queue: (ready_time, tid)
        self._queues: Dict[str, List[Tuple[float, int]]] = {}
        self._active: Dict[str, int] = {}     # fifo resource -> active count
        self._channels: Dict[str, _SharedChannel] = {}
        self._res_busy: Dict[str, float] = {}
        self._records: List[TaskRecord] = []
        self._lanes: List = []  # ServiceLane | TemplateLane
        self._void: set = set()   # tids whose pending 'done' was cancelled
        # event heap: (time, seq, kind, payload)
        #   kind 'done'  — a fifo task finished (payload = tid)
        #   kind 'chan'  — a shared channel may have completions
        #                  (payload = (resource, epoch))
        #   kind 'call'  — a timed callback (payload = zero-arg callable)
        #   kind 'lane'  — a service-lane task finished
        #                  (payload = (lane, handler, epoch))
        self._events: List[Tuple[float, int, str, object]] = []

    def _validate(self, tasks: List[Task]) -> None:
        ids = set(self.tasks)
        for t in tasks:
            for d in t.deps:
                if d not in ids:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")

    def _spec(self, resource: str) -> ResourceSpec:
        return self.resources.get(resource) or ResourceSpec(name=resource)

    # ------------------------------------------------------------------
    # Dynamic injection API
    # ------------------------------------------------------------------

    @property
    def now(self) -> float:
        """Current simulation time."""
        return self._now

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to run inside the event loop at time ``t``.

        Callbacks at equal times run in scheduling order.  ``fn`` may call
        :meth:`inject` / :meth:`at` — this is how open-loop arrivals and
        scheduler timeouts enter a running simulation.
        """
        if t < self._now - 1e-18:
            raise ValueError(f"cannot schedule at {t} < now ({self._now})")
        self._push_event(max(t, self._now), "call", fn)

    def every(self, interval: float, fn: Callable[[], bool],
              start: Optional[float] = None) -> None:
        """Run ``fn`` periodically inside the event loop (health checks,
        autoscaler ticks).  The first tick fires at ``start`` (default
        ``now + interval``), then every ``interval`` for as long as
        ``fn()`` returns truthy; a falsy return ends the chain so the
        heap can drain and :meth:`run` can terminate."""
        _schedule_every(self, interval, fn, start)

    def inject(self, task: Task) -> Task:
        """Add ``task`` to a (possibly running) simulation.

        Dependencies may reference completed or in-flight tasks.  The task
        becomes ready once its outstanding dependencies finish (immediately
        if there are none).
        """
        if task.tid in self.tasks:
            raise ValueError(f"duplicate task id {task.tid}")
        for d in task.deps:
            if d not in self.tasks:
                raise ValueError(f"task {task.tid} depends on unknown {d}")
        self.tasks[task.tid] = task
        self.durations[task.tid] = task.duration
        self._next_tid = max(self._next_tid, task.tid + 1)
        if not self._running:
            return task
        outstanding = [d for d in task.deps if d not in self._completed_ids]
        self._n_deps[task.tid] = len(outstanding)
        self._dependents.setdefault(task.tid, [])
        for d in outstanding:
            self._dependents.setdefault(d, []).append(task.tid)
        if not outstanding:
            self._enqueue(task.tid, self._now)
        return task

    def lane(self, resource: str,
             name_fn: Optional[Callable[[str, object], str]] = None
             ) -> ServiceLane:
        """Open a :class:`ServiceLane` on a dedicated single-server
        resource (see the class docstring for the contract)."""
        ln = ServiceLane(self, resource, name_fn)
        self._lanes.append(ln)
        return ln

    def template_lane(self, resource: str,
                      step_durs: Optional[Callable] = None) -> TemplateLane:
        """Open a :class:`TemplateLane` — graph-structured phases with
        one event per phase (see the class docstring for the contract)."""
        ln = TemplateLane(self, resource, step_durs)
        self._lanes.append(ln)
        return ln

    def next_task_id(self) -> int:
        """A fresh task id (monotone counter above every existing id)."""
        return self._next_tid

    def cancel_tasks(self, tids: Iterable[int]) -> None:
        """Cancel uncompleted tasks mid-run (a replica crash in the
        serving simulator's dict-graph mode).

        Queued and dependency-blocked tasks are dropped before they
        start; an in-flight task's record is truncated at the current
        time (work really done before the crash stays recorded), its
        pending completion event is voided, and its server freed.
        Cancelled tasks count as completed for the run's termination
        check but never reach ``on_complete`` or release dependents.
        FIFO resources only — a bandwidth-shared channel would need a
        rate re-plan for the surviving tasks."""
        if not self._running:
            raise RuntimeError("cancel_tasks is only valid during run()")
        now = self._now
        started_res = []
        for tid in tids:
            if tid in self._completed_ids or tid not in self.tasks:
                continue
            res = self.tasks[tid].resource
            if self._spec(res).mode == "shared":
                raise NotImplementedError(
                    "cancel_tasks on bandwidth-shared resources")
            queued = False
            q = self._queues.get(res)
            if q:
                for i, (_, qt) in enumerate(q):
                    if qt == tid:
                        q[i] = q[-1]
                        q.pop()
                        heapq.heapify(q)
                        queued = True
                        break
            if not queued and self._n_deps.get(tid, 0) == 0:
                # started: truncate its record, void the pending 'done'
                for r in reversed(self._records):
                    if r.task.tid == tid:
                        if r.end > now:
                            self._res_busy[res] -= r.end - now
                            r.end = now
                        break
                self._active[res] -= 1
                self._void.add(tid)
                started_res.append(res)
            self._n_deps[tid] = 0
            self._completed_ids.add(tid)
        for res in started_res:
            self._drain(res)

    # ------------------------------------------------------------------
    # Event loop internals
    # ------------------------------------------------------------------

    def _push_event(self, t_ev: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t_ev, self._seq, kind, payload))

    def _chan_probe(self, res: str, ch: "_SharedChannel",
                    t: float) -> None:
        """Record a shared channel's active count and per-task bandwidth
        share at a rate-change boundary (admit/complete) — called only
        when a probe is installed."""
        g = self._chan_gauges.get(res)
        if g is None:
            g = self._chan_gauges[res] = (
                self.probe.gauge(f"engine/chan/{res}/active", unit="tasks"),
                self.probe.gauge(f"engine/chan/{res}/share", unit="frac"))
        g[0].set(t, ch.n)
        g[1].set(t, ch.rate)

    def _reschedule_channel(self, res: str) -> None:
        ch = self._channels[res]
        ch.epoch += 1
        t_next = ch.next_completion(self._now)
        if t_next is not None:
            self._push_event(t_next, "chan", (res, ch.epoch))

    def _enqueue(self, tid: int, t_ready: float) -> None:
        t = self.tasks[tid]
        spec = self._spec(t.resource)
        if spec.mode == "shared":
            ch = self._channels.get(t.resource)
            if ch is None:
                ch = self._channels[t.resource] = _SharedChannel(spec.servers)
            ch.admit(tid, self.durations[tid], t_ready)
            self._reschedule_channel(t.resource)
            if self.probe is not None:
                self._chan_probe(t.resource, ch, t_ready)
        else:
            q = self._queues.setdefault(t.resource, [])
            heapq.heappush(q, (t_ready, tid))
            self._drain(t.resource)

    def _drain(self, resource: str) -> None:
        spec = self._spec(resource)
        q = self._queues.get(resource)
        while q and self._active.get(resource, 0) < spec.servers:
            t_ready, tid = heapq.heappop(q)
            t = self.tasks[tid]
            dur = self.durations[tid]
            start = max(t_ready, self._now)
            end = start + dur
            self._active[resource] = self._active.get(resource, 0) + 1
            self._res_busy[resource] = self._res_busy.get(resource, 0.0) + dur
            self._records.append(TaskRecord(t, start, end))
            self._push_event(end, "done", tid)

    def _complete(self, tid: int) -> None:
        self._completed_ids.add(tid)
        for dep_tid in self._dependents.get(tid, ()):
            self._n_deps[dep_tid] -= 1
            if self._n_deps[dep_tid] == 0:
                self._enqueue(dep_tid, self._now)
        if self.on_complete is not None:
            self.on_complete(self.tasks[tid], self._now)

    def run(self) -> SimResult:
        if self._running or self._completed_ids:
            raise RuntimeError("Simulator.run() may only be called once")
        self._running = True
        self._n_deps = {tid: len(t.deps) for tid, t in self.tasks.items()}
        self._dependents = {tid: [] for tid in self.tasks}
        for t in self.tasks.values():
            for d in t.deps:
                self._dependents[d].append(t.tid)

        for tid, n in list(self._n_deps.items()):
            if n == 0:
                self._enqueue(tid, 0.0)

        # Observability: one local None-check per event when disabled
        # (the default) — counters live only behind an installed probe.
        prb = self.probe
        if prb is not None:
            p_done = prb.counter("engine/fifo_completions")
            p_lane = prb.counter("engine/lane_completions")
            p_call = prb.counter("engine/callbacks")
            p_chan = prb.counter("engine/chan_completions")

        events = self._events
        void = self._void
        while events:
            self._now, _, kind, payload = heapq.heappop(events)
            if kind == "done":
                tid = payload
                if void and tid in void:
                    void.discard(tid)     # cancelled mid-flight
                    continue
                t = self.tasks[tid]
                self._active[t.resource] -= 1
                self._complete(tid)
                self._drain(t.resource)
                if prb is not None:
                    p_done.add(self._now)
            elif kind == "lane":
                ln, handler, epoch = payload
                if epoch != ln.epoch:
                    continue                  # superseded by a truncation
                ln.busy = False
                handler(self._now)
                if prb is not None:
                    p_lane.add(self._now)
            elif kind == "call":
                payload()
                if prb is not None:
                    p_call.add(self._now)
            else:  # 'chan'
                res, epoch = payload
                ch = self._channels[res]
                if epoch != ch.epoch:
                    continue                      # superseded by a re-plan
                for tid in ch.pop_done(self._now):
                    t = self.tasks[tid]
                    self._res_busy[res] = (self._res_busy.get(res, 0.0)
                                           + self.durations[tid])
                    self._records.append(
                        TaskRecord(t, ch.start.pop(tid), self._now))
                    self._complete(tid)
                    if prb is not None:
                        p_chan.add(self._now)
                self._reschedule_channel(res)
                if prb is not None:
                    self._chan_probe(res, ch, self._now)

        if len(self._completed_ids) != len(self.tasks):
            stuck = [tid for tid, n in self._n_deps.items() if n > 0]
            raise RuntimeError(
                f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
                f"{[self.tasks[t].name for t in stuck[:5]]}")
        self._running = False

        makespan = max((r.end for r in self._records), default=0.0)
        layer_time: Dict[str, Tuple[float, float]] = {}
        for r in self._records:
            lay = r.task.layer
            if lay in layer_time:
                s, e = layer_time[lay]
                layer_time[lay] = (min(s, r.start), max(e, r.end))
            else:
                layer_time[lay] = (r.start, r.end)

        lanes = [ln for ln in self._lanes if ln._nonempty()]
        for ln in lanes:
            makespan = max(makespan, ln._merge(self._res_busy, layer_time))

        if not lanes:
            return SimResult(makespan=makespan, records=self._records,
                             resource_busy=self._res_busy,
                             layer_time=layer_time)

        static_records = self._records
        tid0 = self._next_tid

        def materialize() -> List[TaskRecord]:
            out = list(static_records)
            base = tid0
            for ln in lanes:
                recs = ln._materialize(base)
                out.extend(recs)
                base += len(recs)
            return out

        return SimResult(makespan=makespan, records_thunk=materialize,
                         resource_busy=self._res_busy, layer_time=layer_time)


# ---------------------------------------------------------------------------
# Array-backed fast path for static graphs
# ---------------------------------------------------------------------------


class StaticCache:
    """Precomputed dependency/resource structure for one static task list.

    System-independent: resource *names*, the dependency CSR, and layer
    grouping depend only on the task list, so a cache built once per
    compiled graph is shared across every re-annotated what-if variant
    (``CompiledGraph.sim_cache()``).  Per-system resource widths/modes and
    the duration vector are passed to :func:`simulate_static` per run.
    """

    __slots__ = ("n", "index_of", "tids", "dependents", "indeg", "res_of",
                 "res_names", "layer_of", "layer_names")

    def __init__(self, tasks: Sequence[Task]):
        n = len(tasks)
        self.n = n
        self.tids = [t.tid for t in tasks]
        index_of = {t.tid: i for i, t in enumerate(tasks)}
        if len(index_of) != n:
            raise ValueError("duplicate task ids")
        self.index_of = index_of
        res_index: Dict[str, int] = {}
        lay_index: Dict[str, int] = {}
        res_of = [0] * n
        lay_of = [0] * n
        indeg = [0] * n
        dependents: List[List[int]] = [[] for _ in range(n)]
        for i, t in enumerate(tasks):
            r = t.resource
            ri = res_index.get(r)
            if ri is None:
                ri = res_index[r] = len(res_index)
            res_of[i] = ri
            lay = t.layer
            li = lay_index.get(lay)
            if li is None:
                li = lay_index[lay] = len(lay_index)
            lay_of[i] = li
            indeg[i] = len(t.deps)
            for d in t.deps:
                j = index_of.get(d)
                if j is None:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")
                dependents[j].append(i)
        self.dependents = [tuple(dd) for dd in dependents]
        self.indeg = indeg
        self.res_of = res_of
        self.res_names = list(res_index)
        self.layer_of = lay_of
        self.layer_names = list(lay_index)


def simulate_static(tasks: Sequence[Task],
                    resources: Optional[Dict[str, ResourceSpec]] = None,
                    durations=None,
                    cache: Optional[StaticCache] = None,
                    probe=None) -> SimResult:
    """Run a *static* task graph (no callbacks, no injection) over
    precomputed dependency arrays.

    Same causal semantics as :class:`Simulator` — multi-server FIFO
    stations, virtual-time processor-sharing channels, identical
    tie-breaking — but the hot loop indexes flat lists instead of dicts
    and defers ``TaskRecord`` materialization until a trace is read, so
    ``reannotate``-then-simulate sweep points skip all per-task object
    churn.  Exact-parity with the general engine is asserted by
    ``tests/test_engine_parity.py``.

    ``probe`` enables instrumentation with *zero* in-loop cost: the
    per-resource concurrency series and completion counters are derived
    post-hoc from the start/end arrays the loop fills anyway
    (:func:`_static_probe_series`), so the hot loop is byte-identical
    with and without a probe.
    """
    tasks = tasks if isinstance(tasks, list) else list(tasks)
    if cache is None:
        cache = StaticCache(tasks)
    n = cache.n
    resources = resources or {}
    if durations is None:
        durs = [t.duration for t in tasks]
    elif hasattr(durations, "tolist"):
        durs = durations.tolist()
        if len(durs) != n:
            raise ValueError("durations must align with tasks")
    else:
        if len(durations) != n:
            raise ValueError("durations must align with tasks")
        durs = [float(d) for d in durations]

    n_res = len(cache.res_names)
    shared = [False] * n_res
    servers = [1] * n_res
    for ri, name in enumerate(cache.res_names):
        spec = resources.get(name)
        if spec is not None:
            shared[ri] = spec.mode == "shared"
            servers[ri] = spec.servers

    res_of = cache.res_of
    tids = cache.tids            # equal-time ties break by tid, not index,
    dependents = cache.dependents    # mirroring the general Simulator
    indeg = list(cache.indeg)
    starts = [0.0] * n
    ends = [0.0] * n
    busy = [0.0] * n_res
    active = [0] * n_res
    queues: List[List[Tuple[float, int]]] = [[] for _ in range(n_res)]
    # Shared channels live as flat per-resource state (virtual-time GPS
    # with the object/property overhead of _SharedChannel inlined away):
    ch_heap: List[Optional[List[Tuple[float, int]]]] = [None] * n_res
    ch_vnow = [0.0] * n_res      # virtual clock
    ch_last = [0.0] * n_res      # real time of the last advance
    ch_n = [0] * n_res           # active tasks
    ch_epoch = [0] * n_res       # invalidates superseded completion events
    rel_eps = _SharedChannel.REL_EPS
    events: List[Tuple[float, int, int, object]] = []
    # event tuple: (time, seq, code, payload); code 0 = fifo done
    # (payload = task index), code 1 = channel completion
    # (payload = (res index, epoch at issue))
    seq = 0
    now = 0.0
    n_done = 0
    push = heapq.heappush
    pop = heapq.heappop

    def reschedule(ri: int) -> None:
        nonlocal seq
        ch_epoch[ri] += 1
        m = ch_n[ri]
        if m:
            srv = servers[ri]
            rate = 1.0 if m <= srv else srv / m
            dv = ch_heap[ri][0][0] - ch_vnow[ri]
            t_next = now + (dv if dv > 0.0 else 0.0) / rate
            seq += 1
            push(events, (t_next, seq, 1, (ri, ch_epoch[ri])))

    def drain(ri: int) -> None:
        nonlocal seq
        q = queues[ri]
        cap = servers[ri]
        while q and active[ri] < cap:
            t_ready, _, i = pop(q)
            dur = durs[i]
            start = t_ready if t_ready > now else now
            end = start + dur
            active[ri] += 1
            busy[ri] += dur
            starts[i] = start
            ends[i] = end
            seq += 1
            push(events, (end, seq, 0, i))

    def enqueue(i: int, t_ready: float) -> None:
        ri = res_of[i]
        if shared[ri]:
            heap = ch_heap[ri]
            if heap is None:
                heap = ch_heap[ri] = []
            m = ch_n[ri]
            dt = t_ready - ch_last[ri]
            if dt > 0.0:                      # advance the virtual clock
                if m:
                    srv = servers[ri]
                    ch_vnow[ri] += dt * (1.0 if m <= srv else srv / m)
                ch_last[ri] = t_ready
            ch_n[ri] = m + 1
            push(heap, (ch_vnow[ri] + durs[i], tids[i], i))
            starts[i] = t_ready
            reschedule(ri)
        else:
            push(queues[ri], (t_ready, tids[i], i))
            drain(ri)

    for i in range(n):
        if indeg[i] == 0:
            enqueue(i, 0.0)

    while events:
        now, _, code, payload = pop(events)
        if code == 0:                       # fifo completion
            i = payload
            active[res_of[i]] -= 1
            n_done += 1
            for j in dependents[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    enqueue(j, now)
            drain(res_of[i])
        else:                               # channel completion(s)
            ri, epoch = payload
            if epoch != ch_epoch[ri]:
                continue                    # superseded by a re-plan
            # advance the virtual clock to now
            m = ch_n[ri]
            dt = now - ch_last[ri]
            if dt > 0.0:
                if m:
                    srv = servers[ri]
                    ch_vnow[ri] += dt * (1.0 if m <= srv else srv / m)
                ch_last[ri] = now
            # the head is complete by construction (epoch was current);
            # pop it plus near-ties within the relative epsilon
            heap = ch_heap[ri]
            vf0, _, i = pop(heap)
            if vf0 > ch_vnow[ri]:           # absorb scheduling round-off
                ch_vnow[ri] = vf0
            m -= 1
            done = [i]
            while heap:
                vf, _, i2 = heap[0]
                if vf - vf0 > rel_eps * durs[i2]:
                    break
                pop(heap)
                m -= 1
                done.append(i2)
            ch_n[ri] = m
            if len(done) > 1:
                done.sort(key=tids.__getitem__)   # complete in tid order
            for i in done:
                busy[ri] += durs[i]
                ends[i] = now
                n_done += 1
                for j in dependents[i]:
                    indeg[j] -= 1
                    if indeg[j] == 0:
                        enqueue(j, now)
            reschedule(ri)

    if n_done != n:
        stuck = [i for i in range(n) if indeg[i] > 0]
        raise RuntimeError(
            f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
            f"{[tasks[i].name for i in stuck[:5]]}")

    makespan = max(ends) if n else 0.0
    lay_of = cache.layer_of
    lay_lo = [float("inf")] * len(cache.layer_names)
    lay_hi = [float("-inf")] * len(cache.layer_names)
    for i in range(n):
        li = lay_of[i]
        s = starts[i]
        e = ends[i]
        if s < lay_lo[li]:
            lay_lo[li] = s
        if e > lay_hi[li]:
            lay_hi[li] = e
    layer_time = {name: (lay_lo[li], lay_hi[li])
                  for li, name in enumerate(cache.layer_names)}
    resource_busy = {name: busy[ri]
                     for ri, name in enumerate(cache.res_names)}

    if probe is not None:
        _static_probe_series(probe, cache, starts, ends)

    def materialize() -> List[TaskRecord]:
        return [TaskRecord(tasks[i], starts[i], ends[i]) for i in range(n)]

    return SimResult(makespan=makespan, records_thunk=materialize,
                     resource_busy=resource_busy, layer_time=layer_time)


def _static_probe_series(probe, cache: StaticCache, starts: Sequence[float],
                         ends: Sequence[float]) -> None:
    """Derive ``simulate_static`` instrumentation after the run: a
    per-resource active-task concurrency gauge (+1 at each start, -1 at
    each end, starts-before-ends on ties so the level never dips
    negative) and a global completion counter over the end times."""
    n = cache.n
    if not n:
        return
    res_of = cache.res_of
    for ri, name in enumerate(cache.res_names):
        deltas = []
        for i in range(n):
            if res_of[i] == ri:
                deltas.append((starts[i], 1))
                deltas.append((ends[i], -1))
        g = probe.gauge(f"static/{name}/active", unit="tasks")
        level = 0
        for t, d in sorted(deltas, key=lambda td: (td[0], -td[1])):
            level += d
            g.set(t, level)
    c = probe.counter("static/tasks_completed")
    for t in sorted(ends[:n]):
        c.add(t)


# ---------------------------------------------------------------------------
# Array-backed fast path for dynamic (injected) task graphs
# ---------------------------------------------------------------------------


class GraphTemplate:
    """Precompiled structure of a small task graph injected repeatedly.

    The serving simulator's task-graph mode injects the same phase shape
    (chunked prefill/decode compute with KV-write DMAs) once per scheduler
    decision — thousands of times per run.  Building ``Task`` objects and
    re-walking their dependencies on every injection is exactly the
    per-task churn the dynamic fast path removes: a template captures the
    local dependency CSR, resource/layer names, and record metadata once,
    so :meth:`DynamicSimulator.inject_template` instantiates it with a
    handful of list extends and no object construction.

    ``tasks`` must use dense local ids ``0..n-1`` with local-only deps;
    ``tail`` names the task whose completion fires the per-instance
    ``on_done`` callback (default: the last task).
    """

    __slots__ = ("n", "names", "kinds", "res_names", "layer_names",
                 "res_of", "layer_of", "deps", "dependents", "indeg",
                 "roots", "tail", "nbytes", "flops")

    def __init__(self, tasks: Sequence[Task], tail: Optional[int] = None):
        n = len(tasks)
        self.n = n
        if [t.tid for t in tasks] != list(range(n)):
            raise ValueError("template tasks must use dense local ids 0..n-1")
        self.deps = [tuple(t.deps) for t in tasks]
        self.names = [t.name for t in tasks]
        self.kinds = [t.kind for t in tasks]
        self.nbytes = [t.nbytes for t in tasks]
        self.flops = [t.flops for t in tasks]
        res_index: Dict[str, int] = {}
        lay_index: Dict[str, int] = {}
        self.res_of = [res_index.setdefault(t.resource, len(res_index))
                       for t in tasks]
        self.layer_of = [lay_index.setdefault(t.layer, len(lay_index))
                         for t in tasks]
        self.res_names = list(res_index)
        self.layer_names = list(lay_index)
        dependents: List[List[int]] = [[] for _ in range(n)]
        self.indeg = [0] * n
        for i, t in enumerate(tasks):
            self.indeg[i] = len(t.deps)
            for d in t.deps:
                if not 0 <= d < n:
                    raise ValueError(f"template task {i}: non-local dep {d}")
                dependents[d].append(i)
        self.dependents = [tuple(dd) for dd in dependents]
        self.roots = [i for i in range(n) if self.indeg[i] == 0]
        self.tail = n - 1 if tail is None else tail
        if not 0 <= self.tail < n:
            raise ValueError(f"tail {self.tail} out of range")


class DynamicCache:
    """Growable flat task structure for the dynamic fast path.

    The static fast path's :class:`StaticCache` precomputes a dependency
    CSR for a *fixed* task list; dynamic injection breaks that premise.
    A DynamicCache keeps the same flat layout — parallel lists indexed by
    a dense task index — but assigns each task its index *on arrival*
    (initial list order, then injection order).  Indices are stable: they
    never move as the arrays grow, so the event loop keeps integer-
    indexing flat lists while ``tid -> index`` remapping stays O(1) per
    lookup and is skipped entirely for template instances (their indices
    are a contiguous block known at injection).

    ``from_static`` seeds the dynamic structure from a precomputed
    :class:`StaticCache` (``CompiledGraph.sim_cache()``), so traffic
    injected on top of a compiled graph reuses its CSR instead of
    re-walking every dependency.
    """

    __slots__ = ("tids", "index_of", "tasks", "durs", "res_of", "layer_of",
                 "indeg", "dependents", "dep_base", "res_names", "res_index",
                 "layer_names", "layer_index", "instances")

    def __init__(self):
        self.tids: List[int] = []
        self.index_of: Dict[int, int] = {}
        self.tasks: List[Optional[Task]] = []   # None for template instances
        self.durs: List[float] = []
        self.res_of: List[int] = []
        self.layer_of: List[int] = []
        self.indeg: List[int] = []
        # ``dependents[i]`` holds ids relative to ``dep_base[i]`` — 0 for
        # individually added tasks (absolute ids), the instance base for
        # template tasks, whose dependents alias the template's local
        # tuples (no per-instance list is ever built).
        self.dependents: List[Sequence[int]] = []
        self.dep_base: List[int] = []
        self.res_names: List[str] = []
        self.res_index: Dict[str, int] = {}
        self.layer_names: List[str] = []
        self.layer_index: Dict[str, int] = {}
        # (base index, template) per instantiation, base ascending — the
        # record materializer recovers names/kinds from here.
        self.instances: List[Tuple[int, GraphTemplate]] = []

    @property
    def n(self) -> int:
        return len(self.tids)

    @classmethod
    def from_static(cls, cache: StaticCache, tasks: Sequence[Task],
                    durations=None) -> "DynamicCache":
        """Seed from a :class:`StaticCache` — the CSR of the static prefix
        is copied, not recomputed from ``Task.deps``."""
        c = cls()
        c.tids = list(cache.tids)
        c.index_of = dict(cache.index_of)
        c.tasks = list(tasks)
        if durations is None:
            c.durs = [t.duration for t in tasks]
        else:
            c.durs = [float(d) for d in durations]
            if len(c.durs) != cache.n:
                raise ValueError("durations must align with tasks")
        c.res_of = list(cache.res_of)
        c.layer_of = list(cache.layer_of)
        c.indeg = list(cache.indeg)
        c.dependents = [list(dd) for dd in cache.dependents]
        c.dep_base = [0] * cache.n
        c.res_names = list(cache.res_names)
        c.res_index = {name: ri for ri, name in enumerate(cache.res_names)}
        c.layer_names = list(cache.layer_names)
        c.layer_index = {name: li
                         for li, name in enumerate(cache.layer_names)}
        return c

    def intern_resource(self, name: str) -> int:
        ri = self.res_index.get(name)
        if ri is None:
            ri = self.res_index[name] = len(self.res_names)
            self.res_names.append(name)
        return ri

    def intern_layer(self, name: str) -> int:
        li = self.layer_index.get(name)
        if li is None:
            li = self.layer_index[name] = len(self.layer_names)
            self.layer_names.append(name)
        return li

    def add_task(self, task: Task, dur: float) -> int:
        """Append one task (dependencies are wired by the simulator, which
        knows which are already complete)."""
        if task.tid in self.index_of:
            raise ValueError(f"duplicate task id {task.tid}")
        i = len(self.tids)
        self.index_of[task.tid] = i
        self.tids.append(task.tid)
        self.tasks.append(task)
        self.durs.append(dur)
        self.res_of.append(self.intern_resource(task.resource))
        self.layer_of.append(self.intern_layer(task.layer))
        self.indeg.append(0)
        self.dependents.append([])
        self.dep_base.append(0)
        return i

    def task_of(self, i: int) -> Task:
        """The ``Task`` at index ``i``, materializing template instances
        lazily (binary search over the instance bases)."""
        t = self.tasks[i]
        if t is not None:
            return t
        from bisect import bisect_right
        k = bisect_right(self.instances, i, key=lambda inst: inst[0]) - 1
        base, tpl = self.instances[k]
        j = i - base
        t = Task(tid=self.tids[i], name=tpl.names[j],
                 layer=self.layer_names[self.layer_of[i]],
                 resource=self.res_names[self.res_of[i]],
                 duration=self.durs[i], kind=tpl.kinds[j],
                 nbytes=tpl.nbytes[j], flops=tpl.flops[j])
        self.tasks[i] = t
        return t


class DynamicSimulator:
    """Array-backed engine for *dynamic* simulations.

    The fast-path counterpart of :class:`Simulator`: the same causal
    semantics, the same dynamic API (:meth:`at`, :meth:`inject`,
    ``on_complete`` observers, :meth:`lane`), and the same event ordering
    — exact parity is asserted task-for-task in
    ``tests/test_engine_parity.py`` — but the hot loop indexes the flat
    :class:`DynamicCache` arrays instead of per-task dicts, resource specs
    are resolved once per resource name instead of per enqueue, and
    ``TaskRecord``/name construction is deferred until a trace is read.
    :meth:`inject_template` additionally amortizes the structure of a
    repeatedly injected subgraph (one CSR walk per :class:`GraphTemplate`,
    list extends per instance) — the serving simulator's task-graph mode
    runs ~3-4x faster than the dict engine on it.
    """

    def __init__(self, tasks: Iterable[Task] = (),
                 resources: Optional[Dict[str, ResourceSpec]] = None,
                 durations=None,
                 on_complete: Optional[Callable[[Task, float], None]] = None,
                 cache: Optional[StaticCache] = None,
                 probe=None):
        """``durations`` optionally overrides annotated durations (aligned
        with ``tasks``); ``cache`` optionally seeds the dependency layout
        from a precomputed :class:`StaticCache` of the same task list.
        ``probe`` enables event-loop instrumentation (same contract as on
        :class:`Simulator`: read-only, bit-identical results)."""
        tasks = tasks if isinstance(tasks, list) else list(tasks)
        self.resources = dict(resources or {})
        self.on_complete = on_complete
        self.probe = probe
        self._chan_gauges: Dict[int, Tuple] = {}
        if durations is not None and len(durations) != len(tasks):
            raise ValueError("durations must align with tasks")
        if cache is not None:
            if cache.n != len(tasks):
                raise ValueError("cache does not match tasks")
            self.cache = DynamicCache.from_static(cache, tasks, durations)
        else:
            self.cache = c = DynamicCache()
            for k, t in enumerate(tasks):
                i = c.add_task(
                    t, t.duration if durations is None
                    else float(durations[k]))
                c.indeg[i] = len(t.deps)
            for t in tasks:
                for d in t.deps:
                    j = c.index_of.get(d)
                    if j is None:
                        raise ValueError(
                            f"task {t.tid} depends on unknown {d}")
                    c.dependents[j].append(c.index_of[t.tid])
        self._next_tid = max(self.cache.tids, default=-1) + 1
        # ---- runtime state, parallel to cache indices ----
        n = self.cache.n
        self._starts = [0.0] * n
        self._ends = [0.0] * n
        self._done = [False] * n
        self._n_done = 0
        self._on_done: Dict[int, Callable[[float], None]] = {}
        # ---- per-resource runtime state, parallel to cache.res_names;
        # grown lazily as resources intern (spec resolved once per name)
        self._shared: List[bool] = []
        self._servers: List[int] = []
        self._active: List[int] = []
        self._busy: List[float] = []
        self._used: List[bool] = []   # ever scheduled a task (the dict
        #                               engine reports those, even all-zero)
        self._queues: List[List[Tuple[float, int, int]]] = []
        self._ch_heap: List[Optional[List[Tuple[float, int, int]]]] = []
        self._ch_vnow: List[float] = []
        self._ch_last: List[float] = []
        self._ch_n: List[int] = []
        self._ch_epoch: List[int] = []
        # per-template interned instantiation payloads (mapped resource and
        # layer ids + reusable extend tuples), keyed by id(template)
        self._tpl_ids: Dict[int, Tuple] = {}
        self._lanes: List = []  # ServiceLane | TemplateLane
        self._now = 0.0
        self._seq = 0
        self._running = False
        self._events: List[Tuple[float, int, str, object]] = []
        self._grow_resources()

    # ------------------------------------------------------------------
    # Dynamic injection API (mirrors Simulator)
    # ------------------------------------------------------------------

    @property
    def now(self) -> float:
        return self._now

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` inside the event loop at time ``t`` (see
        :meth:`Simulator.at`)."""
        if t < self._now - 1e-18:
            raise ValueError(f"cannot schedule at {t} < now ({self._now})")
        self._seq += 1
        heapq.heappush(self._events,
                       (max(t, self._now), self._seq, "call", fn))

    def every(self, interval: float, fn: Callable[[], bool],
              start: Optional[float] = None) -> None:
        """Periodic conditional callback (see :meth:`Simulator.every`)."""
        _schedule_every(self, interval, fn, start)

    def next_task_id(self) -> int:
        return self._next_tid

    def lane(self, resource: str,
             name_fn: Optional[Callable[[str, object], str]] = None
             ) -> ServiceLane:
        """Open a :class:`ServiceLane` (express path, same contract as on
        the dict engine — lanes only touch the shared event heap)."""
        ln = ServiceLane(self, resource, name_fn)
        self._lanes.append(ln)
        return ln

    def template_lane(self, resource: str,
                      step_durs: Optional[Callable] = None) -> TemplateLane:
        """Open a :class:`TemplateLane` — full graph-structured phase
        records at lane speed (same contract as on the dict engine)."""
        ln = TemplateLane(self, resource, step_durs)
        self._lanes.append(ln)
        return ln

    def inject(self, task: Task,
               on_done: Optional[Callable[[float], None]] = None) -> Task:
        """Add ``task`` to a (possibly running) simulation — the exact
        :meth:`Simulator.inject` semantics over the flat arrays.
        ``on_done(now)`` additionally fires when this task completes
        (after dependents are released and the global ``on_complete``)."""
        c = self.cache
        for d in task.deps:
            if d not in c.index_of:
                raise ValueError(f"task {task.tid} depends on unknown {d}")
        i = c.add_task(task, task.duration)
        if task.tid >= self._next_tid:
            self._next_tid = task.tid + 1
        self._starts.append(0.0)
        self._ends.append(0.0)
        self._done.append(False)
        if on_done is not None:
            self._on_done[i] = on_done
        if not self._running:
            c.indeg[i] = len(task.deps)
            for d in task.deps:
                c.dependents[c.index_of[d]].append(i)
            return task
        outstanding = 0
        for d in task.deps:
            j = c.index_of[d]
            if not self._done[j]:
                outstanding += 1
                c.dependents[j].append(i)
        c.indeg[i] = outstanding
        if not outstanding:
            self._enqueue(i, self._now)
        return task

    def inject_template(self, tpl: GraphTemplate, durations: Sequence[float],
                        on_done: Optional[Callable[[float], None]] = None
                        ) -> int:
        """Instantiate ``tpl`` with per-instance ``durations``; all
        template roots become ready now.  Returns the instance's base task
        id (ids are ``base .. base + tpl.n - 1`` in template order).

        Template instances are pure array extends: no Task objects, no
        tid remapping (the block's indices are contiguous), no dependency
        walk.  Their ids are therefore *not* valid dependency targets for
        later :meth:`inject` calls, and the global ``on_complete``
        observer — which receives ``Task`` objects — materializes them
        lazily; ``on_done`` fires when the template's tail completes.
        """
        if len(durations) != tpl.n:
            raise ValueError("durations must align with the template")
        c = self.cache
        base = c.n
        tid0 = self._next_tid
        self._next_tid = tid0 + tpl.n
        ids = self._tpl_ids.get(id(tpl))
        if ids is None:
            # intern once per (simulator, template): resource/layer ids
            # mapped into this simulator's index space, plus reusable
            # extend payloads (tuples extend at C speed)
            res_ids = tuple(c.intern_resource(r) for r in tpl.res_names)
            lay_ids = tuple(c.intern_layer(name) for name in tpl.layer_names)
            ids = self._tpl_ids[id(tpl)] = (
                tuple(res_ids[r] for r in tpl.res_of),
                tuple(lay_ids[li] for li in tpl.layer_of),
                tuple(tpl.indeg), (None,) * tpl.n, (0.0,) * tpl.n,
                (False,) * tpl.n)
            self._grow_resources()
        mapped_res, mapped_lay, indeg, nones, zeros, falses = ids
        c.tids.extend(range(tid0, tid0 + tpl.n))
        c.tasks.extend(nones)
        c.durs.extend(durations)
        c.res_of.extend(mapped_res)
        c.layer_of.extend(mapped_lay)
        c.indeg.extend(indeg)
        c.dependents.extend(tpl.dependents)   # shared local-id tuples
        c.dep_base.extend([base] * tpl.n)
        c.instances.append((base, tpl))
        self._starts.extend(zeros)
        self._ends.extend(zeros)
        self._done.extend(falses)
        if on_done is not None:
            self._on_done[base + tpl.tail] = on_done
        if self._running:
            for j in tpl.roots:
                self._enqueue(base + j, self._now)
        return tid0

    # ------------------------------------------------------------------
    # Event loop
    # ------------------------------------------------------------------

    def _grow_resources(self) -> None:
        """Extend per-resource runtime arrays to cover newly interned
        resources, resolving each spec exactly once."""
        names = self.cache.res_names
        for ri in range(len(self._servers), len(names)):
            spec = self.resources.get(names[ri])
            self._shared.append(spec is not None and spec.mode == "shared")
            self._servers.append(spec.servers if spec is not None else 1)
            self._active.append(0)
            self._used.append(False)
            self._busy.append(0.0)
            self._queues.append([])
            self._ch_heap.append(None)
            self._ch_vnow.append(0.0)
            self._ch_last.append(0.0)
            self._ch_n.append(0)
            self._ch_epoch.append(0)

    def _reschedule_channel(self, ri: int) -> None:
        self._ch_epoch[ri] += 1
        m = self._ch_n[ri]
        if m:
            srv = self._servers[ri]
            rate = 1.0 if m <= srv else srv / m
            dv = self._ch_heap[ri][0][0] - self._ch_vnow[ri]
            self._seq += 1
            heapq.heappush(
                self._events,
                (self._now + (dv if dv > 0.0 else 0.0) / rate, self._seq,
                 "chan", (ri, self._ch_epoch[ri])))

    def _chan_probe(self, ri: int, t: float) -> None:
        """Shared-channel active/share gauges at a rate-change boundary —
        called only when a probe is installed."""
        g = self._chan_gauges.get(ri)
        if g is None:
            name = self.cache.res_names[ri]
            g = self._chan_gauges[ri] = (
                self.probe.gauge(f"engine/chan/{name}/active", unit="tasks"),
                self.probe.gauge(f"engine/chan/{name}/share", unit="frac"))
        m = self._ch_n[ri]
        srv = self._servers[ri]
        g[0].set(t, m)
        g[1].set(t, 1.0 if not m or m <= srv else srv / m)

    def _drain(self, ri: int) -> None:
        q = self._queues[ri]
        cap = self._servers[ri]
        active = self._active
        durs = self.cache.durs
        now = self._now
        while q and active[ri] < cap:
            t_ready, _, i = heapq.heappop(q)
            dur = durs[i]
            start = t_ready if t_ready > now else now
            active[ri] += 1
            self._busy[ri] += dur
            self._starts[i] = start
            self._ends[i] = start + dur
            self._seq += 1
            heapq.heappush(self._events, (start + dur, self._seq, "done", i))

    def _enqueue(self, i: int, t_ready: float) -> None:
        c = self.cache
        ri = c.res_of[i]
        if ri >= len(self._servers):
            self._grow_resources()
        self._used[ri] = True
        if not self._shared[ri]:
            # FIFO: immediate dispatch when a server is free and nothing
            # queues ahead — same outcome as push-then-drain, without the
            # heap round-trip (the overwhelmingly common case for the
            # serving simulator's one-phase-at-a-time replica resources).
            if not self._queues[ri] and self._active[ri] < self._servers[ri]:
                dur = c.durs[i]
                now = self._now
                start = t_ready if t_ready > now else now
                self._active[ri] += 1
                self._busy[ri] += dur
                self._starts[i] = start
                self._ends[i] = start + dur
                self._seq += 1
                heapq.heappush(self._events,
                               (start + dur, self._seq, "done", i))
            else:
                heapq.heappush(self._queues[ri], (t_ready, c.tids[i], i))
                self._drain(ri)
            return
        heap = self._ch_heap[ri]
        if heap is None:
            heap = self._ch_heap[ri] = []
        m = self._ch_n[ri]
        dt = t_ready - self._ch_last[ri]
        if dt > 0.0:                          # advance the virtual clock
            if m:
                srv = self._servers[ri]
                self._ch_vnow[ri] += dt * (1.0 if m <= srv else srv / m)
            self._ch_last[ri] = t_ready
        self._ch_n[ri] = m + 1
        heapq.heappush(heap, (self._ch_vnow[ri] + c.durs[i],
                              c.tids[i], i))
        self._starts[i] = t_ready
        self._reschedule_channel(ri)
        if self.probe is not None:
            self._chan_probe(ri, t_ready)

    def run(self) -> SimResult:
        if self._running or self._n_done:
            raise RuntimeError(
                "DynamicSimulator.run() may only be called once")
        self._running = True
        c = self.cache
        indeg = c.indeg
        for i in range(c.n):
            if not indeg[i]:
                self._enqueue(i, 0.0)

        # The hot loop binds every per-task array to a local: the lists
        # are grown strictly in place (append/extend), so the bindings
        # stay valid across injections from callbacks.  The completion
        # path (_complete) is inlined — it runs once per task.
        events = self._events
        res_of = c.res_of
        durs = c.durs
        tids = c.tids
        indeg = c.indeg
        dependents = c.dependents
        dep_base = c.dep_base
        done_flags = self._done
        active = self._active
        queues = self._queues
        busy = self._busy
        starts = self._starts
        ends = self._ends
        used = self._used
        shared_res = self._shared
        servers = self._servers
        on_done = self._on_done
        enqueue = self._enqueue
        rel_eps = _SharedChannel.REL_EPS
        pop = heapq.heappop
        push = heapq.heappush
        n_res_known = len(servers)
        n_done = 0
        # Observability: one local None-check per event when disabled.
        prb = self.probe
        if prb is not None:
            p_done = prb.counter("engine/fifo_completions")
            p_lane = prb.counter("engine/lane_completions")
            p_call = prb.counter("engine/callbacks")
            p_chan = prb.counter("engine/chan_completions")
        while events:
            now, _, kind, payload = pop(events)
            self._now = now
            if kind == "done":                # fifo completion
                i = payload
                ri = res_of[i]
                active[ri] -= 1
                done_flags[i] = True
                n_done += 1
                off = dep_base[i]
                for j in dependents[i]:
                    j += off
                    indeg[j] -= 1
                    if not indeg[j]:
                        # inlined FIFO immediate dispatch (the dominant
                        # release path); everything else falls back to the
                        # general _enqueue
                        rj = res_of[j]
                        if (rj < n_res_known and not shared_res[rj]
                                and not queues[rj]
                                and active[rj] < servers[rj]):
                            dur = durs[j]
                            used[rj] = True
                            starts[j] = now
                            end = now + dur
                            ends[j] = end
                            if (dur == 0.0 and not dependents[j]
                                    and self.on_complete is None
                                    and j not in on_done):
                                # completes at `now` with no observable
                                # effect between dispatch and completion
                                # (no deps to release, no callbacks): skip
                                # the event round-trip entirely
                                done_flags[j] = True
                                n_done += 1
                                continue
                            active[rj] += 1
                            busy[rj] += dur
                            self._seq += 1
                            push(events, (end, self._seq, "done", j))
                        else:
                            enqueue(j, now)
                            n_res_known = len(servers)
                cb = self.on_complete
                if cb is not None:
                    cb(c.task_of(i), now)
                if on_done:
                    h = on_done.pop(i, None)
                    if h is not None:
                        h(now)
                    n_res_known = len(servers)
                if queues[ri]:
                    self._drain(ri)
                if prb is not None:
                    p_done.add(now)
            elif kind == "lane":
                ln, handler, epoch = payload
                if epoch != ln.epoch:
                    continue                  # superseded by a truncation
                ln.busy = False
                handler(self._now)
                if prb is not None:
                    p_lane.add(self._now)
            elif kind == "call":
                payload()
                if prb is not None:
                    p_call.add(self._now)
            else:                             # channel completion(s)
                ri, epoch = payload
                if epoch != self._ch_epoch[ri]:
                    continue                  # superseded by a re-plan
                now = self._now
                m = self._ch_n[ri]
                dt = now - self._ch_last[ri]
                if dt > 0.0:
                    if m:
                        srv = self._servers[ri]
                        self._ch_vnow[ri] += dt * (1.0 if m <= srv
                                                   else srv / m)
                    self._ch_last[ri] = now
                heap = self._ch_heap[ri]
                vf0, _, i = pop(heap)
                if vf0 > self._ch_vnow[ri]:   # absorb scheduling round-off
                    self._ch_vnow[ri] = vf0
                m -= 1
                done = [i]
                while heap:
                    vf, _, i2 = heap[0]
                    if vf - vf0 > rel_eps * durs[i2]:
                        break
                    pop(heap)
                    m -= 1
                    done.append(i2)
                self._ch_n[ri] = m
                if len(done) > 1:
                    done.sort(key=tids.__getitem__)  # complete in tid order
                for i in done:
                    busy[ri] += durs[i]
                    ends[i] = now
                    done_flags[i] = True
                    n_done += 1
                    off = dep_base[i]
                    for j in dependents[i]:
                        j += off
                        indeg[j] -= 1
                        if not indeg[j]:
                            enqueue(j, now)
                    cb = self.on_complete
                    if cb is not None:
                        cb(c.task_of(i), now)
                    if on_done:
                        h = on_done.pop(i, None)
                        if h is not None:
                            h(now)
                    if prb is not None:
                        p_chan.add(now)
                self._reschedule_channel(ri)
                if prb is not None:
                    self._chan_probe(ri, now)

        self._n_done = n_done
        if self._n_done != c.n:
            stuck = [i for i in range(c.n) if c.indeg[i] > 0]
            raise RuntimeError(
                f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
                f"{[c.task_of(i).name for i in stuck[:5]]}")
        self._running = False

        n = c.n
        starts, ends = self._starts, self._ends
        makespan = max(ends) if n else 0.0
        lay_of = c.layer_of
        lay_lo = [float("inf")] * len(c.layer_names)
        lay_hi = [float("-inf")] * len(c.layer_names)
        for i in range(n):
            li = lay_of[i]
            if starts[i] < lay_lo[li]:
                lay_lo[li] = starts[i]
            if ends[i] > lay_hi[li]:
                lay_hi[li] = ends[i]
        layer_time = {name: (lay_lo[li], lay_hi[li])
                      for li, name in enumerate(c.layer_names)
                      if lay_lo[li] != float("inf")}
        resource_busy = {name: self._busy[ri]
                         for ri, name in enumerate(c.res_names)
                         if self._used[ri]}

        lanes = [ln for ln in self._lanes if ln._nonempty()]
        for ln in lanes:
            makespan = max(makespan, ln._merge(resource_busy, layer_time))

        tid_base = self._next_tid

        def materialize() -> List[TaskRecord]:
            out = [TaskRecord(c.task_of(i), starts[i], ends[i])
                   for i in range(n)]
            base = tid_base
            for ln in lanes:
                recs = ln._materialize(base)
                out.extend(recs)
                base += len(recs)
            return out

        return SimResult(makespan=makespan, records_thunk=materialize,
                         resource_busy=resource_busy, layer_time=layer_time)
