"""Discrete-event simulation engine (the SystemC / Platform Architect analog).

Executes a hardware-adapted task graph on named resources while preserving
causality — the property the paper argues distinguishes simulation from
statistical estimation: a DMA that a compute task depends on *blocks* it,
and transfers sharing a link contend for its bandwidth.

Resources come in two flavours (:class:`ResourceSpec`):

  * ``fifo``   — a ``servers``-wide FIFO station: up to ``servers`` tasks
    run concurrently, each at full rate; excess tasks queue in ready order
    (tie-broken by task id for determinism).  A single-server FIFO is the
    classic exclusive resource.
  * ``shared`` — a bandwidth-shared channel (generalized processor
    sharing): every admitted task progresses at rate
    ``min(1, servers / n_active)``, so total throughput never exceeds
    ``servers`` times the annotated full rate.  Two collectives sharing an
    ICI link each see half the bandwidth instead of strictly serializing.

Task durations are pre-annotated at *full rate* by the virtual hardware
models (repro.core.taskgraph.compiler); contention stretches them.
Unknown resources default to a single-server FIFO, so plain task lists
behave exactly as the original exclusive-resource engine.

Beyond static graphs, the engine supports **dynamic event injection** — the
foundation of the traffic-driven serving simulator (``repro.serve_sim``):

  * :meth:`Simulator.at` schedules a timed callback (e.g. a request
    arrival) that runs inside the event loop and may inject new work;
  * :meth:`Simulator.inject` adds a task *while the simulation runs*; its
    dependencies may already be satisfied or still in flight;
  * ``on_complete`` observers fire as tasks finish, letting a scheduler
    react causally (free a slot, admit the next request, issue the next
    decode step);
  * :meth:`Simulator.lane` opens a :class:`ServiceLane` — the express path
    for the dominant serving pattern (one task at a time on a dedicated
    single-server resource, submitted only when idle) that skips Task
    construction and dependency bookkeeping entirely.

Static task graphs are simply the special case with no callbacks — and
for them :func:`simulate_static` runs the same causal semantics over
precomputed dependency arrays (:class:`StaticCache`) with deferred record
materialization, several times faster than the dict-based general loop.

Complexity: shared-link contention is O(log n) per event via virtual-time
generalized processor sharing — each admitted task gets a fixed virtual
finish time, completions pop from a heap, and real-to-virtual conversion
happens only at rate-change boundaries.  (The seed engine decremented
every active task's remaining work on every event: O(n) per event,
O(n^2) per burst of n concurrent transfers.)
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class ResourceSpec:
    """How a named resource serves tasks."""

    name: str
    servers: int = 1
    mode: str = "fifo"           # fifo | shared

    def __post_init__(self):
        if self.servers < 1:
            raise ValueError(f"resource {self.name}: servers must be >= 1")
        if self.mode not in ("fifo", "shared"):
            raise ValueError(f"resource {self.name}: unknown mode {self.mode}")


@dataclass(slots=True)
class Task:
    tid: int
    name: str
    layer: str                  # grouping key for per-layer stats
    resource: str               # e.g. "nce", "dma", "ici_model"
    duration: float             # seconds at full rate
    deps: Tuple[int, ...] = ()
    kind: str = "compute"       # compute | dma | collective | launch | host
    nbytes: int = 0
    flops: int = 0
    op_id: int = -1             # index of the originating LayerOp (-1: none)
    anno: Optional[object] = None   # RateAnno re-annotation rule (what-if)


@dataclass(slots=True)
class TaskRecord:
    task: Task
    start: float
    end: float


class SimResult:
    """Outcome of one simulation run.

    ``records`` may be materialized lazily: the static fast path and the
    serving lanes keep start/end arrays and only build ``TaskRecord``
    objects when a trace/Gantt export actually reads them.
    """

    __slots__ = ("makespan", "resource_busy", "layer_time", "_records",
                 "_records_thunk")

    def __init__(self, makespan: float,
                 records: Optional[List[TaskRecord]] = None,
                 resource_busy: Optional[Dict[str, float]] = None,
                 layer_time: Optional[Dict[str, Tuple[float, float]]] = None,
                 records_thunk: Optional[Callable[[], List[TaskRecord]]] = None):
        self.makespan = makespan
        self.resource_busy = resource_busy if resource_busy is not None else {}
        self.layer_time = layer_time if layer_time is not None else {}
        self._records = records
        self._records_thunk = records_thunk

    @property
    def records(self) -> List[TaskRecord]:
        if self._records is None:
            thunk = self._records_thunk
            self._records = thunk() if thunk is not None else []
            self._records_thunk = None
        return self._records

    def utilization(self, resource: str) -> float:
        return (self.resource_busy.get(resource, 0.0) / self.makespan
                if self.makespan > 0 else 0.0)

    def layer_durations(self) -> Dict[str, float]:
        return {k: e - s for k, (s, e) in self.layer_time.items()}

    def __repr__(self) -> str:
        n = "lazy" if self._records is None else len(self._records)
        return (f"SimResult(makespan={self.makespan!r}, "
                f"n_records={n}, "
                f"resources={sorted(self.resource_busy)})")


class _SharedChannel:
    """Virtual-time generalized processor sharing for one ``shared`` resource.

    All active tasks progress at the common rate ``min(1, servers / n)``,
    so completion order equals admission-virtual-finish order: a task
    admitted with ``work`` full-rate seconds at virtual time ``v`` finishes
    at fixed virtual time ``v + work``.  The virtual clock advances at the
    common rate and is converted to real time only at rate-change
    boundaries (admit / complete), making each channel event O(log n) in
    active tasks instead of the O(n) per-event remaining-work sweep of the
    seed engine.  ``epoch`` invalidates stale completion events.
    """

    __slots__ = ("servers", "heap", "work", "start", "vnow", "last_t",
                 "epoch", "n")

    #: near-tie completion tolerance, *relative* to each task's own
    #: full-rate duration.  (The seed engine used an absolute 1e-15 s
    #: cutoff, which completed genuinely unfinished tasks early whenever
    #: durations were themselves O(1e-15).)
    REL_EPS = 1e-12

    def __init__(self, servers: int):
        self.servers = servers
        self.heap: List[Tuple[float, int]] = []   # (virtual finish, tid)
        self.work: Dict[int, float] = {}
        self.start: Dict[int, float] = {}
        self.vnow = 0.0
        self.last_t = 0.0
        self.epoch = 0
        self.n = 0

    @property
    def rate(self) -> float:
        n = self.n
        return min(1.0, self.servers / n) if n else 1.0

    def advance(self, now: float) -> None:
        dt = now - self.last_t
        if dt > 0.0:
            if self.n:
                self.vnow += dt * self.rate
            self.last_t = now

    def admit(self, tid: int, work: float, now: float) -> None:
        self.advance(now)
        self.n += 1
        heapq.heappush(self.heap, (self.vnow + work, tid))
        self.work[tid] = work
        self.start[tid] = now

    def next_completion(self, now: float) -> Optional[float]:
        if not self.n:
            return None
        vf = self.heap[0][0]
        return now + max(vf - self.vnow, 0.0) / self.rate

    def pop_done(self, now: float) -> List[int]:
        """Pop the head task plus any near-ties.

        Called when the completion event scheduled for the current head
        fires (``epoch`` guarantees no admission or completion intervened),
        so the head is complete by construction — no absolute epsilon is
        needed.  Near-ties complete together only when within
        ``REL_EPS * work`` of the head's virtual finish.
        """
        self.advance(now)
        if not self.n:
            return []
        vf0, tid0 = heapq.heappop(self.heap)
        if vf0 > self.vnow:                # absorb scheduling round-off
            self.vnow = vf0
        self.n -= 1
        del self.work[tid0]
        done = [tid0]
        heap = self.heap
        while heap:
            vf, tid = heap[0]
            if vf - vf0 > self.REL_EPS * self.work[tid]:
                break
            heapq.heappop(heap)
            self.n -= 1
            del self.work[tid]
            done.append(tid)
        done.sort()
        return done


class ServiceLane:
    """Express path for dynamic service on one single-server FIFO resource.

    The traffic-driven serving simulator issues one prefill/decode task at
    a time per replica, always from an idle state — so the general
    inject/enqueue/drain machinery (Task construction, dependency and
    duration dicts, ready queues) is pure overhead.  A lane keeps plain
    start/end/kind arrays, schedules the completion event directly, and
    materializes ``TaskRecord``s lazily only when a trace is requested.

    ``name_fn(kind, info) -> str`` builds record names at materialization
    time, so per-step f-string formatting is also deferred.
    """

    __slots__ = ("sim", "resource", "busy", "busy_time", "starts", "ends",
                 "kinds", "infos", "name_fn")

    def __init__(self, sim: "Simulator", resource: str,
                 name_fn: Optional[Callable[[str, object], str]] = None):
        self.sim = sim
        self.resource = resource
        self.busy = False
        self.busy_time = 0.0
        self.starts: List[float] = []
        self.ends: List[float] = []
        self.kinds: List[str] = []
        self.infos: List[object] = []
        self.name_fn = name_fn

    def submit(self, duration: float, handler: Callable[[float], None],
               kind: str = "task", info: object = None) -> None:
        """Start a task now; ``handler(now)`` runs when it completes."""
        if self.busy:
            raise RuntimeError(f"lane {self.resource!r} is busy")
        sim = self.sim
        self.busy = True
        start = sim._now
        end = start + duration
        self.starts.append(start)
        self.ends.append(end)
        self.kinds.append(kind)
        self.infos.append(info)
        self.busy_time += duration
        sim._seq += 1
        heapq.heappush(sim._events, (end, sim._seq, "lane", (self, handler)))

    def _materialize(self, tid0: int) -> List[TaskRecord]:
        name_fn = self.name_fn
        res = self.resource
        out = []
        for i, (s, e, k, info) in enumerate(zip(self.starts, self.ends,
                                                self.kinds, self.infos)):
            name = name_fn(k, info) if name_fn is not None else f"{res}/{k}"
            out.append(TaskRecord(
                Task(tid=tid0 + i, name=name, layer=res, resource=res,
                     duration=e - s, kind=k), s, e))
        return out


class Simulator:
    """Event-driven scheduler over FIFO and bandwidth-shared resources.

    The event loop is instance-level state, so timed callbacks
    (:meth:`at`) and completion observers (``on_complete``) can inject
    new tasks (:meth:`inject`) while the simulation is running — dynamic
    arrivals preempting a static task graph.
    """

    def __init__(self, tasks: Iterable[Task] = (),
                 resources: Optional[Dict[str, ResourceSpec]] = None,
                 durations=None,
                 on_complete: Optional[Callable[[Task, float], None]] = None):
        """``durations`` optionally overrides each task's annotated duration
        (aligned with ``tasks``); the what-if fast path re-annotates a graph
        by swapping this array, leaving the Task objects untouched."""
        tasks = list(tasks)
        self.tasks = {t.tid: t for t in tasks}
        if len(self.tasks) != len(tasks):
            raise ValueError("duplicate task ids")
        if durations is None:
            self.durations = {t.tid: t.duration for t in tasks}
        else:
            if len(durations) != len(tasks):
                raise ValueError("durations must align with tasks")
            self.durations = {t.tid: float(d)
                              for t, d in zip(tasks, durations)}
        self.resources = dict(resources or {})
        self.on_complete = on_complete
        self._validate(tasks)
        self._next_tid = max(self.tasks, default=-1) + 1
        # ---- event-loop state (live during run()) ----
        self._now = 0.0
        self._seq = 0
        self._running = False
        self._completed_ids: set = set()
        self._n_deps: Dict[int, int] = {}
        self._dependents: Dict[int, List[int]] = {}
        # per-FIFO-resource ready queue: (ready_time, tid)
        self._queues: Dict[str, List[Tuple[float, int]]] = {}
        self._active: Dict[str, int] = {}     # fifo resource -> active count
        self._channels: Dict[str, _SharedChannel] = {}
        self._res_busy: Dict[str, float] = {}
        self._records: List[TaskRecord] = []
        self._lanes: List[ServiceLane] = []
        # event heap: (time, seq, kind, payload)
        #   kind 'done'  — a fifo task finished (payload = tid)
        #   kind 'chan'  — a shared channel may have completions
        #                  (payload = (resource, epoch))
        #   kind 'call'  — a timed callback (payload = zero-arg callable)
        #   kind 'lane'  — a service-lane task finished
        #                  (payload = (lane, handler))
        self._events: List[Tuple[float, int, str, object]] = []

    def _validate(self, tasks: List[Task]) -> None:
        ids = set(self.tasks)
        for t in tasks:
            for d in t.deps:
                if d not in ids:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")

    def _spec(self, resource: str) -> ResourceSpec:
        return self.resources.get(resource) or ResourceSpec(name=resource)

    # ------------------------------------------------------------------
    # Dynamic injection API
    # ------------------------------------------------------------------

    @property
    def now(self) -> float:
        """Current simulation time."""
        return self._now

    def at(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to run inside the event loop at time ``t``.

        Callbacks at equal times run in scheduling order.  ``fn`` may call
        :meth:`inject` / :meth:`at` — this is how open-loop arrivals and
        scheduler timeouts enter a running simulation.
        """
        if t < self._now - 1e-18:
            raise ValueError(f"cannot schedule at {t} < now ({self._now})")
        self._push_event(max(t, self._now), "call", fn)

    def inject(self, task: Task) -> Task:
        """Add ``task`` to a (possibly running) simulation.

        Dependencies may reference completed or in-flight tasks.  The task
        becomes ready once its outstanding dependencies finish (immediately
        if there are none).
        """
        if task.tid in self.tasks:
            raise ValueError(f"duplicate task id {task.tid}")
        for d in task.deps:
            if d not in self.tasks:
                raise ValueError(f"task {task.tid} depends on unknown {d}")
        self.tasks[task.tid] = task
        self.durations[task.tid] = task.duration
        self._next_tid = max(self._next_tid, task.tid + 1)
        if not self._running:
            return task
        outstanding = [d for d in task.deps if d not in self._completed_ids]
        self._n_deps[task.tid] = len(outstanding)
        self._dependents.setdefault(task.tid, [])
        for d in outstanding:
            self._dependents.setdefault(d, []).append(task.tid)
        if not outstanding:
            self._enqueue(task.tid, self._now)
        return task

    def lane(self, resource: str,
             name_fn: Optional[Callable[[str, object], str]] = None
             ) -> ServiceLane:
        """Open a :class:`ServiceLane` on a dedicated single-server
        resource (see the class docstring for the contract)."""
        ln = ServiceLane(self, resource, name_fn)
        self._lanes.append(ln)
        return ln

    def next_task_id(self) -> int:
        """A fresh task id (monotone counter above every existing id)."""
        return self._next_tid

    # ------------------------------------------------------------------
    # Event loop internals
    # ------------------------------------------------------------------

    def _push_event(self, t_ev: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t_ev, self._seq, kind, payload))

    def _reschedule_channel(self, res: str) -> None:
        ch = self._channels[res]
        ch.epoch += 1
        t_next = ch.next_completion(self._now)
        if t_next is not None:
            self._push_event(t_next, "chan", (res, ch.epoch))

    def _enqueue(self, tid: int, t_ready: float) -> None:
        t = self.tasks[tid]
        spec = self._spec(t.resource)
        if spec.mode == "shared":
            ch = self._channels.get(t.resource)
            if ch is None:
                ch = self._channels[t.resource] = _SharedChannel(spec.servers)
            ch.admit(tid, self.durations[tid], t_ready)
            self._reschedule_channel(t.resource)
        else:
            q = self._queues.setdefault(t.resource, [])
            heapq.heappush(q, (t_ready, tid))
            self._drain(t.resource)

    def _drain(self, resource: str) -> None:
        spec = self._spec(resource)
        q = self._queues.get(resource)
        while q and self._active.get(resource, 0) < spec.servers:
            t_ready, tid = heapq.heappop(q)
            t = self.tasks[tid]
            dur = self.durations[tid]
            start = max(t_ready, self._now)
            end = start + dur
            self._active[resource] = self._active.get(resource, 0) + 1
            self._res_busy[resource] = self._res_busy.get(resource, 0.0) + dur
            self._records.append(TaskRecord(t, start, end))
            self._push_event(end, "done", tid)

    def _complete(self, tid: int) -> None:
        self._completed_ids.add(tid)
        for dep_tid in self._dependents.get(tid, ()):
            self._n_deps[dep_tid] -= 1
            if self._n_deps[dep_tid] == 0:
                self._enqueue(dep_tid, self._now)
        if self.on_complete is not None:
            self.on_complete(self.tasks[tid], self._now)

    def run(self) -> SimResult:
        if self._running or self._completed_ids:
            raise RuntimeError("Simulator.run() may only be called once")
        self._running = True
        self._n_deps = {tid: len(t.deps) for tid, t in self.tasks.items()}
        self._dependents = {tid: [] for tid in self.tasks}
        for t in self.tasks.values():
            for d in t.deps:
                self._dependents[d].append(t.tid)

        for tid, n in list(self._n_deps.items()):
            if n == 0:
                self._enqueue(tid, 0.0)

        events = self._events
        while events:
            self._now, _, kind, payload = heapq.heappop(events)
            if kind == "done":
                tid = payload
                t = self.tasks[tid]
                self._active[t.resource] -= 1
                self._complete(tid)
                self._drain(t.resource)
            elif kind == "lane":
                ln, handler = payload
                ln.busy = False
                handler(self._now)
            elif kind == "call":
                payload()
            else:  # 'chan'
                res, epoch = payload
                ch = self._channels[res]
                if epoch != ch.epoch:
                    continue                      # superseded by a re-plan
                for tid in ch.pop_done(self._now):
                    t = self.tasks[tid]
                    self._res_busy[res] = (self._res_busy.get(res, 0.0)
                                           + self.durations[tid])
                    self._records.append(
                        TaskRecord(t, ch.start.pop(tid), self._now))
                    self._complete(tid)
                self._reschedule_channel(res)

        if len(self._completed_ids) != len(self.tasks):
            stuck = [tid for tid, n in self._n_deps.items() if n > 0]
            raise RuntimeError(
                f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
                f"{[self.tasks[t].name for t in stuck[:5]]}")
        self._running = False

        makespan = max((r.end for r in self._records), default=0.0)
        layer_time: Dict[str, Tuple[float, float]] = {}
        for r in self._records:
            lay = r.task.layer
            if lay in layer_time:
                s, e = layer_time[lay]
                layer_time[lay] = (min(s, r.start), max(e, r.end))
            else:
                layer_time[lay] = (r.start, r.end)

        lanes = [ln for ln in self._lanes if ln.starts]
        for ln in lanes:
            makespan = max(makespan, ln.ends[-1])
            self._res_busy[ln.resource] = (
                self._res_busy.get(ln.resource, 0.0) + ln.busy_time)
            span = (ln.starts[0], ln.ends[-1])
            if ln.resource in layer_time:
                s, e = layer_time[ln.resource]
                span = (min(s, span[0]), max(e, span[1]))
            layer_time[ln.resource] = span

        if not lanes:
            return SimResult(makespan=makespan, records=self._records,
                             resource_busy=self._res_busy,
                             layer_time=layer_time)

        static_records = self._records
        tid0 = self._next_tid

        def materialize() -> List[TaskRecord]:
            out = list(static_records)
            base = tid0
            for ln in lanes:
                out.extend(ln._materialize(base))
                base += len(ln.starts)
            return out

        return SimResult(makespan=makespan, records_thunk=materialize,
                         resource_busy=self._res_busy, layer_time=layer_time)


# ---------------------------------------------------------------------------
# Array-backed fast path for static graphs
# ---------------------------------------------------------------------------


class StaticCache:
    """Precomputed dependency/resource structure for one static task list.

    System-independent: resource *names*, the dependency CSR, and layer
    grouping depend only on the task list, so a cache built once per
    compiled graph is shared across every re-annotated what-if variant
    (``CompiledGraph.sim_cache()``).  Per-system resource widths/modes and
    the duration vector are passed to :func:`simulate_static` per run.
    """

    __slots__ = ("n", "index_of", "tids", "dependents", "indeg", "res_of",
                 "res_names", "layer_of", "layer_names")

    def __init__(self, tasks: Sequence[Task]):
        n = len(tasks)
        self.n = n
        self.tids = [t.tid for t in tasks]
        index_of = {t.tid: i for i, t in enumerate(tasks)}
        if len(index_of) != n:
            raise ValueError("duplicate task ids")
        self.index_of = index_of
        res_index: Dict[str, int] = {}
        lay_index: Dict[str, int] = {}
        res_of = [0] * n
        lay_of = [0] * n
        indeg = [0] * n
        dependents: List[List[int]] = [[] for _ in range(n)]
        for i, t in enumerate(tasks):
            r = t.resource
            ri = res_index.get(r)
            if ri is None:
                ri = res_index[r] = len(res_index)
            res_of[i] = ri
            lay = t.layer
            li = lay_index.get(lay)
            if li is None:
                li = lay_index[lay] = len(lay_index)
            lay_of[i] = li
            indeg[i] = len(t.deps)
            for d in t.deps:
                j = index_of.get(d)
                if j is None:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")
                dependents[j].append(i)
        self.dependents = [tuple(dd) for dd in dependents]
        self.indeg = indeg
        self.res_of = res_of
        self.res_names = list(res_index)
        self.layer_of = lay_of
        self.layer_names = list(lay_index)


def simulate_static(tasks: Sequence[Task],
                    resources: Optional[Dict[str, ResourceSpec]] = None,
                    durations=None,
                    cache: Optional[StaticCache] = None) -> SimResult:
    """Run a *static* task graph (no callbacks, no injection) over
    precomputed dependency arrays.

    Same causal semantics as :class:`Simulator` — multi-server FIFO
    stations, virtual-time processor-sharing channels, identical
    tie-breaking — but the hot loop indexes flat lists instead of dicts
    and defers ``TaskRecord`` materialization until a trace is read, so
    ``reannotate``-then-simulate sweep points skip all per-task object
    churn.  Exact-parity with the general engine is asserted by
    ``tests/test_engine_parity.py``.
    """
    tasks = tasks if isinstance(tasks, list) else list(tasks)
    if cache is None:
        cache = StaticCache(tasks)
    n = cache.n
    resources = resources or {}
    if durations is None:
        durs = [t.duration for t in tasks]
    elif hasattr(durations, "tolist"):
        durs = durations.tolist()
        if len(durs) != n:
            raise ValueError("durations must align with tasks")
    else:
        if len(durations) != n:
            raise ValueError("durations must align with tasks")
        durs = [float(d) for d in durations]

    n_res = len(cache.res_names)
    shared = [False] * n_res
    servers = [1] * n_res
    for ri, name in enumerate(cache.res_names):
        spec = resources.get(name)
        if spec is not None:
            shared[ri] = spec.mode == "shared"
            servers[ri] = spec.servers

    res_of = cache.res_of
    tids = cache.tids            # equal-time ties break by tid, not index,
    dependents = cache.dependents    # mirroring the general Simulator
    indeg = list(cache.indeg)
    starts = [0.0] * n
    ends = [0.0] * n
    busy = [0.0] * n_res
    active = [0] * n_res
    queues: List[List[Tuple[float, int]]] = [[] for _ in range(n_res)]
    # Shared channels live as flat per-resource state (virtual-time GPS
    # with the object/property overhead of _SharedChannel inlined away):
    ch_heap: List[Optional[List[Tuple[float, int]]]] = [None] * n_res
    ch_vnow = [0.0] * n_res      # virtual clock
    ch_last = [0.0] * n_res      # real time of the last advance
    ch_n = [0] * n_res           # active tasks
    ch_epoch = [0] * n_res       # invalidates superseded completion events
    rel_eps = _SharedChannel.REL_EPS
    events: List[Tuple[float, int, int, object]] = []
    # event tuple: (time, seq, code, payload); code 0 = fifo done
    # (payload = task index), code 1 = channel completion
    # (payload = (res index, epoch at issue))
    seq = 0
    now = 0.0
    n_done = 0
    push = heapq.heappush
    pop = heapq.heappop

    def reschedule(ri: int) -> None:
        nonlocal seq
        ch_epoch[ri] += 1
        m = ch_n[ri]
        if m:
            srv = servers[ri]
            rate = 1.0 if m <= srv else srv / m
            dv = ch_heap[ri][0][0] - ch_vnow[ri]
            t_next = now + (dv if dv > 0.0 else 0.0) / rate
            seq += 1
            push(events, (t_next, seq, 1, (ri, ch_epoch[ri])))

    def drain(ri: int) -> None:
        nonlocal seq
        q = queues[ri]
        cap = servers[ri]
        while q and active[ri] < cap:
            t_ready, _, i = pop(q)
            dur = durs[i]
            start = t_ready if t_ready > now else now
            end = start + dur
            active[ri] += 1
            busy[ri] += dur
            starts[i] = start
            ends[i] = end
            seq += 1
            push(events, (end, seq, 0, i))

    def enqueue(i: int, t_ready: float) -> None:
        ri = res_of[i]
        if shared[ri]:
            heap = ch_heap[ri]
            if heap is None:
                heap = ch_heap[ri] = []
            m = ch_n[ri]
            dt = t_ready - ch_last[ri]
            if dt > 0.0:                      # advance the virtual clock
                if m:
                    srv = servers[ri]
                    ch_vnow[ri] += dt * (1.0 if m <= srv else srv / m)
                ch_last[ri] = t_ready
            ch_n[ri] = m + 1
            push(heap, (ch_vnow[ri] + durs[i], tids[i], i))
            starts[i] = t_ready
            reschedule(ri)
        else:
            push(queues[ri], (t_ready, tids[i], i))
            drain(ri)

    for i in range(n):
        if indeg[i] == 0:
            enqueue(i, 0.0)

    while events:
        now, _, code, payload = pop(events)
        if code == 0:                       # fifo completion
            i = payload
            active[res_of[i]] -= 1
            n_done += 1
            for j in dependents[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    enqueue(j, now)
            drain(res_of[i])
        else:                               # channel completion(s)
            ri, epoch = payload
            if epoch != ch_epoch[ri]:
                continue                    # superseded by a re-plan
            # advance the virtual clock to now
            m = ch_n[ri]
            dt = now - ch_last[ri]
            if dt > 0.0:
                if m:
                    srv = servers[ri]
                    ch_vnow[ri] += dt * (1.0 if m <= srv else srv / m)
                ch_last[ri] = now
            # the head is complete by construction (epoch was current);
            # pop it plus near-ties within the relative epsilon
            heap = ch_heap[ri]
            vf0, _, i = pop(heap)
            if vf0 > ch_vnow[ri]:           # absorb scheduling round-off
                ch_vnow[ri] = vf0
            m -= 1
            done = [i]
            while heap:
                vf, _, i2 = heap[0]
                if vf - vf0 > rel_eps * durs[i2]:
                    break
                pop(heap)
                m -= 1
                done.append(i2)
            ch_n[ri] = m
            if len(done) > 1:
                done.sort(key=tids.__getitem__)   # complete in tid order
            for i in done:
                busy[ri] += durs[i]
                ends[i] = now
                n_done += 1
                for j in dependents[i]:
                    indeg[j] -= 1
                    if indeg[j] == 0:
                        enqueue(j, now)
            reschedule(ri)

    if n_done != n:
        stuck = [i for i in range(n) if indeg[i] > 0]
        raise RuntimeError(
            f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
            f"{[tasks[i].name for i in stuck[:5]]}")

    makespan = max(ends) if n else 0.0
    lay_of = cache.layer_of
    lay_lo = [float("inf")] * len(cache.layer_names)
    lay_hi = [float("-inf")] * len(cache.layer_names)
    for i in range(n):
        li = lay_of[i]
        s = starts[i]
        e = ends[i]
        if s < lay_lo[li]:
            lay_lo[li] = s
        if e > lay_hi[li]:
            lay_hi[li] = e
    layer_time = {name: (lay_lo[li], lay_hi[li])
                  for li, name in enumerate(cache.layer_names)}
    resource_busy = {name: busy[ri]
                     for ri, name in enumerate(cache.res_names)}

    def materialize() -> List[TaskRecord]:
        return [TaskRecord(tasks[i], starts[i], ends[i]) for i in range(n)]

    return SimResult(makespan=makespan, records_thunk=materialize,
                     resource_busy=resource_busy, layer_time=layer_time)
