"""Discrete-event simulation engine (the SystemC / Platform Architect analog).

Executes a hardware-adapted task graph on named resources while preserving
causality — the property the paper argues distinguishes simulation from
statistical estimation: a DMA that a compute task depends on *blocks* it,
and transfers sharing a link contend for its bandwidth.

Resources come in two flavours (:class:`ResourceSpec`):

  * ``fifo``   — a ``servers``-wide FIFO station: up to ``servers`` tasks
    run concurrently, each at full rate; excess tasks queue in ready order
    (tie-broken by task id for determinism).  A single-server FIFO is the
    classic exclusive resource.
  * ``shared`` — a bandwidth-shared channel (generalized processor
    sharing): every admitted task progresses at rate
    ``min(1, servers / n_active)``, so total throughput never exceeds
    ``servers`` times the annotated full rate.  Two collectives sharing an
    ICI link each see half the bandwidth instead of strictly serializing.

Task durations are pre-annotated at *full rate* by the virtual hardware
models (repro.core.taskgraph.compiler); contention stretches them.
Unknown resources default to a single-server FIFO, so plain task lists
behave exactly as the original exclusive-resource engine.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.taskgraph.anno import RateAnno


@dataclass(frozen=True)
class ResourceSpec:
    """How a named resource serves tasks."""

    name: str
    servers: int = 1
    mode: str = "fifo"           # fifo | shared

    def __post_init__(self):
        if self.servers < 1:
            raise ValueError(f"resource {self.name}: servers must be >= 1")
        if self.mode not in ("fifo", "shared"):
            raise ValueError(f"resource {self.name}: unknown mode {self.mode}")


@dataclass
class Task:
    tid: int
    name: str
    layer: str                  # grouping key for per-layer stats
    resource: str               # e.g. "nce", "dma", "ici_model"
    duration: float             # seconds at full rate
    deps: Tuple[int, ...] = ()
    kind: str = "compute"       # compute | dma | collective | launch | host
    nbytes: int = 0
    flops: int = 0
    op_id: int = -1             # index of the originating LayerOp (-1: none)
    anno: Optional[RateAnno] = None   # re-annotation rule (what-if fast path)


@dataclass
class TaskRecord:
    task: Task
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    records: List[TaskRecord]
    resource_busy: Dict[str, float]
    layer_time: Dict[str, Tuple[float, float]]   # layer -> (start, end)

    def utilization(self, resource: str) -> float:
        return (self.resource_busy.get(resource, 0.0) / self.makespan
                if self.makespan > 0 else 0.0)

    def layer_durations(self) -> Dict[str, float]:
        return {k: e - s for k, (s, e) in self.layer_time.items()}


class _SharedChannel:
    """Processor-sharing state for one ``shared`` resource.

    ``remaining`` holds full-rate seconds of work left per active task;
    real time stretches by ``n_active / servers`` whenever the channel is
    oversubscribed.  ``epoch`` invalidates stale completion events.
    """

    __slots__ = ("servers", "remaining", "start", "last_t", "epoch")

    def __init__(self, servers: int):
        self.servers = servers
        self.remaining: Dict[int, float] = {}
        self.start: Dict[int, float] = {}
        self.last_t = 0.0
        self.epoch = 0

    @property
    def rate(self) -> float:
        n = len(self.remaining)
        return min(1.0, self.servers / n) if n else 1.0

    def advance(self, now: float) -> None:
        dt = now - self.last_t
        if dt > 0 and self.remaining:
            r = self.rate
            for tid in self.remaining:
                self.remaining[tid] -= dt * r
        self.last_t = now

    def admit(self, tid: int, work: float, now: float) -> None:
        self.advance(now)
        self.remaining[tid] = work
        self.start[tid] = now

    def next_completion(self, now: float) -> Optional[float]:
        if not self.remaining:
            return None
        rem = min(self.remaining.values())
        return now + max(rem, 0.0) / self.rate

    def pop_done(self, now: float) -> List[int]:
        """Task ids whose remaining work is (numerically) exhausted."""
        self.advance(now)
        if not self.remaining:
            return []
        rem_min = min(self.remaining.values())
        done = sorted(tid for tid, rem in self.remaining.items()
                      if rem <= rem_min + 1e-15 or rem <= 1e-18)
        for tid in done:
            del self.remaining[tid]
        return done


class Simulator:
    """Event-driven scheduler over FIFO and bandwidth-shared resources."""

    def __init__(self, tasks: List[Task],
                 resources: Optional[Dict[str, ResourceSpec]] = None,
                 durations=None):
        """``durations`` optionally overrides each task's annotated duration
        (aligned with ``tasks``); the what-if fast path re-annotates a graph
        by swapping this array, leaving the Task objects untouched."""
        self.tasks = {t.tid: t for t in tasks}
        if len(self.tasks) != len(tasks):
            raise ValueError("duplicate task ids")
        if durations is None:
            self.durations = {t.tid: t.duration for t in tasks}
        else:
            if len(durations) != len(tasks):
                raise ValueError("durations must align with tasks")
            self.durations = {t.tid: float(d)
                              for t, d in zip(tasks, durations)}
        self.resources = dict(resources or {})
        self._validate(tasks)

    def _validate(self, tasks: List[Task]) -> None:
        ids = set(self.tasks)
        for t in tasks:
            for d in t.deps:
                if d not in ids:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")

    def _spec(self, resource: str) -> ResourceSpec:
        return self.resources.get(resource) or ResourceSpec(name=resource)

    def run(self) -> SimResult:
        tasks = self.tasks
        n_deps = {tid: len(t.deps) for tid, t in tasks.items()}
        dependents: Dict[int, List[int]] = {tid: [] for tid in tasks}
        for t in tasks.values():
            for d in t.deps:
                dependents[d].append(t.tid)

        # per-FIFO-resource ready queue: (ready_time, tid)
        queues: Dict[str, List[Tuple[float, int]]] = {}
        running: Dict[str, int] = {}          # fifo resource -> active count
        channels: Dict[str, _SharedChannel] = {}
        res_busy: Dict[str, float] = {}
        records: List[TaskRecord] = []
        # event heap: (time, seq, kind, payload)
        #   kind 'done'  — a fifo task finished (payload = tid)
        #   kind 'chan'  — a shared channel may have completions
        #                  (payload = (resource, epoch))
        events: List[Tuple[float, int, str, object]] = []
        seq = 0
        completed = 0
        now = 0.0

        def push_event(t_ev: float, kind: str, payload) -> None:
            nonlocal seq
            seq += 1
            heapq.heappush(events, (t_ev, seq, kind, payload))

        def reschedule_channel(res: str) -> None:
            ch = channels[res]
            ch.epoch += 1
            t_next = ch.next_completion(now)
            if t_next is not None:
                push_event(t_next, "chan", (res, ch.epoch))

        durations = self.durations

        def enqueue(tid: int, t_ready: float) -> None:
            t = tasks[tid]
            spec = self._spec(t.resource)
            if spec.mode == "shared":
                ch = channels.get(t.resource)
                if ch is None:
                    ch = channels[t.resource] = _SharedChannel(spec.servers)
                ch.admit(tid, durations[tid], t_ready)
                reschedule_channel(t.resource)
            else:
                q = queues.setdefault(t.resource, [])
                heapq.heappush(q, (t_ready, tid))
                drain(t.resource)

        def drain(resource: str) -> None:
            spec = self._spec(resource)
            q = queues.get(resource)
            while q and running.get(resource, 0) < spec.servers:
                t_ready, tid = heapq.heappop(q)
                t = tasks[tid]
                dur = durations[tid]
                start = max(t_ready, now)
                end = start + dur
                running[resource] = running.get(resource, 0) + 1
                res_busy[resource] = res_busy.get(resource, 0.0) + dur
                records.append(TaskRecord(t, start, end))
                push_event(end, "done", tid)

        def complete(tid: int) -> None:
            nonlocal completed
            completed += 1
            for dep_tid in dependents[tid]:
                n_deps[dep_tid] -= 1
                if n_deps[dep_tid] == 0:
                    enqueue(dep_tid, now)

        for tid in tasks:
            if n_deps[tid] == 0:
                enqueue(tid, 0.0)

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == "done":
                tid = payload
                t = tasks[tid]
                running[t.resource] -= 1
                complete(tid)
                drain(t.resource)
            else:  # 'chan'
                res, epoch = payload
                ch = channels[res]
                if epoch != ch.epoch:
                    continue                      # superseded by a re-plan
                for tid in ch.pop_done(now):
                    t = tasks[tid]
                    res_busy[res] = res_busy.get(res, 0.0) + durations[tid]
                    records.append(TaskRecord(t, ch.start.pop(tid), now))
                    complete(tid)
                reschedule_channel(res)

        if completed != len(tasks):
            stuck = [tid for tid, n in n_deps.items() if n > 0]
            raise RuntimeError(
                f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
                f"{[tasks[t].name for t in stuck[:5]]}")

        makespan = max((r.end for r in records), default=0.0)
        layer_time: Dict[str, Tuple[float, float]] = {}
        for r in records:
            lay = r.task.layer
            if lay in layer_time:
                s, e = layer_time[lay]
                layer_time[lay] = (min(s, r.start), max(e, r.end))
            else:
                layer_time[lay] = (r.start, r.end)

        return SimResult(makespan=makespan, records=records,
                         resource_busy=res_busy, layer_time=layer_time)
