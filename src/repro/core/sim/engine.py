"""Discrete-event simulation engine (the SystemC / Platform Architect analog).

Executes a hardware-adapted task graph on named FIFO resources while
preserving causality — the property the paper argues distinguishes
simulation from statistical estimation: a DMA that a compute task depends
on *blocks* it, and two collectives sharing a link serialize.

Semantics:
  * a task becomes READY when all dependencies completed;
  * each resource runs one task at a time, FIFO in ready order
    (tie-broken by task id for determinism);
  * task duration is pre-annotated by the virtual hardware models
    (repro.core.taskgraph.compiler).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Task:
    tid: int
    name: str
    layer: str                  # grouping key for per-layer stats
    resource: str               # e.g. "nce", "dma0", "ici_x"
    duration: float             # seconds
    deps: Tuple[int, ...] = ()
    kind: str = "compute"       # compute | dma | collective | launch | host
    nbytes: int = 0
    flops: int = 0


@dataclass
class TaskRecord:
    task: Task
    start: float
    end: float


@dataclass
class SimResult:
    makespan: float
    records: List[TaskRecord]
    resource_busy: Dict[str, float]
    layer_time: Dict[str, Tuple[float, float]]   # layer -> (start, end)

    def utilization(self, resource: str) -> float:
        return (self.resource_busy.get(resource, 0.0) / self.makespan
                if self.makespan > 0 else 0.0)

    def layer_durations(self) -> Dict[str, float]:
        return {k: e - s for k, (s, e) in self.layer_time.items()}


class Simulator:
    """Event-driven list scheduler over FIFO resources."""

    def __init__(self, tasks: List[Task]):
        self.tasks = {t.tid: t for t in tasks}
        if len(self.tasks) != len(tasks):
            raise ValueError("duplicate task ids")
        self._validate(tasks)

    def _validate(self, tasks: List[Task]) -> None:
        ids = set(self.tasks)
        for t in tasks:
            for d in t.deps:
                if d not in ids:
                    raise ValueError(f"task {t.tid} depends on unknown {d}")

    def run(self) -> SimResult:
        tasks = self.tasks
        n_deps = {tid: len(t.deps) for tid, t in tasks.items()}
        dependents: Dict[int, List[int]] = {tid: [] for tid in tasks}
        for t in tasks.values():
            for d in t.deps:
                dependents[d].append(t.tid)

        # per-resource FIFO queue of ready tasks: (ready_time, tid)
        queues: Dict[str, List[Tuple[float, int]]] = {}
        res_free: Dict[str, float] = {}
        res_busy: Dict[str, float] = {}
        records: List[TaskRecord] = []
        # event heap: (time, seq, kind, payload); kinds: 'done'
        events: List[Tuple[float, int, str, int]] = []
        seq = 0
        completed = 0
        running: Dict[str, Optional[int]] = {}

        def enqueue(tid: int, t_ready: float):
            t = tasks[tid]
            q = queues.setdefault(t.resource, [])
            heapq.heappush(q, (t_ready, tid))
            try_start(t.resource)

        def try_start(resource: str):
            nonlocal seq
            if running.get(resource) is not None:
                return
            q = queues.get(resource)
            if not q:
                return
            t_ready, tid = heapq.heappop(q)
            t = tasks[tid]
            start = max(t_ready, res_free.get(resource, 0.0))
            end = start + t.duration
            running[resource] = tid
            res_free[resource] = end
            res_busy[resource] = res_busy.get(resource, 0.0) + t.duration
            records.append(TaskRecord(t, start, end))
            seq += 1
            heapq.heappush(events, (end, seq, "done", tid))

        now = 0.0
        for tid, t in tasks.items():
            if n_deps[tid] == 0:
                enqueue(tid, 0.0)

        while events:
            now, _, _, tid = heapq.heappop(events)
            t = tasks[tid]
            running[t.resource] = None
            completed += 1
            for dep_tid in dependents[tid]:
                n_deps[dep_tid] -= 1
                if n_deps[dep_tid] == 0:
                    enqueue(dep_tid, now)
            try_start(t.resource)

        if completed != len(tasks):
            stuck = [tid for tid, n in n_deps.items() if n > 0]
            raise RuntimeError(
                f"deadlock/cycle: {len(stuck)} tasks never ran, e.g. "
                f"{[tasks[t].name for t in stuck[:5]]}")

        layer_time: Dict[str, Tuple[float, float]] = {}
        for r in records:
            lay = r.task.layer
            if lay in layer_time:
                s, e = layer_time[lay]
                layer_time[lay] = (min(s, r.start), max(e, r.end))
            else:
                layer_time[lay] = (r.start, r.end)

        return SimResult(makespan=now, records=records,
                         resource_busy=res_busy, layer_time=layer_time)
