"""Gantt-chart export: Chrome trace-event JSON (loadable in Perfetto UI /
chrome://tracing) + an ASCII Gantt for terminals — the paper's Figure 4.

:func:`chrome_trace` renders a static task-graph ``SimResult`` (one lane
per hardware resource); :func:`serving_chrome_trace` renders a
traffic-driven ``ServingReport`` from ``repro.serve_sim`` (replica
prefill/decode lanes, per-slot request spans, and a queue-depth counter
track).

Reading ``result.records`` here is what materializes the lazy record
arrays kept by the engine's fast paths (``simulate_static``, serving
``ServiceLane``s) — simulations that are never exported pay nothing for
``TaskRecord`` construction.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.sim.engine import SimResult


def chrome_trace(result: SimResult, path: Optional[str] = None) -> str:
    """Emit Chrome trace-event JSON; one 'thread' per resource."""
    resources = sorted({r.task.resource for r in result.records})
    tid_of = {res: i for i, res in enumerate(resources)}
    events: List[Dict] = []
    for i, res in enumerate(resources):
        events.append({"ph": "M", "pid": 0, "tid": i,
                       "name": "thread_name", "args": {"name": res}})
    for rec in result.records:
        events.append({
            "ph": "X", "pid": 0, "tid": tid_of[rec.task.resource],
            "name": rec.task.name,
            "cat": rec.task.kind,
            "ts": rec.start * 1e6,            # microseconds
            "dur": max(rec.end - rec.start, 1e-9) * 1e6,
            "args": {"layer": rec.task.layer, "bytes": rec.task.nbytes,
                     "flops": rec.task.flops},
        })
    text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


def serving_chrome_trace(report, path: Optional[str] = None) -> str:
    """Chrome trace-event JSON for a serving simulation.

    ``report`` is a ``repro.serve_sim.simulator.ServingReport`` (typed
    loosely to keep core free of serve_sim imports).  Three tracks:

      * pid 0 ``replicas`` — prefill/decode tasks per replica (from the
        embedded ``SimResult``);
      * pid 1 ``requests`` — one lane per (replica, slot) with a span per
        request from admit to completion (args carry TTFT/TPOT);
      * pid 2 ``queue``    — a counter track of pending-queue depth.
    """
    events: List[Dict] = [
        {"ph": "M", "pid": 0, "name": "process_name",
         "args": {"name": "replicas"}},
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": "requests"}},
        {"ph": "M", "pid": 2, "name": "process_name",
         "args": {"name": "queue"}},
    ]

    if report.sim_result is not None:
        resources = sorted({r.task.resource
                            for r in report.sim_result.records})
        tid_of = {res: i for i, res in enumerate(resources)}
        for res, i in tid_of.items():
            events.append({"ph": "M", "pid": 0, "tid": i,
                           "name": "thread_name", "args": {"name": res}})
        for rec in report.sim_result.records:
            events.append({
                "ph": "X", "pid": 0, "tid": tid_of[rec.task.resource],
                "name": rec.task.name, "cat": rec.task.kind,
                "ts": rec.start * 1e6,
                "dur": max(rec.end - rec.start, 1e-9) * 1e6,
            })

    lanes: Dict = {}
    for m in report.requests:
        lane = (m.replica, m.slot)
        if lane not in lanes:
            lanes[lane] = len(lanes)
            events.append({"ph": "M", "pid": 1, "tid": lanes[lane],
                           "name": "thread_name",
                           "args": {"name": f"replica{lane[0]}/"
                                            f"slot{lane[1]}"}})
        tid = lanes[lane]
        events.append({
            "ph": "X", "pid": 1, "tid": tid, "name": f"req{m.rid}",
            "cat": "request",
            "ts": m.t_admit * 1e6,
            "dur": max(m.t_done - m.t_admit, 1e-9) * 1e6,
            "args": {"ttft_ms": m.ttft * 1e3, "tpot_ms": m.tpot * 1e3,
                     "queue_delay_ms": m.queue_delay * 1e3,
                     "prompt_tokens": m.prompt_tokens,
                     "output_tokens": m.output_tokens},
        })

    # queue-depth counter: +1 on arrival, -1 on admit
    deltas = []
    for m in report.requests:
        deltas.append((m.t_arrive, 1))
        deltas.append((m.t_admit, -1))
    depth = 0
    # arrivals (+1) before admits (-1) at equal times: depth never dips < 0
    for t, d in sorted(deltas, key=lambda td: (td[0], -td[1])):
        depth += d
        events.append({"ph": "C", "pid": 2, "name": "pending",
                       "ts": t * 1e6, "args": {"requests": depth}})

    text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


def ascii_gantt(result: SimResult, width: int = 100,
                max_rows: int = 24) -> str:
    """Terminal Gantt chart: one row per resource, '#' = busy."""
    records = result.records        # materializes lazy records once
    if not records or result.makespan <= 0:
        return "(empty)"
    # single pass: group records by resource (the per-resource scan was
    # O(records x resources) on big traces)
    by_res: Dict[str, List] = {}
    for rec in records:
        by_res.setdefault(rec.task.resource, []).append(rec)
    resources = sorted(by_res)[:max_rows]
    scale = width / result.makespan
    glyph = {"compute": "#", "dma": "=", "collective": "~",
             "launch": ".", "host": "."}
    lines = [f"t=0 {'':{width - 12}} t={result.makespan * 1e3:.3f} ms"]
    for res in resources:
        row = [" "] * width
        for rec in by_res[res]:
            a = min(width - 1, int(rec.start * scale))
            b = min(width, max(a + 1, int(rec.end * scale)))
            ch = glyph.get(rec.task.kind, "#")
            for i in range(a, b):
                row[i] = ch
        util = result.utilization(res)
        lines.append(f"{res:>12s} |{''.join(row)}| {util * 100:5.1f}%")
    lines.append(f"{'':>12s}  #=compute  ==dma  ~=collective")
    return "\n".join(lines)
