"""Gantt-chart export: Chrome trace-event JSON (loadable in Perfetto UI /
chrome://tracing) + an ASCII Gantt for terminals — the paper's Figure 4.

The span/counter emission lives in :class:`repro.obs.trace.TraceBuilder`
(the unified exporter); this module keeps the two historical entry
points as thin wrappers: :func:`chrome_trace` renders a static
task-graph ``SimResult`` (one lane per hardware resource) and
:func:`serving_chrome_trace` renders a traffic-driven ``ServingReport``
from ``repro.serve_sim`` (replica prefill/decode lanes, per-slot request
spans, and a queue-depth counter track).  The builder-returning variants
(:func:`trace_builder`, :func:`serving_trace_builder`) let callers — the
``runs/<name>/`` bundle writer in :mod:`repro.obs.artifacts` — add probe
counter tracks before serialization.

Reading ``result.records`` here is what materializes the lazy record
arrays kept by the engine's fast paths (``simulate_static``, serving
``ServiceLane``s) — simulations that are never exported pay nothing for
``TaskRecord`` construction.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.sim.engine import SimResult
from repro.obs.trace import TraceBuilder


def trace_builder(result: SimResult) -> TraceBuilder:
    """A :class:`TraceBuilder` holding one 'thread' per resource."""
    return TraceBuilder().add_records(result.records, pid=0,
                                      include_args=True)


def chrome_trace(result: SimResult, path: Optional[str] = None) -> str:
    """Emit Chrome trace-event JSON; one 'thread' per resource."""
    return trace_builder(result).to_json(path)


def serving_trace_builder(report) -> TraceBuilder:
    """A :class:`TraceBuilder` for a serving simulation.

    ``report`` is a ``repro.serve_sim.simulator.ServingReport`` (typed
    loosely to keep core free of serve_sim imports).  Three tracks:

      * pid 0 ``replicas`` — prefill/decode tasks per replica (from the
        embedded ``SimResult``);
      * pid 1 ``requests`` — one lane per (replica, slot) with a span per
        request from admit to completion (args carry TTFT/TPOT);
      * pid 2 ``queue``    — a counter track of pending-queue depth,
        closed with a final sample at the makespan so the track spans
        the whole run in Perfetto.
    """
    tb = TraceBuilder()
    tb.process(0, "replicas").process(1, "requests").process(2, "queue")

    if report.sim_result is not None:
        tb.add_records(report.sim_result.records, pid=0,
                       include_args=False)

    lanes: Dict = {}
    for m in report.requests:
        lane = (m.replica, m.slot)
        if lane not in lanes:
            lanes[lane] = len(lanes)
            tb.thread(1, lanes[lane],
                      f"replica{lane[0]}/slot{lane[1]}")
        tb.span(1, lanes[lane], f"req{m.rid}", m.t_admit, m.t_done,
                cat="request",
                args={"ttft_ms": m.ttft * 1e3, "tpot_ms": m.tpot * 1e3,
                      "queue_delay_ms": m.queue_delay * 1e3,
                      "prompt_tokens": m.prompt_tokens,
                      "output_tokens": m.output_tokens})

    # queue-depth counter: +1 on arrival, -1 on admit
    deltas: List = []
    for m in report.requests:
        deltas.append((m.t_arrive, 1))
        deltas.append((m.t_admit, -1))
    depth = 0
    t_last = 0.0
    # arrivals (+1) before admits (-1) at equal times: depth never dips < 0
    for t, d in sorted(deltas, key=lambda td: (td[0], -td[1])):
        depth += d
        t_last = t
        tb.counter(2, "pending", t, depth, key="requests")
    # close the track at simulation end so it doesn't truncate early
    if deltas and report.duration > t_last:
        tb.counter(2, "pending", report.duration, depth, key="requests")
    return tb


def serving_chrome_trace(report, path: Optional[str] = None) -> str:
    """Chrome trace-event JSON for a serving simulation (see
    :func:`serving_trace_builder` for the track layout)."""
    return serving_trace_builder(report).to_json(path)


def ascii_gantt(result: SimResult, width: int = 100,
                max_rows: int = 24) -> str:
    """Terminal Gantt chart: one row per resource, '#' = busy."""
    records = result.records        # materializes lazy records once
    if not records or result.makespan <= 0:
        return "(empty)"
    width = max(int(width), 1)
    # single pass: group records by resource (the per-resource scan was
    # O(records x resources) on big traces)
    by_res: Dict[str, List] = {}
    for rec in records:
        by_res.setdefault(rec.task.resource, []).append(rec)
    resources = sorted(by_res)[:max_rows]
    scale = width / result.makespan
    glyph = {"compute": "#", "dma": "=", "collective": "~",
             "launch": ".", "host": "."}
    # pad, clamped so narrow widths (< 12) degrade instead of raising
    lines = [f"t=0 {'':{max(width - 12, 0)}} "
             f"t={result.makespan * 1e3:.3f} ms"]
    for res in resources:
        row = [" "] * width
        for rec in by_res[res]:
            a = min(width - 1, int(rec.start * scale))
            b = min(width, max(a + 1, int(rec.end * scale)))
            ch = glyph.get(rec.task.kind, "#")
            for i in range(a, b):
                row[i] = ch
        util = result.utilization(res)
        lines.append(f"{res:>12s} |{''.join(row)}| {util * 100:5.1f}%")
    lines.append(f"{'':>12s}  #=compute  ==dma  ~=collective")
    return "\n".join(lines)
