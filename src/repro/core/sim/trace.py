"""Gantt-chart export: Chrome trace-event JSON (loadable in Perfetto UI /
chrome://tracing) + an ASCII Gantt for terminals — the paper's Figure 4.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.sim.engine import SimResult


def chrome_trace(result: SimResult, path: Optional[str] = None) -> str:
    """Emit Chrome trace-event JSON; one 'thread' per resource."""
    resources = sorted({r.task.resource for r in result.records})
    tid_of = {res: i for i, res in enumerate(resources)}
    events: List[Dict] = []
    for i, res in enumerate(resources):
        events.append({"ph": "M", "pid": 0, "tid": i,
                       "name": "thread_name", "args": {"name": res}})
    for rec in result.records:
        events.append({
            "ph": "X", "pid": 0, "tid": tid_of[rec.task.resource],
            "name": rec.task.name,
            "cat": rec.task.kind,
            "ts": rec.start * 1e6,            # microseconds
            "dur": max(rec.end - rec.start, 1e-9) * 1e6,
            "args": {"layer": rec.task.layer, "bytes": rec.task.nbytes,
                     "flops": rec.task.flops},
        })
    text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


def ascii_gantt(result: SimResult, width: int = 100,
                max_rows: int = 24) -> str:
    """Terminal Gantt chart: one row per resource, '#' = busy."""
    if not result.records or result.makespan <= 0:
        return "(empty)"
    resources = sorted({r.task.resource for r in result.records})[:max_rows]
    scale = width / result.makespan
    lines = [f"t=0 {'':{width - 12}} t={result.makespan * 1e3:.3f} ms"]
    for res in resources:
        row = [" "] * width
        for rec in result.records:
            if rec.task.resource != res:
                continue
            a = min(width - 1, int(rec.start * scale))
            b = min(width, max(a + 1, int(rec.end * scale)))
            ch = {"compute": "#", "dma": "=", "collective": "~",
                  "launch": ".", "host": "."}.get(rec.task.kind, "#")
            for i in range(a, b):
                row[i] = ch
        util = result.utilization(res)
        lines.append(f"{res:>12s} |{''.join(row)}| {util * 100:5.1f}%")
    lines.append(f"{'':>12s}  #=compute  ==dma  ~=collective")
    return "\n".join(lines)
