"""Re-annotation rules for the what-if fast path.

Every task the AVSM compiler emits carries a :class:`RateAnno` describing
how its full-rate duration derives from the system description:

    duration = work / rate_table[rate_key] + fixed_table[fixed_key]

``work`` is fixed by the tiling (FLOPs adjusted for array-alignment
efficiency, or bytes moved), so re-annotating physical parameters
(frequencies, bandwidths, latencies) only requires rebuilding the two
lookup tables and rescaling durations — no re-tiling, no graph rebuild.
This is the paper's "click-of-a-button" exploration: O(n_tasks) per
sweep point instead of a full recompile.
"""
from __future__ import annotations

from dataclasses import dataclass

# rate keys: matrix | vector | mem | ici | dcn
# fixed keys: launch | mem_lat | ici_lat | dcn_lat | none


@dataclass(frozen=True)
class RateAnno:
    rate_key: str
    work: float          # FLOPs/eff for compute, bytes for transfers
    fixed_key: str = "none"
