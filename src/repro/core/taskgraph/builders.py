"""Builders: (ModelConfig x ShapeConfig x mesh) -> per-device LayerOp graph.

This is the AVSM "deep learning compiler" front end: it applies the sharding
plan (mirroring ``repro.sharding``'s divisibility rules) to derive the
per-device shard of every operation, and inserts the collectives the plan
implies (Megatron-style TP all-reduces, MoE all-to-alls, FSDP weight
all-gathers, gradient reduce-scatters).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.config import ModelConfig, ShapeConfig
from repro.core.taskgraph.ops import (LayerOp, attention_op, collective_op,
                                      elementwise_op, matmul_op, scan_op)


@dataclass(frozen=True)
class ShardPlan:
    """How the builders shard the program onto the mesh."""

    data: int = 16               # batch-parallel ways (pod*data axes)
    model: int = 16              # tensor/expert/sequence-parallel ways
    pods: int = 1
    fsdp: bool = True            # params+optimizer sharded over data axis
    seq_parallel: bool = False   # shard sequence on model axis (long ctx)
    overlap_grad_comm: bool = True
    bytes_per_el: int = 2        # bf16
    grad_compression: int = 1    # divisor on grad collective payload (int8=2)
    remat: str = "dots"          # none | dots | full — backward recompute

    @property
    def dp_total(self) -> int:
        return self.data * self.pods


def _div(x: int, ways: int) -> int:
    """Shard size with divisibility fallback (replicate if not divisible)."""
    return x // ways if ways > 1 and x % ways == 0 else x


def _ceil_div(x: int, ways: int) -> int:
    """Padded shard size (GSPMD pads uneven shards, e.g. 40 heads / 16)."""
    return -(-x // ways) if ways > 1 else x


def _tp(x: int, plan: ShardPlan) -> int:
    return _div(x, plan.model)


class OpList:
    def __init__(self):
        self.ops: List[LayerOp] = []

    def add(self, op: LayerOp):
        self.ops.append(op)

    def extend(self, ops: List[LayerOp]):
        self.ops.extend(ops)


# ---------------------------------------------------------------------------
# Per-layer forward ops
# ---------------------------------------------------------------------------


def _attn_layer_ops(cfg: ModelConfig, lay: str, b_l: int, s_q: int, s_kv: int,
                    plan: ShardPlan, mode: str) -> List[LayerOp]:
    a = cfg.attention
    d = cfg.d_model
    bpe = plan.bytes_per_el
    t = b_l * s_q                      # tokens on this device
    ops: List[LayerOp] = []
    heads_l = _ceil_div(a.num_heads, plan.model)
    kvh_l = _ceil_div(a.num_kv_heads, plan.model)

    ops.append(elementwise_op(f"{lay}/ln1", lay, t * d * bpe, t * d * bpe,
                              flops_per_el=6))
    if a.kind == "mla":
        qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim
        if a.q_lora_rank:
            ops.append(matmul_op(f"{lay}/wq_a", lay, t, d, a.q_lora_rank, bpe))
            ops.append(matmul_op(f"{lay}/wq_b", lay, t, a.q_lora_rank,
                                 heads_l * qk_dim, bpe))
        else:
            ops.append(matmul_op(f"{lay}/wq", lay, t, d, heads_l * qk_dim, bpe))
        ops.append(matmul_op(f"{lay}/wkv_a", lay, t, d,
                             a.kv_lora_rank + a.qk_rope_head_dim, bpe))
        ops.append(matmul_op(f"{lay}/wkv_b", lay, t, a.kv_lora_rank,
                             heads_l * (a.qk_nope_head_dim + a.v_head_dim),
                             bpe))
        ops.append(attention_op(f"{lay}/attn", lay, heads_l, s_q, s_kv,
                                qk_dim, a.v_head_dim,
                                causal=(mode != "decode"), batch=b_l,
                                bytes_per_el=bpe))
        ops.append(matmul_op(f"{lay}/wo", lay, t, heads_l * a.v_head_dim,
                             d, bpe))
    else:
        hd = a.head_dim
        ops.append(matmul_op(f"{lay}/wq", lay, t, d, heads_l * hd, bpe))
        ops.append(matmul_op(f"{lay}/wk", lay, t, d, kvh_l * hd, bpe))
        ops.append(matmul_op(f"{lay}/wv", lay, t, d, kvh_l * hd, bpe))
        ops.append(attention_op(f"{lay}/attn", lay, heads_l, s_q, s_kv,
                                hd, hd, causal=(mode != "decode"), batch=b_l,
                                bytes_per_el=bpe))
        ops.append(matmul_op(f"{lay}/wo", lay, t, heads_l * hd, d, bpe))
    # Megatron-TP g: partial sums of the output projection
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/attn_ar", lay, "all_reduce",
                                 t * d * bpe, "model", plan.model))
    return ops


def _ffn_layer_ops(cfg: ModelConfig, lay: str, t: int, plan: ShardPlan,
                   d_ff: int) -> List[LayerOp]:
    d = cfg.d_model
    bpe = plan.bytes_per_el
    f_l = _tp(d_ff, plan)
    n_mats = 3 if cfg.act == "swiglu" else 2
    ops = [elementwise_op(f"{lay}/ln2", lay, t * d * bpe, t * d * bpe, 6)]
    ops.append(matmul_op(f"{lay}/ffn_up", lay, t, d, f_l * (n_mats - 1), bpe))
    ops.append(elementwise_op(f"{lay}/ffn_act", lay, t * f_l * bpe,
                              t * f_l * bpe, 4))
    ops.append(matmul_op(f"{lay}/ffn_down", lay, t, f_l, d, bpe))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/ffn_ar", lay, "all_reduce",
                                 t * d * bpe, "model", plan.model))
    return ops


def _moe_layer_ops(cfg: ModelConfig, lay: str, t: int, plan: ShardPlan,
                   ) -> List[LayerOp]:
    m = cfg.moe
    d = cfg.d_model
    bpe = plan.bytes_per_el
    e_l = max(1, _div(m.num_experts, plan.model))          # experts/device
    k = m.num_experts_per_tok
    ep_ways = m.num_experts // e_l                          # EP sharding ways
    # token*choice volume this device's experts receive (balanced routing)
    t_routed = max(1, t * k // ep_ways)
    f = m.d_ff_expert
    n_mats = 3 if cfg.act == "swiglu" else 2
    ops = [elementwise_op(f"{lay}/ln2", lay, t * d * bpe, t * d * bpe, 6)]
    ops.append(matmul_op(f"{lay}/router", lay, t, d, m.num_experts, 4))
    ops.append(elementwise_op(f"{lay}/route_topk", lay,
                              t * m.num_experts * 4, t * k * 4, 8,
                              bytes_per_el=4))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/moe_dispatch", lay, "all_to_all",
                                 t_routed * d * bpe, "model", plan.model))
    # expert matmuls: this device holds e_l experts, receives ~t_routed toks
    ops.append(matmul_op(f"{lay}/experts_up", lay, t_routed, d,
                         f * (n_mats - 1), bpe,
                         flops_scale=1.0))
    ops.append(elementwise_op(f"{lay}/experts_act", lay, t_routed * f * bpe,
                              t_routed * f * bpe, 4))
    ops.append(matmul_op(f"{lay}/experts_down", lay, t_routed, f, d, bpe))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/moe_combine", lay, "all_to_all",
                                 t_routed * d * bpe, "model", plan.model))
    if m.num_shared_experts:
        f_sh = _tp(m.d_ff_shared or f * m.num_shared_experts, plan)
        ops.append(matmul_op(f"{lay}/shared_up", lay, t, d,
                             f_sh * (n_mats - 1), bpe))
        ops.append(matmul_op(f"{lay}/shared_down", lay, t, f_sh, d, bpe))
    return ops


def _ssm_layer_ops(cfg: ModelConfig, lay: str, b_l: int, s: int,
                   plan: ShardPlan, mode: str) -> List[LayerOp]:
    ss = cfg.ssm
    d = cfg.d_model
    bpe = plan.bytes_per_el
    di = ss.expand * d
    di_l = _tp(di, plan)
    ds = ss.d_state
    dtr = ss.resolved_dt_rank(d)
    t = b_l * s
    ops = [elementwise_op(f"{lay}/ln1", lay, t * d * bpe, t * d * bpe, 6)]
    ops.append(matmul_op(f"{lay}/in_proj", lay, t, d, 2 * di_l, bpe))
    ops.append(elementwise_op(f"{lay}/conv1d", lay, t * di_l * bpe,
                              t * di_l * bpe, 2 * ss.d_conv))
    ops.append(matmul_op(f"{lay}/x_proj", lay, t, di_l, dtr + 2 * ds, bpe))
    ops.append(matmul_op(f"{lay}/dt_proj", lay, t, dtr, di_l, bpe))
    # selective scan: 9 flops/state-el (discretise, recur, project)
    chunks = max(1, s // 256) if mode != "decode" else 1
    ops.append(scan_op(f"{lay}/sel_scan", lay,
                       flops=9.0 * t * di_l * ds,
                       in_bytes=t * di_l * bpe + 2 * t * ds * bpe,
                       out_bytes=t * di_l * bpe, seq_chunks=chunks))
    ops.append(matmul_op(f"{lay}/out_proj", lay, t, di_l, d, bpe))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/ssm_ar", lay, "all_reduce",
                                 t * d * bpe, "model", plan.model))
    return ops


def _rwkv_layer_ops(cfg: ModelConfig, lay: str, b_l: int, s: int,
                    plan: ShardPlan, mode: str) -> List[LayerOp]:
    r = cfg.rwkv
    d = cfg.d_model
    bpe = plan.bytes_per_el
    d_l = _tp(d, plan)
    t = b_l * s
    hd = r.head_dim
    h_l = max(1, d_l // hd)
    ops = [elementwise_op(f"{lay}/ln1", lay, t * d * bpe, t * d * bpe, 6)]
    ops.append(matmul_op(f"{lay}/ddlerp", lay, t, d, 5 * r.mix_lora, bpe))
    for nm in ("wr", "wk", "wv", "wg"):
        ops.append(matmul_op(f"{lay}/{nm}", lay, t, d, d_l, bpe))
    ops.append(matmul_op(f"{lay}/w_lora", lay, t, d, r.decay_lora, bpe))
    # chunked WKV: ~2*(c + 2*hd) flops per (token, channel); c=32
    chunk = 32
    chunks = max(1, s // chunk) if mode != "decode" else 1
    ops.append(scan_op(f"{lay}/wkv", lay,
                       flops=2.0 * t * h_l * hd * (chunk + 2 * hd),
                       in_bytes=4 * t * d_l * bpe,
                       out_bytes=t * d_l * bpe, seq_chunks=chunks,
                       matrix=True))
    ops.append(matmul_op(f"{lay}/wo", lay, t, d_l, d, bpe))
    # channel mix
    f_l = _tp(cfg.d_ff, plan)
    ops.append(matmul_op(f"{lay}/cm_k", lay, t, d, f_l, bpe))
    ops.append(matmul_op(f"{lay}/cm_v", lay, t, f_l, d, bpe))
    ops.append(matmul_op(f"{lay}/cm_r", lay, t, d, d_l, bpe))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/rwkv_ar", lay, "all_reduce",
                                 t * d * bpe, "model", plan.model))
    return ops


# ---------------------------------------------------------------------------
# Whole-step builders
# ---------------------------------------------------------------------------


def _decode_cache_ops(cfg: ModelConfig, lay: str, kind: str, b_l: int,
                      s_ctx: int, plan: ShardPlan) -> List[LayerOp]:
    """Decode reads the whole per-device KV/state cache once per step."""
    a = cfg.attention
    bpe = plan.bytes_per_el
    ops: List[LayerOp] = []
    if kind == "attn":
        if a is not None and a.kind == "mla":
            per_tok = a.kv_lora_rank + a.qk_rope_head_dim
            heads_l = _tp(a.num_heads, plan)
            cache_b = b_l * _div(s_ctx, plan.model if plan.seq_parallel else 1) \
                * per_tok * bpe
            flops = 2.0 * b_l * heads_l * s_ctx * (a.kv_lora_rank +
                                                   a.qk_rope_head_dim +
                                                   a.kv_lora_rank)
        else:
            kvh_l = _ceil_div(a.num_kv_heads, plan.model)
            s_l = _div(s_ctx, plan.model) if plan.seq_parallel else s_ctx
            cache_b = 2 * b_l * kvh_l * s_l * a.head_dim * bpe
            heads_l = _ceil_div(a.num_heads, plan.model)
            flops = 4.0 * b_l * heads_l * s_l * a.head_dim
        ops.append(LayerOp(name=f"{lay}/kv_read", layer=lay, kind="attention",
                           flops=flops, in_bytes=int(cache_b),
                           out_bytes=b_l * cfg.d_model * bpe,
                           dims=(1, a.head_dim if a else 64, s_ctx),
                           matrix=True))
        if plan.seq_parallel and plan.model > 1:
            # combine partial softmax stats across sequence shards
            ops.append(collective_op(f"{lay}/softmax_comb", lay, "all_reduce",
                                     b_l * cfg.d_model * bpe, "model",
                                     plan.model))
    return ops


def lm_step_ops(cfg: ModelConfig, shape: ShapeConfig, plan: ShardPlan,
                ) -> List[LayerOp]:
    """Per-device LayerOp graph for one step of the given shape cell."""
    bpe = plan.bytes_per_el
    mode = shape.mode
    B, S = shape.global_batch, shape.seq_len
    b_l = max(1, _div(B, plan.dp_total))
    if mode == "decode":
        s_q = 1
        s_kv = S
    else:
        s_q = _div(S, plan.model) if plan.seq_parallel else S
        s_kv = S
    t = b_l * s_q

    d = cfg.d_model
    out = OpList()
    v_l = _ceil_div(cfg.vocab_size, plan.model)   # GSPMD pads uneven vocab

    # --- embedding ---
    out.add(LayerOp(name="embed/gather", layer="embed", kind="embed",
                    flops=0, in_bytes=t * bpe * 2, out_bytes=t * d * bpe,
                    matrix=False))

    # --- blocks ---
    mixers = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    enc_layers = cfg.encoder_layers if cfg.family in ("encdec", "audio") else 0
    if enc_layers:
        # enc-dec shape convention: S/2 encoder frames + S/2 decoder tokens.
        # At decode the encoder output is cached: only the decoder runs.
        if mode != "decode":
            s_enc = max(1, s_q // 2)
            t_enc = b_l * s_enc
            for i in range(enc_layers):
                lay = f"enc{i}"
                out.extend(_attn_layer_ops(cfg, lay, b_l, s_enc, s_enc,
                                           plan, "train"))
                out.extend(_ffn_layer_ops(cfg, lay, t_enc, plan, cfg.d_ff))
            s_q_dec = max(1, s_q // 2)
            s_kv_dec = s_q_dec
        else:
            s_q_dec, s_kv_dec = 1, max(1, S // 2)
        t = b_l * s_q_dec
    else:
        s_q_dec, s_kv_dec = s_q, s_kv

    dense_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                else cfg.d_ff)
    for i, (mx, ff) in enumerate(zip(mixers, ffns)):
        lay = f"layer{i}"
        if mode == "decode":
            if mx == "attn":
                out.extend(_attn_proj_decode_ops(cfg, lay, b_l, plan))
                out.extend(_decode_cache_ops(cfg, lay, "attn", b_l,
                                             s_kv_dec, plan))
            elif mx == "ssm":
                out.extend(_ssm_layer_ops(cfg, lay, b_l, 1, plan, mode))
            elif mx == "rwkv":
                out.extend(_rwkv_layer_ops(cfg, lay, b_l, 1, plan, mode))
            if ff == "moe":
                out.extend(_moe_layer_ops(cfg, lay, b_l, plan))
            elif ff == "dense":
                out.extend(_ffn_layer_ops(cfg, lay, b_l, plan, dense_ff))
            # rwkv channel mix is included in _rwkv_layer_ops
        else:
            if mx == "attn":
                out.extend(_attn_layer_ops(cfg, lay, b_l, s_q_dec, s_kv_dec,
                                           plan, mode))
            elif mx == "ssm":
                out.extend(_ssm_layer_ops(cfg, lay, b_l, s_q_dec, plan, mode))
            elif mx == "rwkv":
                out.extend(_rwkv_layer_ops(cfg, lay, b_l, s_q_dec, plan, mode))
            if ff == "moe":
                out.extend(_moe_layer_ops(cfg, lay, t, plan))
            elif ff == "dense":
                out.extend(_ffn_layer_ops(cfg, lay, t, plan, dense_ff))

    # --- head ---
    t_head = t if mode != "decode" else b_l
    out.add(matmul_op("head/logits", "head", t_head, d, v_l, bpe))
    if plan.model > 1:
        # vocab-sharded logits: softmax/xent needs a cross-shard reduction
        out.add(collective_op("head/logits_ar", "head", "all_reduce",
                              t_head * 8, "model", plan.model))
    if mode == "train":
        out.add(elementwise_op("head/softmax_xent", "head",
                               t_head * cfg.vocab_size * 2,
                               t_head * 4, 6))
        out.extend(_backward_ops(out.ops, cfg, plan))
        out.extend(_optimizer_ops(cfg, plan))
    return out.ops


def _attn_proj_decode_ops(cfg: ModelConfig, lay: str, b_l: int,
                          plan: ShardPlan) -> List[LayerOp]:
    a = cfg.attention
    bpe = plan.bytes_per_el
    d = cfg.d_model
    heads_l = _ceil_div(a.num_heads, plan.model)
    ops = []
    if a.kind == "mla":
        qk = a.qk_nope_head_dim + a.qk_rope_head_dim
        ops.append(matmul_op(f"{lay}/q_proj", lay, b_l,
                             a.q_lora_rank or d, heads_l * qk, bpe))
        ops.append(matmul_op(f"{lay}/kv_a", lay, b_l, d,
                             a.kv_lora_rank + a.qk_rope_head_dim, bpe))
        ops.append(matmul_op(f"{lay}/wo", lay, b_l,
                             heads_l * a.v_head_dim, d, bpe))
    else:
        hd = a.head_dim
        kvh_l = _ceil_div(a.num_kv_heads, plan.model)
        ops.append(matmul_op(f"{lay}/wq", lay, b_l, d, heads_l * hd, bpe))
        ops.append(matmul_op(f"{lay}/wk", lay, b_l, d, kvh_l * hd, bpe))
        ops.append(matmul_op(f"{lay}/wv", lay, b_l, d, kvh_l * hd, bpe))
        ops.append(matmul_op(f"{lay}/wo", lay, b_l, heads_l * hd, d, bpe))
    if plan.model > 1:
        ops.append(collective_op(f"{lay}/attn_ar", lay, "all_reduce",
                                 b_l * d * bpe, "model", plan.model))
    return ops


def _backward_ops(fwd_ops: List[LayerOp], cfg: ModelConfig,
                  plan: ShardPlan) -> List[LayerOp]:
    """Backward pass: 2x forward matmul FLOPs (dgrad+wgrad), recompute per
    remat policy, and per-layer gradient reduce-scatter over the data axis."""
    bwd: List[LayerOp] = []
    recompute = {"none": 0.0, "dots": 0.35, "full": 1.0}[plan.remat]
    layer_weight_bytes: Dict[str, int] = {}
    for op in reversed(fwd_ops):
        if op.kind == "collective":
            bwd.append(collective_op(op.name + "_bwd", op.layer + "_bwd",
                                     op.coll.kind, op.coll.payload,
                                     op.coll.axis, op.coll.axis_size))
            continue
        scale = 2.0 + recompute if op.kind in ("matmul", "attention", "conv") \
            else 1.0 + recompute
        bwd.append(LayerOp(
            name=op.name + "_bwd", layer=op.layer + "_bwd", kind=op.kind,
            flops=op.flops * scale,
            weight_bytes=op.weight_bytes * 2,       # read W for dgrad, write dW
            in_bytes=op.in_bytes + op.out_bytes,
            out_bytes=op.in_bytes,
            dims=op.dims, matrix=op.matrix, seq_chunks=op.seq_chunks))
        layer_weight_bytes[op.layer] = (layer_weight_bytes.get(op.layer, 0)
                                        + op.weight_bytes)
    # gradient reduction over the data axis (per layer, overlappable)
    if plan.dp_total > 1:
        for lay, wb in layer_weight_bytes.items():
            if wb == 0:
                continue
            payload = wb // plan.grad_compression
            kind = "reduce_scatter" if plan.fsdp else "all_reduce"
            bwd.append(collective_op(f"{lay}/grad_rs", f"{lay}_bwd", kind,
                                     payload, "data", plan.dp_total))
    return bwd


def _optimizer_ops(cfg: ModelConfig, plan: ShardPlan) -> List[LayerOp]:
    """AdamW update: read param+m+v+grad, write param+m+v (f32 states)."""
    from repro.models import api
    n = api.param_count(cfg)
    shard = plan.dp_total * plan.model if plan.fsdp else plan.model
    n_l = n // max(1, shard)
    nbytes = n_l * (2 + 4 + 4 + 2)      # bf16 param, f32 m, f32 v, bf16 grad
    return [LayerOp(name="opt/adamw", layer="optimizer", kind="optimizer",
                    flops=12.0 * n_l, in_bytes=nbytes,
                    out_bytes=n_l * (2 + 4 + 4), matrix=False)]


# ---------------------------------------------------------------------------
# ConvNet (DilatedVGG) builder — single-chip AVSM (the paper's Fig 2 system)
# ---------------------------------------------------------------------------


def convnet_ops(cfg: ModelConfig, batch: int = 1,
                bytes_per_el: int = 2) -> List[LayerOp]:
    net = cfg.convnet
    h, w = net.in_hw
    ops: List[LayerOp] = []
    for lay in net.layers:
        if lay.kind in ("conv", "dense"):
            flops = 2.0 * batch * h * w * lay.in_ch * lay.out_ch \
                * lay.kernel * lay.kernel
            ops.append(LayerOp(
                name=lay.name, layer=lay.name, kind="conv", flops=flops,
                weight_bytes=lay.kernel ** 2 * lay.in_ch * lay.out_ch
                * bytes_per_el,
                in_bytes=batch * h * w * lay.in_ch * bytes_per_el,
                out_bytes=batch * (h // lay.stride) * (w // lay.stride)
                * lay.out_ch * bytes_per_el,
                dims=(batch * h * w, lay.in_ch * lay.kernel ** 2, lay.out_ch),
                matrix=True))
            h, w = h // lay.stride, w // lay.stride
        elif lay.kind == "pool":
            ops.append(elementwise_op(
                lay.name, lay.name,
                batch * h * w * lay.in_ch * bytes_per_el,
                batch * (h // lay.stride) * (w // lay.stride) * lay.in_ch
                * bytes_per_el, 1, bytes_per_el))
            h, w = h // lay.stride, w // lay.stride
        elif lay.kind == "upsample":
            ops.append(elementwise_op(
                lay.name, lay.name,
                batch * h * w * lay.in_ch * bytes_per_el,
                batch * h * lay.stride * w * lay.stride * lay.out_ch
                * bytes_per_el, 4, bytes_per_el))
            h, w = h * lay.stride, w * lay.stride
    return ops
