"""AVSM compiler back end: LayerOps -> hardware-adapted task graph.

Mirrors the paper's flow: the compiler "considers the memory hierarchy, the
on-chip memory sizes and the supported operations" of the target — every op
is tiled so a tile's working set fits the on-chip memory (VMEM/BRAM) with
double buffering, and each tile becomes DMA-in -> compute -> DMA-out tasks
on the virtual hardware models.  Collectives become per-hop link tasks
(ring algorithms), so the DES sees link contention and overlap causally.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

from repro.core.hw import SystemDescription
from repro.core.sim.engine import Task
from repro.core.taskgraph.ops import LayerOp


@dataclass(frozen=True)
class CompilePlan:
    """Back-end knobs (hillclimb surface of the AVSM)."""

    dtype: str = "bfloat16"
    vmem_fill: float = 0.45          # fraction of VMEM per tile buffer
    double_buffer: int = 2           # DMA prefetch depth (tiles)
    max_tiles_per_op: int = 16       # aggregate beyond this (sim granularity)
    bidirectional_ici: bool = True   # ring uses both directions
    overlap_grad_comm: bool = True   # grad collectives off the critical path
    weights_resident: bool = False   # pin weights on-chip (paper's NCE mode)


@dataclass
class CompiledGraph:
    tasks: List[Task]
    ops: List[LayerOp]
    system: SystemDescription
    plan: CompilePlan

    @property
    def total_flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def total_hbm_bytes(self) -> float:
        return sum(o.total_bytes for o in self.ops if o.kind != "collective")

    @property
    def total_collective_bytes(self) -> float:
        return sum(o.coll.payload for o in self.ops if o.coll is not None)


def _mxu_efficiency(op: LayerOp, align: int) -> float:
    """Pad-to-align efficiency for matrix ops (paper: 'arrangement of the
    multiplier array')."""
    if not op.dims:
        return 1.0
    eff = 1.0
    for dim in op.dims:
        if dim <= 0:
            continue
        padded = math.ceil(dim / align) * align
        eff *= dim / padded
    return max(eff, 0.05)


def compile_ops(ops: List[LayerOp], system: SystemDescription,
                plan: Optional[CompilePlan] = None) -> CompiledGraph:
    plan = plan or CompilePlan()
    chip = system.chip
    eng = chip.compute
    mem = chip.memory
    vmem_budget = max(1, int(chip.onchip.capacity * plan.vmem_fill))

    tasks: List[Task] = []
    tid = 0

    def new_task(**kw) -> Task:
        nonlocal tid
        t = Task(tid=tid, **kw)
        tasks.append(t)
        tid += 1
        return t

    # tail compute task of the previous op (data dependency chain) and the
    # last grad-producing compute per layer (for overlap-aware collectives)
    prev_tail: Optional[Task] = None
    barrier_tail: Optional[Task] = None   # for non-overlapped collectives

    for op in ops:
        if op.kind == "collective":
            c = op.coll
            n = c.axis_size
            if n <= 1 or c.payload <= 0:
                continue
            link_bw = chip.link.bandwidth * (2 if plan.bidirectional_ici
                                             else 1)
            if c.axis == "pod":
                link_bw = system.dcn_bandwidth
            if c.kind == "all_reduce":
                steps, step_bytes = 2 * (n - 1), c.payload / n
            elif c.kind in ("all_gather", "reduce_scatter"):
                steps, step_bytes = n - 1, c.payload / n
            elif c.kind == "all_to_all":
                steps, step_bytes = n - 1, c.payload / n
            else:  # permute
                steps, step_bytes = 1, c.payload
            dep = prev_tail if plan.overlap_grad_comm or \
                not op.name.endswith("grad_rs") else barrier_tail
            prev = dep
            for s in range(steps):
                t = new_task(
                    name=f"{op.name}/hop{s}", layer=op.layer,
                    resource=f"ici_{c.axis}",
                    duration=step_bytes / link_bw + chip.link.latency,
                    deps=(prev.tid,) if prev is not None else (),
                    kind="collective", nbytes=int(step_bytes))
                prev = t
            # collectives producing activations gate the next op
            if not op.name.endswith(("grad_rs", "grad_rs_bwd")):
                prev_tail = prev
            continue

        # ---- tiled compute op ----
        eff = _mxu_efficiency(op, eng.align) if op.matrix else 1.0
        flops_rate = eng.flops_for(plan.dtype, matrix=op.matrix)
        working = max(op.total_bytes, 1)
        n_tiles = max(1, math.ceil(working / vmem_budget))
        n_tiles = max(n_tiles, op.seq_chunks)
        agg = 1
        if n_tiles > plan.max_tiles_per_op and op.seq_chunks <= 1:
            agg = math.ceil(n_tiles / plan.max_tiles_per_op)
            n_tiles = math.ceil(n_tiles / agg)

        w_share = (0 if plan.weights_resident
                   else op.weight_bytes / n_tiles)
        in_share = op.in_bytes / n_tiles
        out_share = op.out_bytes / n_tiles
        comp_dur = (op.flops / n_tiles) / (flops_rate * eff) \
            + eng.launch_overhead

        producer_tail = prev_tail
        compute_tasks: List[Task] = []
        for i in range(n_tiles):
            deps_w: List[int] = []
            # double-buffer constraint: DMA i waits for compute i - depth
            if i >= plan.double_buffer and compute_tasks:
                deps_w.append(compute_tasks[i - plan.double_buffer].tid)
            dma_deps = list(deps_w)
            if producer_tail is not None:
                dma_deps.append(producer_tail.tid)
            dma_res = f"dma{i % mem.num_dma_engines}"
            t_in = None
            if w_share + in_share > 0:
                t_in = new_task(
                    name=f"{op.name}/t{i}/dma_in", layer=op.layer,
                    resource=dma_res,
                    duration=(w_share + in_share) / mem.bandwidth
                    + mem.latency,
                    deps=tuple(dma_deps), kind="dma",
                    nbytes=int(w_share + in_share))
            comp_deps = [t_in.tid] if t_in is not None else list(dma_deps)
            if op.seq_chunks > 1 and compute_tasks:
                comp_deps.append(compute_tasks[-1].tid)   # recurrence chain
            t_c = new_task(
                name=f"{op.name}/t{i}/compute", layer=op.layer,
                resource="nce" if op.matrix else "vpu",
                duration=comp_dur, deps=tuple(comp_deps),
                kind="compute", flops=int(op.flops / n_tiles),
                nbytes=int(w_share + in_share + out_share))
            compute_tasks.append(t_c)
            if out_share > 0:
                new_task(
                    name=f"{op.name}/t{i}/dma_out", layer=op.layer,
                    resource=dma_res,
                    duration=out_share / mem.bandwidth + mem.latency,
                    deps=(t_c.tid,), kind="dma", nbytes=int(out_share))
        prev_tail = compute_tasks[-1]
        barrier_tail = compute_tasks[-1]

    return CompiledGraph(tasks=tasks, ops=list(ops), system=system, plan=plan)
