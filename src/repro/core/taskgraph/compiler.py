"""AVSM compiler back end: LayerOps -> hardware-adapted task graph.

Mirrors the paper's flow: the compiler "considers the memory hierarchy, the
on-chip memory sizes and the supported operations" of the target — every op
is tiled so a tile's working set fits the on-chip memory (VMEM/BRAM) with
double buffering, and each tile becomes DMA-in -> compute -> DMA-out tasks
on the virtual hardware models.  Collectives become per-hop link tasks
(ring algorithms), so the DES sees link contention and overlap causally.

Two artifacts make the what-if loop cheap:

  * every task carries a :class:`~repro.core.taskgraph.anno.RateAnno`, so
    :func:`reannotate` rescales durations from new physical annotations
    (frequencies, bandwidths, latencies) in O(n_tasks) without re-tiling;
  * the graph carries :class:`~repro.core.sim.engine.ResourceSpec`s derived
    from the topology (``num_dma_engines`` DMA servers, ``num_links``-wide
    bandwidth-shared ICI channels), so resource-count what-ifs are also
    re-annotation, not recompilation.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.hw import SystemDescription
from repro.core.sim.engine import ResourceSpec, Task
from repro.core.taskgraph.anno import RateAnno
from repro.core.taskgraph.ops import LayerOp


@dataclass(frozen=True)
class CompilePlan:
    """Back-end knobs (hillclimb surface of the AVSM)."""

    dtype: str = "bfloat16"
    vmem_fill: float = 0.45          # fraction of VMEM per tile buffer
    double_buffer: int = 2           # DMA prefetch depth (tiles)
    max_tiles_per_op: int = 16       # aggregate beyond this (sim granularity)
    bidirectional_ici: bool = True   # ring uses both directions
    overlap_grad_comm: bool = True   # grad collectives off the critical path
    weights_resident: bool = False   # pin weights on-chip (paper's NCE mode)


# Process-unique suffixes for CompiledGraph.pool_key().
_POOL_KEYS = itertools.count()

# Index order for the vectorized re-annotation arrays.
RATE_KEYS = ("matrix", "vector", "mem", "ici", "dcn")
FIXED_KEYS = ("launch", "mem_lat", "ici_lat", "dcn_lat", "none")


@dataclass
class CompiledGraph:
    tasks: List[Task]
    ops: List[LayerOp]
    system: SystemDescription
    plan: CompilePlan
    resources: Dict[str, ResourceSpec] = field(default_factory=dict)
    # (work, rate_idx, fixed_idx, durations) parallel to ``tasks`` — built
    # lazily, shared across re-annotated copies (task order is identical).
    _anno_arrays: Optional[Tuple[np.ndarray, ...]] = field(
        default=None, repr=False, compare=False)
    # Mutable state shared *by reference* across every re-annotated copy
    # (they all alias the same task list): holds lazily built structural
    # caches — the DES engine's StaticCache, estimator per-op arrays —
    # so whichever what-if variant builds one first, all variants reuse it.
    _shared: Dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def durations(self) -> np.ndarray:
        """Authoritative per-task durations, aligned with ``tasks``.

        Estimator backends read this (not ``Task.duration``): a
        re-annotated graph shares its Task objects with the source graph
        and carries only a fresh duration array.
        """
        return self.anno_arrays()[3]

    def pool_key(self) -> str:
        """Process-unique sticky token for persistent-pool broadcasts
        (``repro.core.parallel.ensure_shared``): every re-annotated
        variant of one structure shares the token (``_shared`` is aliased),
        so the heavy task list crosses the process boundary once per pool
        and sweep items ship only duration vectors."""
        key = self._shared.get("pool_key")
        if key is None:
            key = self._shared["pool_key"] = f"graph:{next(_POOL_KEYS)}"
        return key

    def __getstate__(self):
        # Persistent-pool jobs ship compiled graphs across process
        # boundaries; ``_shared`` holds lazily rebuilt structural caches
        # (dependency CSR, per-op arrays), so don't pay to pickle them —
        # a worker rebuilds on first use and reuses them for the rest of
        # its map (the unpickled graph is shared across its items).
        state = self.__dict__.copy()
        state["_shared"] = {}
        return state

    def sim_cache(self):
        """Dependency-CSR cache for the DES fast path
        (:func:`repro.core.sim.engine.simulate_static`) — built once per
        task-graph structure and shared across re-annotated variants."""
        cache = self._shared.get("sim_cache")
        if cache is None:
            from repro.core.sim.engine import StaticCache
            cache = self._shared["sim_cache"] = StaticCache(self.tasks)
        return cache

    def anno_arrays(self) -> Tuple[np.ndarray, ...]:
        if self._anno_arrays is None:
            n = len(self.tasks)
            work = np.empty(n)
            ridx = np.empty(n, dtype=np.int8)
            fidx = np.empty(n, dtype=np.int8)
            durs = np.empty(n)
            for i, t in enumerate(self.tasks):
                durs[i] = t.duration
                if t.anno is None:
                    work[i], ridx[i], fidx[i] = 0.0, -1, len(FIXED_KEYS) - 1
                else:
                    work[i] = t.anno.work
                    ridx[i] = RATE_KEYS.index(t.anno.rate_key)
                    fidx[i] = FIXED_KEYS.index(t.anno.fixed_key)
            self._anno_arrays = (work, ridx, fidx, durs)
        return self._anno_arrays

    @property
    def total_flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def total_hbm_bytes(self) -> float:
        return sum(o.total_bytes for o in self.ops if o.kind != "collective")

    @property
    def total_collective_bytes(self) -> float:
        return sum(o.coll.payload for o in self.ops if o.coll is not None)


def _mxu_efficiency(op: LayerOp, align: int) -> float:
    """Pad-to-align efficiency for matrix ops (paper: 'arrangement of the
    multiplier array')."""
    if not op.dims:
        return 1.0
    eff = 1.0
    for dim in op.dims:
        if dim <= 0:
            continue
        padded = math.ceil(dim / align) * align
        eff *= dim / padded
    return max(eff, 0.05)


def rate_table(system: SystemDescription,
               plan: CompilePlan) -> Dict[str, float]:
    """Full-rate service rates per RateAnno.rate_key for this system."""
    chip = system.chip
    return {
        "matrix": chip.compute.flops_for(plan.dtype, matrix=True),
        "vector": chip.compute.flops_for(plan.dtype, matrix=False),
        "mem": chip.memory.bandwidth,
        "ici": chip.link.bandwidth * (2 if plan.bidirectional_ici else 1),
        "dcn": system.dcn_bandwidth,
    }


def fixed_table(system: SystemDescription) -> Dict[str, float]:
    """Per-task fixed costs (launch overhead, transaction latencies)."""
    chip = system.chip
    return {
        "launch": chip.compute.launch_overhead,
        "mem_lat": chip.memory.latency,
        "ici_lat": chip.link.latency,
        "dcn_lat": system.dcn_latency,
        "none": 0.0,
    }


def structural_key(system: SystemDescription) -> Tuple:
    """Chip parameters that change the *tiling* of a compiled graph; systems
    that agree on this key differ only in physical annotations and can share
    a cached graph via :func:`reannotate` (used by ``repro.core.dse`` and
    ``repro.serve_sim.cost``)."""
    chip = system.chip
    return (chip.onchip.capacity, chip.compute.align)


def resource_specs(system: SystemDescription) -> Dict[str, ResourceSpec]:
    """Topology -> resource model.

    * compute engines are exclusive FIFO stations;
    * ``dma`` is a ``num_dma_engines``-server channel (concurrent streams);
    * each mesh axis gets a bandwidth-shared ICI channel whose width is the
      links available per torus dimension, so concurrent collectives split
      bandwidth instead of strictly serializing;
    * the inter-pod DCN is a single bandwidth-shared channel.
    """
    chip = system.chip
    n_axes = max(1, len(system.torus))
    links_per_axis = max(1, chip.num_links // n_axes)
    specs = {
        "nce": ResourceSpec("nce", servers=1, mode="fifo"),
        "vpu": ResourceSpec("vpu", servers=1, mode="fifo"),
        "dma": ResourceSpec("dma", servers=max(1, chip.memory.num_dma_engines),
                            mode="shared"),
        "ici_pod": ResourceSpec("ici_pod", servers=1, mode="shared"),
    }
    for axis in ("data", "model"):
        specs[f"ici_{axis}"] = ResourceSpec(
            f"ici_{axis}", servers=links_per_axis, mode="shared")
    return specs


def _duration(anno: RateAnno, rates: Dict[str, float],
              fixed: Dict[str, float]) -> float:
    return anno.work / max(rates[anno.rate_key], 1e-30) + fixed[anno.fixed_key]


def reannotate(graph: CompiledGraph,
               system: SystemDescription) -> CompiledGraph:
    """What-if fast path: rescale task durations for new physical
    annotations without re-tiling (the paper's click-of-a-button sweep).

    Valid when tiling-relevant parameters (on-chip capacity, array
    alignment) are unchanged — :meth:`AVSM.what_if` checks this and falls
    back to a full recompile otherwise.
    """
    rates = rate_table(system, graph.plan)
    fixed = fixed_table(system)
    work, ridx, fidx, old_durs = graph.anno_arrays()
    rate_vec = np.array([rates[k] for k in RATE_KEYS])
    fixed_vec = np.array([fixed[k] for k in FIXED_KEYS])
    new_durs = work / np.maximum(rate_vec[ridx], 1e-30) + fixed_vec[fidx]
    new_durs[ridx < 0] = old_durs[ridx < 0]      # tasks without annotations
    # Task objects are shared with the source graph (they are treated as
    # immutable after compilation); only the duration array is new, which
    # keeps a sweep point at O(n_tasks) numpy work — ~100x cheaper than a
    # recompile.  Consumers must read ``graph.durations``, as the estimator
    # backends do, not ``Task.duration``.
    return CompiledGraph(tasks=graph.tasks, ops=graph.ops, system=system,
                         plan=graph.plan, resources=resource_specs(system),
                         _anno_arrays=(work, ridx, fidx, new_durs),
                         _shared=graph._shared)


def compile_ops(ops: List[LayerOp], system: SystemDescription,
                plan: Optional[CompilePlan] = None) -> CompiledGraph:
    plan = plan or CompilePlan()
    chip = system.chip
    eng = chip.compute
    mem = chip.memory
    vmem_budget = max(1, int(chip.onchip.capacity * plan.vmem_fill))
    rates = rate_table(system, plan)
    fixed = fixed_table(system)

    tasks: List[Task] = []
    tid = 0

    def new_task(anno: Optional[RateAnno] = None, **kw) -> Task:
        nonlocal tid
        if anno is not None:
            kw["duration"] = _duration(anno, rates, fixed)
        t = Task(tid=tid, anno=anno, **kw)
        tasks.append(t)
        tid += 1
        return t

    # tail compute task of the previous op (data dependency chain) and the
    # last grad-producing compute per layer (for overlap-aware collectives)
    prev_tail: Optional[Task] = None
    barrier_tail: Optional[Task] = None   # for non-overlapped collectives

    for op_id, op in enumerate(ops):
        if op.kind == "collective":
            c = op.coll
            n = c.axis_size
            if n <= 1 or c.payload <= 0:
                continue
            rate_key = "dcn" if c.axis == "pod" else "ici"
            fixed_key = "dcn_lat" if c.axis == "pod" else "ici_lat"
            if c.kind == "all_reduce":
                steps, step_bytes = 2 * (n - 1), c.payload / n
            elif c.kind in ("all_gather", "reduce_scatter"):
                steps, step_bytes = n - 1, c.payload / n
            elif c.kind == "all_to_all":
                steps, step_bytes = n - 1, c.payload / n
            else:  # permute
                steps, step_bytes = 1, c.payload
            dep = prev_tail if plan.overlap_grad_comm or \
                not op.name.endswith("grad_rs") else barrier_tail
            prev = dep
            for s in range(steps):
                t = new_task(
                    anno=RateAnno(rate_key, step_bytes, fixed_key),
                    name=f"{op.name}/hop{s}", layer=op.layer,
                    resource=f"ici_{c.axis}",
                    deps=(prev.tid,) if prev is not None else (),
                    kind="collective", nbytes=int(step_bytes), op_id=op_id)
                prev = t
            # collectives producing activations gate the next op
            if not op.name.endswith(("grad_rs", "grad_rs_bwd")):
                prev_tail = prev
            continue

        # ---- tiled compute op ----
        eff = _mxu_efficiency(op, eng.align) if op.matrix else 1.0
        working = max(op.total_bytes, 1)
        n_tiles = max(1, math.ceil(working / vmem_budget))
        n_tiles = max(n_tiles, op.seq_chunks)
        agg = 1
        if n_tiles > plan.max_tiles_per_op and op.seq_chunks <= 1:
            agg = math.ceil(n_tiles / plan.max_tiles_per_op)
            n_tiles = math.ceil(n_tiles / agg)

        w_share = (0 if plan.weights_resident
                   else op.weight_bytes / n_tiles)
        in_share = op.in_bytes / n_tiles
        out_share = op.out_bytes / n_tiles
        comp_key = "matrix" if op.matrix else "vector"
        comp_work = (op.flops / n_tiles) / eff

        producer_tail = prev_tail
        compute_tasks: List[Task] = []
        for i in range(n_tiles):
            deps_w: List[int] = []
            # double-buffer constraint: DMA i waits for compute i - depth
            if i >= plan.double_buffer and compute_tasks:
                deps_w.append(compute_tasks[i - plan.double_buffer].tid)
            dma_deps = list(deps_w)
            if producer_tail is not None:
                dma_deps.append(producer_tail.tid)
            t_in = None
            if w_share + in_share > 0:
                t_in = new_task(
                    anno=RateAnno("mem", w_share + in_share, "mem_lat"),
                    name=f"{op.name}/t{i}/dma_in", layer=op.layer,
                    resource="dma",
                    deps=tuple(dma_deps), kind="dma",
                    nbytes=int(w_share + in_share), op_id=op_id)
            comp_deps = [t_in.tid] if t_in is not None else list(dma_deps)
            if op.seq_chunks > 1 and compute_tasks:
                comp_deps.append(compute_tasks[-1].tid)   # recurrence chain
            t_c = new_task(
                anno=RateAnno(comp_key, comp_work, "launch"),
                name=f"{op.name}/t{i}/compute", layer=op.layer,
                resource="nce" if op.matrix else "vpu",
                deps=tuple(comp_deps),
                kind="compute", flops=int(op.flops / n_tiles),
                nbytes=int(w_share + in_share + out_share), op_id=op_id)
            compute_tasks.append(t_c)
            if out_share > 0:
                new_task(
                    anno=RateAnno("mem", out_share, "mem_lat"),
                    name=f"{op.name}/t{i}/dma_out", layer=op.layer,
                    resource="dma",
                    deps=(t_c.tid,), kind="dma", nbytes=int(out_share),
                    op_id=op_id)
        prev_tail = compute_tasks[-1]
        barrier_tail = compute_tasks[-1]

    return CompiledGraph(tasks=tasks, ops=list(ops), system=system, plan=plan,
                        resources=resource_specs(system))
