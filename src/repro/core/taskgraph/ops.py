"""Layer-op IR — the "DNN graph" the AVSM compiler consumes.

A ``LayerOp`` is one logical operation of the per-device SPMD program with
its compute/memory/communication footprint already resolved to *this
device's shard* (the builders in ``builders.py`` apply the sharding plan).
The AVSM compiler (``compiler.py``) tiles these against the on-chip memory
of a virtual hardware model and emits DMA/compute/collective tasks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class CollectiveSpec:
    kind: str            # all_reduce | all_gather | reduce_scatter |
    #                      all_to_all | permute
    payload: int         # bytes per participating device
    axis: str            # mesh axis name ("data" | "model" | "pod")
    axis_size: int


@dataclass
class LayerOp:
    name: str            # e.g. "layer12/ffn_up"
    layer: str           # grouping key, e.g. "layer12"
    kind: str            # matmul | conv | attention | scan | elementwise |
    #                      embed | collective | optimizer
    flops: float = 0.0   # per-device FLOPs
    weight_bytes: int = 0
    in_bytes: int = 0
    out_bytes: int = 0
    # matmul/conv dims (per-device) for MXU-alignment efficiency modelling
    dims: Tuple[int, ...] = ()
    matrix: bool = True          # MXU (matrix) vs VPU (vector) engine
    seq_chunks: int = 1          # >1 => sequential recurrence chain
    coll: Optional[CollectiveSpec] = None

    @property
    def total_bytes(self) -> int:
        return self.weight_bytes + self.in_bytes + self.out_bytes

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.total_bytes, 1)


def matmul_op(name: str, layer: str, m: int, k: int, n: int,
              bytes_per_el: int = 2, weight_resident: bool = False,
              flops_scale: float = 1.0) -> LayerOp:
    """A (m,k) x (k,n) matmul; weight_resident skips the weight DMA
    (weights pinned in on-chip memory — not the TPU default)."""
    return LayerOp(
        name=name, layer=layer, kind="matmul",
        flops=2.0 * m * k * n * flops_scale,
        weight_bytes=0 if weight_resident else k * n * bytes_per_el,
        in_bytes=m * k * bytes_per_el,
        out_bytes=m * n * bytes_per_el,
        dims=(m, k, n), matrix=True)


def elementwise_op(name: str, layer: str, nbytes_in: int, nbytes_out: int,
                   flops_per_el: float = 2.0, bytes_per_el: int = 2) -> LayerOp:
    n_el = nbytes_in / bytes_per_el
    return LayerOp(name=name, layer=layer, kind="elementwise",
                   flops=flops_per_el * n_el, in_bytes=int(nbytes_in),
                   out_bytes=int(nbytes_out), matrix=False)


def attention_op(name: str, layer: str, heads: int, sq: int, sk: int,
                 hd: int, vd: int, causal: bool, batch: int,
                 bytes_per_el: int = 2) -> LayerOp:
    """Flash-style attention core (QK^T + PV), per device."""
    frac = 0.5 if (causal and sq == sk) else 1.0
    flops = 2.0 * batch * heads * sq * sk * (hd + vd) * frac
    qb = batch * heads * sq * hd * bytes_per_el
    kb = batch * heads * sk * hd * bytes_per_el
    vb = batch * heads * sk * vd * bytes_per_el
    ob = batch * heads * sq * vd * bytes_per_el
    return LayerOp(name=name, layer=layer, kind="attention", flops=flops,
                   in_bytes=qb + kb + vb, out_bytes=ob,
                   dims=(sq, hd, sk), matrix=True)


def scan_op(name: str, layer: str, flops: float, in_bytes: int,
            out_bytes: int, seq_chunks: int, matrix: bool = False) -> LayerOp:
    return LayerOp(name=name, layer=layer, kind="scan", flops=flops,
                   in_bytes=in_bytes, out_bytes=out_bytes,
                   seq_chunks=max(1, seq_chunks), matrix=matrix)


def collective_op(name: str, layer: str, kind: str, payload: int,
                  axis: str, axis_size: int) -> LayerOp:
    return LayerOp(name=name, layer=layer, kind="collective",
                   coll=CollectiveSpec(kind=kind, payload=int(payload),
                                       axis=axis, axis_size=axis_size))
