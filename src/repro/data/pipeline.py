"""Deterministic synthetic data pipeline (shardable, seeded, prefetching).

Serves the role of a real corpus loader in this offline container: a
zipf-distributed token stream with enough structure for a language model to
learn (bigram dependencies), generated per-host from (seed, step, host_slice)
so every data-parallel shard sees a disjoint deterministic stream and a
restart resumes *exactly* where it left off (fault-tolerance requirement:
the pipeline state is just the integer step).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    structure: float = 0.7       # P(next token = f(prev)) — learnable signal


class SyntheticTokenPipeline:
    """Deterministic, resumable synthetic LM batches."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        if cfg.global_batch % host_count:
            raise ValueError("global_batch must divide host_count")
        self.local_batch = cfg.global_batch // host_count
        # fixed bigram successor table (the learnable structure)
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, cfg.vocab_size,
                                  size=(cfg.vocab_size,), dtype=np.int64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.host_index)
        b, s = self.local_batch, cfg.seq_len
        base = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
        base = np.clip(base - 1, 0, cfg.vocab_size - 1)
        use_succ = rng.random((b, s)) < cfg.structure
        toks = base.copy()
        # true markov chain: each token follows the *emitted* previous token
        for t in range(1, s):
            toks[:, t] = np.where(use_succ[:, t],
                                  self._succ[toks[:, t - 1]], base[:, t])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (overlaps host data gen with device step)."""

    def __init__(self, pipeline: SyntheticTokenPipeline, start_step: int = 0,
                 depth: int = 2):
        self._pipeline = pipeline
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._pipeline.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
