"""Version shims for the pallas TPU API surface the kernels use."""
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams.
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
