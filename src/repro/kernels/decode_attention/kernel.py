"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Grid (batch, kv_heads, kv_blocks): the query-head *group* of a GQA kv head
(shape (group, hd)) stays resident in VMEM while kv blocks stream through;
(m, l, acc) accumulate in scratch.  Variable cache occupancy is handled with
a kv_len scalar (positions >= kv_len are masked), so one compiled kernel
serves every decode step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                 # (group, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); k, v: (B, Hkv, S, hd); kv_len: scalar int32.

    Returns (B, Hq, hd): softmax(q k^T / sqrt(hd)) v over positions < kv_len.
    """
    B, Hq, hd = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    block_k = min(block_k, S)
    nk = -(-S // block_k)
    pad = nk * block_k - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(B, Hkv, group, hd)
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len_arr, qg, k, v)
    return out.reshape(B, Hq, hd)
