"""Jit'd public wrapper for flash-decode."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention_op(q, k, v, kv_len, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return decode_attention(q, k, v, kv_len, interpret=interpret)


__all__ = ["decode_attention_op", "decode_attention", "decode_attention_ref"]
