"""Pure-jnp oracle for flash-decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len):
    """q: (B,Hq,hd); k,v: (B,Hkv,S,hd); kv_len scalar."""
    B, Hq, hd = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,bnsd->bngs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    mask = jnp.arange(S)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bnsd->bngd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd).astype(q.dtype)
