"""Pallas TPU flash-attention (forward) kernel.

Online-softmax attention tiled for VMEM: grid (batch*q_heads, q_blocks,
kv_blocks) with the kv axis sequential ("arbitrary") so the (m, l, acc)
running statistics live in VMEM scratch across kv steps.  GQA is handled by
indexing the kv arrays at ``head // group``.  Block shapes default to
(128, head_dim) — MXU-aligned for head_dim in {64, 128, 192, 256}.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_offset: int,
                  block_q: int, block_k: int, kv_len: int):
    _, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                   # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Sk, hd).  Returns (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = -(-Sq // block_q), -(-Sk // block_k)
    q_pad, k_pad = nq * block_q - Sq, nk * block_k - Sk
    qf = q.reshape(B * Hq, Sq, hd)
    kf = k.reshape(B * Hkv, Sk, hd)
    vf = v.reshape(B * Hkv, Sk, hd)
    if q_pad:
        qf = jnp.pad(qf, ((0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        kf = jnp.pad(kf, ((0, 0), (0, k_pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, k_pad), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, kv_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, Hq, Sq, hd)
