"""Jit'd public wrapper for flash attention with backend dispatch."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention_op(q, k, v, *, causal: bool = True, q_offset: int = 0,
                       interpret: bool | None = None):
    """Flash attention; interpret defaults to True off-TPU so the Pallas
    kernel body itself is what runs (and is tested) everywhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                           interpret=interpret)


__all__ = ["flash_attention_op", "flash_attention", "attention_ref"]
