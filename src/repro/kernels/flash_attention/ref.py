"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Sk, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bngqd,bnkd->bngqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bngqk,bnkd->bngqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)
