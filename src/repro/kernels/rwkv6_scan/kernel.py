"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked).

Grid (batch*heads, chunks) with the chunk axis sequential: the (hd x hd)
WKV state lives in VMEM scratch across chunks.  Within a chunk the
contribution of in-chunk pairs is a masked (c x c) matmul with per-channel
pairwise decays; every exponent is a difference of cumulative log-decays
inside one chunk (<= 0), so the kernel is overflow-safe by construction —
the same formulation as the XLA twin in repro.models.rwkv6.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams



def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                o_ref, sout_ref, state_scr, *, chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)        # (c, hd) log-decays (<0)
    u = u_ref[0].astype(jnp.float32)          # (1, hd) bonus

    cum = jnp.cumsum(lw, axis=0)              # inclusive logW
    cum_ex = cum - lw                         # exclusive logW (W_{t-1})

    # intra-chunk pairwise decays: exp(cum_ex[t] - cum[i]) for i < t
    diff = cum_ex[:, None, :] - cum[None, :, :]          # (t, i, hd)
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    A = jnp.einsum("tik,tk,ik->ti", decay, r, k)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(t_idx > i_idx, A, 0.0)
    out = jax.lax.dot(A, v, preferred_element_type=jnp.float32)

    # bonus (current token) term
    Au = jnp.sum(r * u * k, axis=-1, keepdims=True)      # (c, 1)
    out += Au * v

    # cross-chunk: query the carried state, decayed from chunk start
    s = state_scr[...]                                   # (hd, hd)
    out += jax.lax.dot(r * jnp.exp(cum_ex), s,
                       preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    # state update: k decayed from position i to the end of the chunk
    wlast = cum[-1:, :]                                  # (1, hd)
    kdec = k * jnp.exp(wlast - cum)                      # exponent <= 0
    state_scr[...] = s * jnp.exp(wlast.T) + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(c == nc - 1)
    def _finish():
        sout_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, state0, *, chunk: int = 32,
               interpret: bool = False):
    """r,k,v,logw: (N, S, hd) with N = batch*heads; u: (N, hd);
    state0: (N, hd, hd) f32.  Returns (out (N,S,hd) f32, state (N,hd,hd) f32).
    """
    N, S, hd = r.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        logw = jnp.pad(logw, zpad)   # log(1)=0 pad is harmless: k,v are 0

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out, state = pl.pallas_call(
        kernel,
        grid=(N, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, hd), lambda n, c: (n, 0)),
            pl.BlockSpec((1, hd, hd), lambda n, c: (n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda n, c: (n, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda n, c: (n, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, nc * chunk, hd), jnp.float32),
            jax.ShapeDtypeStruct((N, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return out[:, :S], state
