"""Jit'd public wrapper for the RWKV-6 WKV kernel."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def rwkv6_scan_op(r, k, v, logw, u, state0, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rwkv6_scan(r, k, v, logw, u, state0, interpret=interpret)


__all__ = ["rwkv6_scan_op", "rwkv6_scan", "rwkv6_scan_ref"]
