"""Pure-jnp sequential oracle for the RWKV-6 WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u, state0):
    """Sequential reference.  r,k,v,logw: (N,S,hd); u: (N,hd);
    state0: (N,hd,hd).  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t);
    S_t = diag(w_t) S_{t-1} + k_t^T v_t.
    """
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                 # (N,hd) each
        kv = kt[:, :, None] * vt[:, None, :]             # (N,hd,hd)
        y = jnp.einsum("nk,nkv->nv", rt, s + uf[:, :, None] * kv)
        s_new = wt[:, :, None] * s + kv
        return s_new, y

    xs = (rf.transpose(1, 0, 2), kf.transpose(1, 0, 2),
          vf.transpose(1, 0, 2), wf.transpose(1, 0, 2))
    s_fin, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), s_fin
