"""Pallas TPU kernel for the Mamba selective scan (chunked).

Grid (batch, d_inner_blocks, chunks) with the chunk axis sequential: the
(di_block x d_state) hidden state is carried in VMEM scratch.  Within a
chunk the recurrence h_t = da_t * h_{t-1} + dbu_t is evaluated with an
associative scan over the chunk axis — identical math to the XLA twin in
repro.models.ssm.selective_scan_chunked.  Blocking over d_inner keeps the
(chunk, di_block, d_state) discretised tensors inside VMEM for d_inner up
to 16384 (jamba).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams



def _ssm_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                y_ref, hout_ref, h_scr):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)          # (c, dib)
    dt = dt_ref[0].astype(jnp.float32)        # (c, dib)
    A = a_ref[...].astype(jnp.float32)        # (dib, ds)
    B = b_ref[0].astype(jnp.float32)          # (c, ds)
    C = c_ref[0].astype(jnp.float32)          # (c, ds)
    D = d_ref[...].astype(jnp.float32)        # (1, dib)

    da = jnp.exp(dt[:, :, None] * (-jnp.exp(A))[None])   # (c, dib, ds)
    dbu = (dt * u)[:, :, None] * B[:, None, :]           # (c, dib, ds)

    def comb(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(comb, (da, dbu), axis=0)
    h_t = a_cum * h_scr[...][None] + b_cum               # (c, dib, ds)
    y = jnp.einsum("cds,cs->cd", h_t, C) + u * D
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h_t[-1]

    @pl.when(c_idx == nc - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_di", "interpret"))
def ssm_scan(u, dt, A, B, C, D, h0, *, chunk: int = 64,
             block_di: int = 512, interpret: bool = False):
    """u, dt: (Bz, S, di); A: (di, ds); B, C: (Bz, S, ds); D: (di,);
    h0: (Bz, di, ds) f32.  Returns (y (Bz,S,di) f32, h (Bz,di,ds) f32)."""
    Bz, S, di = u.shape
    ds = A.shape[-1]
    chunk = min(chunk, S)
    block_di = min(block_di, di)
    nc = -(-S // chunk)
    ndi = -(-di // block_di)
    assert di % block_di == 0, "d_inner must divide block_di"
    pad = nc * chunk - S
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    D2 = D.reshape(1, di)

    y, h = pl.pallas_call(
        _ssm_kernel,
        grid=(Bz, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_di, ds), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, block_di), lambda b, d, c: (0, d)),
            pl.BlockSpec((1, block_di, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_di, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, nc * chunk, di), jnp.float32),
            jax.ShapeDtypeStruct((Bz, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, ds), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, A, B, C, D2, h0)
    return y[:, :S], h
