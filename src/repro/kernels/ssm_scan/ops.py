"""Jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def ssm_scan_op(u, dt, A, B, C, D, h0, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan(u, dt, A, B, C, D, h0, interpret=interpret)


__all__ = ["ssm_scan_op", "ssm_scan", "ssm_scan_ref"]
