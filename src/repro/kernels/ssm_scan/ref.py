"""Pure-jnp sequential oracle for the Mamba selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, A, B, C, D, h0):
    """Sequential reference.  Shapes as repro.kernels.ssm_scan.kernel."""
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = -jnp.exp(A.astype(jnp.float32))
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def step(h, inp):
        ut, dtt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * Af[None])          # (Bz,di,ds)
        dbu = (dtt * ut)[:, :, None] * bt[:, None, :]
        h_new = da * h + dbu
        y = jnp.einsum("bds,bs->bd", h_new, ct) + ut * Df[None]
        return h_new, y

    xs = (uf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    h_fin, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h_fin
