import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
#
# Multi-pod dry-run: for every (architecture x input-shape x mesh) cell,
# lower + compile the step function on the production mesh with
# ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis,
# and write a JSON artifact consumed by the roofline table
# (EXPERIMENTS.md section Dry-run / section Roofline).
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]

import argparse
import json
import sys
import time
import traceback

import jax

from repro.core.config import (LM_SHAPES, OptimizerConfig, get_arch,
                               list_archs)
from repro.core.hlo.analysis import analyze_compiled
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import adamw
from repro.sharding import activation_rules


def dryrun_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
                seq_parallel=None, verbose: bool = True,
                remat: str = "full") -> dict:
    """Lower + compile one cell; returns the roofline artifact dict.

    Baseline remat='full': recompute per layer in backward — conservative
    memory (the CPU dry-run backend also up-casts bf16 dot operands to f32,
    so memory_analysis here is an upper bound vs real TPU).
    """
    spec = get_arch(arch_id)
    cfg = spec.model
    shape = LM_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_str = mesh_lib.mesh_name(mesh)
    if seq_parallel is None:
        seq_parallel = shape.mode == "decode"

    t0 = time.perf_counter()
    params_shapes = api.param_shapes(cfg)
    inputs = api.input_specs(cfg, shape)

    with activation_rules(mesh, seq_parallel=seq_parallel):
        if shape.mode == "train":
            opt_cfg = OptimizerConfig()
            opt_shapes = jax.eval_shape(
                lambda: adamw.init_opt_state(
                    jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape,
                                                                s.dtype),
                                 params_shapes), opt_cfg))
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        opt_shapes, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape, opt_cfg,
                                                  remat=remat)
            jitted = jax.jit(
                step_fn,
                in_shardings=(sh["params"], sh["opt_state"], sh["batch"]),
                out_shardings=(sh["params"], sh["opt_state"], None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, inputs)
        elif shape.mode == "prefill":
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        None, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape)
            jitted = jax.jit(step_fn,
                             in_shardings=(sh["params"], sh["batch"]))
            lowered = jitted.lower(params_shapes, inputs)
        else:  # decode
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        None, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape)
            jitted = jax.jit(
                step_fn,
                in_shardings=(sh["params"], sh["state"], sh["tokens"],
                              sh["pos"]),
                out_shardings=(None, sh["state"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, inputs["state"],
                                   inputs["tokens"], inputs["pos"])
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch_id} | {shape_name} | mesh {mesh_str}]")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
    report = analyze_compiled(compiled)
    report.update({
        "arch": arch_id, "shape": shape_name, "mesh": mesh_str,
        "chips": mesh.devices.size, "multi_pod": multi_pod,
        "seq_parallel": seq_parallel,
        "lower_seconds": t_lower, "compile_seconds": t_compile,
        "model_flops": api.model_flops(cfg, shape),
        "param_count": api.param_count(cfg),
        "active_param_count": api.param_count(cfg, active_only=True),
    })
    if verbose:
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis flops={ca.get('flops', 0):.3e} "
              f"(walker: {report['flops']:.3e})")
        print(f"  per-device: flops={report['flops']:.3e} "
              f"hbm={report['hbm_bytes'] / 1e9:.2f}GB "
              f"coll={report['collective_bytes'] / 1e9:.3f}GB "
              f"peak_mem={report.get('peak_bytes', 0) / 1e9:.2f}GB")
    return report


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", type=str, default="runs/dryrun")
    args = p.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for aid in list_archs():
            spec = get_arch(aid)
            for s in spec.shapes:
                if s in spec.skip_shapes:
                    continue
                cells.append((aid, s))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for aid, s in cells:
        for mp in meshes:
            tag = f"{aid}_{s}_{'512' if mp else '256'}"
            try:
                rep = dryrun_cell(aid, s, multi_pod=mp)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, str(e)))
    if failures:
        print(f"\nFAILED {len(failures)} cells:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        sys.exit(1)
    print(f"\nOK: {len(cells) * len(meshes)} cells")


if __name__ == "__main__":
    main()
