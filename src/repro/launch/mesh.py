"""Mesh construction + sharding assignment for the production topology.

``make_production_mesh`` builds the grading meshes:
  single-pod:  (16, 16)        axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

Functions only — importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.core.config import (MeshConfig, ModelConfig, OptimizerConfig,
                               ShapeConfig)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(num_devices: int, model_parallel: int = 16) -> Mesh:
    """Best-effort (data, model) mesh for an arbitrary surviving device
    count (elastic scaling after failures)."""
    while model_parallel > 1 and num_devices % model_parallel:
        model_parallel //= 2
    data = num_devices // model_parallel
    devs = np.asarray(jax.devices()[:data * model_parallel])
    return Mesh(devs.reshape(data, model_parallel), ("data", "model"))


def mesh_name(mesh: Mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


# ---------------------------------------------------------------------------
# Sharding assignment per step kind
# ---------------------------------------------------------------------------


def batch_shardings(cfg: ModelConfig, batch_specs: Dict[str, Any],
                    mesh: Mesh) -> Dict[str, Any]:
    out = {}
    for k, v in batch_specs.items():
        if k == "tokens":
            logical = ("batch",) + (None,) * (len(v.shape) - 1)
        elif k in ("prefix_embeds", "frames"):
            logical = ("batch", None, None)
        elif k == "image":
            logical = ("batch", None, None, None)
        elif k == "labels":
            logical = ("batch",) + (None,) * (len(v.shape) - 1)
        elif k == "pos":
            logical = ()
        else:
            logical = (None,) * len(v.shape)
        out[k] = sh.input_pspec(v.shape, logical, mesh)
    return out


def shardings_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  params_shapes, opt_shapes=None,
                  input_specs: Optional[Dict[str, Any]] = None,
                  seq_parallel: bool = False) -> Dict[str, Any]:
    """in/out sharding pytrees for the step function of this shape cell."""
    param_sh = sh.param_shardings(params_shapes, mesh)
    repl = NamedSharding(mesh, P())
    out: Dict[str, Any] = {"params": param_sh}
    if opt_shapes is not None:
        opt_sh = {
            "m": sh.param_shardings(opt_shapes["m"], mesh),
            "v": sh.param_shardings(opt_shapes["v"], mesh),
            "step": repl,
        }
        if "ef" in opt_shapes:
            opt_sh["ef"] = sh.param_shardings(opt_shapes["ef"], mesh)
        out["opt_state"] = opt_sh
    if input_specs is not None:
        if shape.mode == "decode":
            out["state"] = sh.state_shardings(input_specs["state"], mesh,
                                              seq_parallel=seq_parallel)
            out["tokens"] = sh.input_pspec(input_specs["tokens"].shape,
                                           ("batch",), mesh)
            out["pos"] = repl
        else:
            batch = {k: v for k, v in input_specs.items()}
            out["batch"] = batch_shardings(cfg, batch, mesh)
    return out
