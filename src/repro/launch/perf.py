"""Perf-iteration harness (§Perf): hypothesis -> change -> re-lower ->
measure, on dry-run artifacts.

Each iteration re-runs one (arch x shape) cell with a knob changed and
reports the three roofline terms + the top HBM/FLOP contributors, appending
to runs/perf/<cell>.jsonl so EXPERIMENTS.md §Perf can show the full path.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen2.5-14b \
        --shape train_4k --tag sp_on --seq-parallel 1 --remat dots
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import time

import jax

from repro.core.config import LM_SHAPES, OptimizerConfig, get_arch
from repro.core.estimator.roofline import roofline_terms
from repro.core.hlo.analysis import analyze_compiled, top_contributors
from repro.core.hw import get_system
from repro.core.taskgraph.compiler import CompilePlan
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import adamw
from repro.sharding import activation_rules


def run_cell(arch_id: str, shape_name: str, *, remat: str = "full",
             seq_parallel=None, capacity_factor=None, multi_pod=False,
             tag: str = "baseline", show_top: int = 8) -> dict:
    spec = get_arch(arch_id)
    cfg = spec.model
    if capacity_factor is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    shape = LM_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    if seq_parallel is None:
        seq_parallel = shape.mode == "decode"

    params_shapes = api.param_shapes(cfg)
    inputs = api.input_specs(cfg, shape)
    t0 = time.perf_counter()
    with activation_rules(mesh, seq_parallel=seq_parallel):
        if shape.mode == "train":
            opt_cfg = OptimizerConfig()
            opt_shapes = jax.eval_shape(
                lambda: adamw.init_opt_state(
                    jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                        s.shape, s.dtype), params_shapes), opt_cfg))
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        opt_shapes, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape, opt_cfg,
                                                  remat=remat)
            jitted = jax.jit(step_fn,
                             in_shardings=(sh["params"], sh["opt_state"],
                                           sh["batch"]),
                             out_shardings=(sh["params"], sh["opt_state"],
                                            None),
                             donate_argnums=(0, 1))
            compiled = jitted.lower(params_shapes, opt_shapes,
                                    inputs).compile()
        elif shape.mode == "prefill":
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        None, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape)
            compiled = jax.jit(step_fn,
                               in_shardings=(sh["params"], sh["batch"])
                               ).lower(params_shapes, inputs).compile()
        else:
            sh = mesh_lib.shardings_for(cfg, shape, mesh, params_shapes,
                                        None, inputs,
                                        seq_parallel=seq_parallel)
            step_fn, _ = steps_lib.step_for_shape(cfg, shape)
            compiled = jax.jit(
                step_fn,
                in_shardings=(sh["params"], sh["state"], sh["tokens"],
                              sh["pos"]),
                out_shardings=(None, sh["state"]),
                donate_argnums=(1,)).lower(
                    params_shapes, inputs["state"], inputs["tokens"],
                    inputs["pos"]).compile()
    wall = time.perf_counter() - t0

    rep = analyze_compiled(compiled)
    chips = mesh.devices.size
    # roofline terms via the estimator stack's rate tables, so the virtual
    # system description (not hard-wired constants) defines the roofs.
    # HLO collective bytes are per-device payloads, not ring wire traffic:
    # use the single-direction link rate (bidirectional_ici=False).
    system = get_system("tpu_v5e_pod")
    plan = CompilePlan(bidirectional_ici=False)
    # TPU-adjusted: f32 collective payloads are CPU dot-legalization
    # artifacts for bf16 models (bf16 on the real target)
    t_c, t_m, t_i = roofline_terms(
        rep["flops"], rep["hbm_bytes"],
        rep.get("collective_bytes_tpu_adjusted", rep["collective_bytes"]),
        system, plan)
    _, _, t_i_raw = roofline_terms(
        rep["flops"], rep["hbm_bytes"], rep["collective_bytes"], system, plan)
    peak_flops = system.chip.compute.flops_for(plan.dtype, matrix=True)
    mf = api.model_flops(cfg, shape)
    out = {
        "tag": tag, "arch": arch_id, "shape": shape_name,
        "mesh": mesh_lib.mesh_name(mesh), "remat": remat,
        "seq_parallel": seq_parallel, "capacity_factor": capacity_factor,
        "t_compute_ms": t_c * 1e3, "t_memory_ms": t_m * 1e3,
        "t_collective_ms": t_i * 1e3,
        "t_collective_raw_ms": t_i_raw * 1e3,
        "bound_ms": max(t_c, t_m, t_i) * 1e3,
        "dominant": max(("compute", t_c), ("memory", t_m),
                        ("collective", t_i), key=lambda kv: kv[1])[0],
        "useful_ratio": mf / chips / max(rep["flops"], 1),
        "peak_bytes_gb": rep.get("peak_bytes", 0) / 1e9,
        "roofline_fraction": (mf / (chips * peak_flops))
        / max(t_c, t_m, t_i),
        "compile_s": wall,
        "collective_breakdown": rep["collective_breakdown"],
    }
    print(f"[{tag}] {arch_id}/{shape_name}  t_comp={t_c * 1e3:.1f}ms  "
          f"t_mem={t_m * 1e3:.1f}ms  t_coll={t_i * 1e3:.1f}ms  "
          f"bound={out['dominant']}  roofline={out['roofline_fraction']:.1%} "
          f"peak_mem={out['peak_bytes_gb']:.1f}GB")
    if show_top:
        print("  top HBM contributors (per device, x trips):")
        for val, mult, comp, opc, name in top_contributors(
                compiled.as_text(), show_top, "bytes"):
            print(f"    {val / 1e9:9.2f}GB x{mult:3d} {opc:12s} {name[:70]}")
    os.makedirs("runs/perf", exist_ok=True)
    with open(f"runs/perf/{arch_id}_{shape_name}.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--tag", default="baseline")
    p.add_argument("--remat", default="full")
    p.add_argument("--seq-parallel", type=int, default=-1)
    p.add_argument("--capacity-factor", type=float, default=None)
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args(argv)
    run_cell(args.arch, args.shape, remat=args.remat,
             seq_parallel=None if args.seq_parallel < 0
             else bool(args.seq_parallel),
             capacity_factor=args.capacity_factor,
             multi_pod=args.multi_pod, tag=args.tag)


if __name__ == "__main__":
    main()
