"""Batched serving driver: continuous-batching loop over prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --max-new 32

A minimal production-shaped server core: a request queue, bucketed prefill,
a decode batch with in-flight slot reuse (a finished request's slot is
refilled from the queue), greedy sampling.  On TPU the same loop runs the
full config on the production mesh with the Pallas decode kernel.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import get_arch
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import api
from repro.sharding import activation_rules


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching (decode-centric)."""

    def __init__(self, cfg, batch_slots: int, max_len: int):
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.state = api.allocate_decode_state(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.decode = jax.jit(steps_lib.make_serve_step(cfg),
                              donate_argnums=(1,))
        self.params = None

    def load(self, params):
        self.params = params

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (token-by-token prefill keeps
        one compiled decode step; bucket prefill is the production path)."""
        try:
            slot = self.slot_req.index(None)
        except ValueError:
            return False
        self.slot_req[slot] = req
        pos = 0
        for tok in req.prompt:
            tokens = np.zeros((self.slots,), np.int32)
            tokens[slot] = tok
            _, self.state = self.decode(self.params, self.state,
                                        jnp.asarray(tokens),
                                        jnp.asarray(pos, jnp.int32))
            pos += 1
        self.slot_pos[slot] = len(req.prompt)
        return True

    def step(self) -> int:
        """One decode step for every active slot; returns #finished."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.slots,), np.int32)
        for i in active:
            r = self.slot_req[i]
            tokens[i] = r.out[-1] if r.out else r.prompt[-1]
        pos = int(max(self.slot_pos[i] for i in active))
        logits, self.state = self.decode(self.params, self.state,
                                         jnp.asarray(tokens),
                                         jnp.asarray(pos, jnp.int32))
        logits = np.asarray(logits)
        finished = 0
        for i in active:
            r = self.slot_req[i]
            nxt = int(np.argmax(logits[i]))
            r.out.append(nxt)
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new or self.slot_pos[i] >= self.max_len - 1:
                r.done = True
                self.slot_req[i] = None
                finished += 1
        return finished


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--max-len", type=int, default=128)
    args = p.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    if cfg.family in ("audio", "encdec", "convnet"):
        raise SystemExit("serve.py targets decoder-only archs")

    mesh = mesh_lib.make_elastic_mesh(jax.device_count(), 1)
    with activation_rules(mesh):
        params = api.init_params(jax.random.key(0), cfg)
        server = BatchedServer(cfg, args.slots, args.max_len)
        server.load(params)

        rng = np.random.default_rng(0)
        queue = [Request(i, rng.integers(0, cfg.vocab_size,
                                         size=(args.prompt_len,)),
                         args.max_new)
                 for i in range(args.requests)]
        done: List[Request] = []
        t0 = time.perf_counter()
        pending = list(queue)
        steps = 0
        while len(done) < len(queue):
            while pending and server.admit(pending[0]):
                pending.pop(0)
            server.step()
            steps += 1
            done = [r for r in queue if r.done]
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in queue)
        print(f"served {len(queue)} requests, {toks} tokens in {wall:.2f}s "
              f"({toks / wall:.1f} tok/s, {steps} decode steps)")
        return queue


if __name__ == "__main__":
    main()
