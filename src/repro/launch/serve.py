"""Batched serving driver: continuous-batching loop over prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --max-new 32

A minimal production-shaped server core: a request queue, bucketed prefill,
a decode batch with in-flight slot reuse (a finished request's slot is
refilled from the queue), greedy sampling.  On TPU the same loop runs the
full config on the production mesh with the Pallas decode kernel.

Decode steps run with **per-slot cache positions**: each active slot
writes/attends at its own sequence position, so slots at different depths
coexist in one batch (the scalar-``pos`` variant corrupted any slot that
was not at ``max(slot_pos)``).

This server is the *measured* counterpart of the virtual
continuous-batching scheduler in ``repro.serve_sim.scheduler`` — it logs
the same per-request TTFT/TPOT and an admit/step/finish event sequence, so
the paper's predicted-vs-measured accuracy loop extends to serving
(``tests/test_serve_sim.py`` asserts the virtual scheduler reproduces this
loop's ordering on a scripted arrival trace).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import get_arch
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import api
from repro.sharding import activation_rules


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    # per-request serving metrics (perf_counter timestamps; the measured
    # side of the virtual ServingReport)
    t_arrive: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrive

    @property
    def tpot(self) -> float:
        n = len(self.out)
        return (self.t_done - self.t_first) / (n - 1) if n > 1 else 0.0


class BatchedServer:
    """Slot-based continuous batching (decode-centric).

    ``decode_fn(params, state, tokens, pos) -> (logits, state)`` defaults
    to the jitted JAX decode step; tests inject a stub to exercise the
    scheduling loop (admit/step ordering, per-slot positions) without
    compiling a model.  ``pos`` is always the per-slot position vector.
    """

    def __init__(self, cfg, batch_slots: int, max_len: int,
                 decode_fn: Optional[Callable] = None, state=None,
                 record_events: bool = False):
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.record_events = record_events
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        if decode_fn is None:
            self.state = api.allocate_decode_state(cfg, batch_slots, max_len)
            self.decode = jax.jit(steps_lib.make_serve_step(cfg),
                                  donate_argnums=(1,))
        else:
            self.state = state
            self.decode = decode_fn
        self.params = None
        # ("admit", rid) | ("step", rids) | ("finish", rid); recorded only
        # with record_events (parity vs the virtual scheduler) — unbounded
        # otherwise
        self.events: List[Tuple] = []

    def load(self, params):
        self.params = params

    def _pos_vector(self, slot: int, pos: int) -> np.ndarray:
        """Per-slot positions: every slot keeps its own write index; only
        ``slot`` is overridden (prefill walks it through the prompt)."""
        vec = self.slot_pos.copy()
        vec[slot] = pos
        return vec

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (token-by-token prefill keeps
        one compiled decode step; bucket prefill is the production path)."""
        try:
            slot = self.slot_req.index(None)
        except ValueError:
            return False
        self.slot_req[slot] = req
        req.t_admit = time.perf_counter()
        if self.record_events:
            self.events.append(("admit", req.rid))
        for pos, tok in enumerate(req.prompt):
            tokens = np.zeros((self.slots,), np.int32)
            tokens[slot] = tok
            _, self.state = self.decode(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(self._pos_vector(slot, pos), jnp.int32))
        self.slot_pos[slot] = len(req.prompt)
        return True

    def step(self) -> int:
        """One decode step for every active slot; returns #finished."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.slots,), np.int32)
        for i in active:
            r = self.slot_req[i]
            tokens[i] = r.out[-1] if r.out else r.prompt[-1]
        if self.record_events:
            self.events.append(
                ("step", tuple(sorted(self.slot_req[i].rid for i in active))))
        logits, self.state = self.decode(
            self.params, self.state, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos, jnp.int32))
        logits = np.asarray(logits)
        now = time.perf_counter()
        finished = 0
        for i in active:
            r = self.slot_req[i]
            nxt = int(np.argmax(logits[i]))
            if not r.out:
                r.t_first = now
            r.out.append(nxt)
            self.slot_pos[i] += 1
            if len(r.out) >= r.max_new or self.slot_pos[i] >= self.max_len - 1:
                r.done = True
                r.t_done = now
                self.slot_req[i] = None
                if self.record_events:
                    self.events.append(("finish", r.rid))
                finished += 1
        return finished


def serve_summary(requests: List[Request]) -> str:
    """Measured TTFT/TPOT percentiles (counterpart of ServingReport)."""
    done = [r for r in requests if r.done]
    if not done:
        return "no finished requests"
    ttft = np.array([r.ttft for r in done])
    tpot = np.array([r.tpot for r in done if len(r.out) > 1])
    lines = [f"  TTFT p50/p99 = {np.percentile(ttft, 50) * 1e3:.0f}/"
             f"{np.percentile(ttft, 99) * 1e3:.0f} ms"]
    if tpot.size:
        lines.append(f"  TPOT p50/p99 = {np.percentile(tpot, 50) * 1e3:.2f}/"
                     f"{np.percentile(tpot, 99) * 1e3:.2f} ms")
    return "\n".join(lines)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--max-len", type=int, default=128)
    args = p.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    if cfg.family in ("audio", "encdec", "convnet"):
        raise SystemExit("serve.py targets decoder-only archs")

    mesh = mesh_lib.make_elastic_mesh(jax.device_count(), 1)
    with activation_rules(mesh):
        params = api.init_params(jax.random.key(0), cfg)
        server = BatchedServer(cfg, args.slots, args.max_len)
        server.load(params)

        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        queue = [Request(i, rng.integers(0, cfg.vocab_size,
                                         size=(args.prompt_len,)),
                         args.max_new, t_arrive=t0)
                 for i in range(args.requests)]
        done: List[Request] = []
        pending = list(queue)
        steps = 0
        while len(done) < len(queue):
            while pending and server.admit(pending[0]):
                pending.pop(0)
            server.step()
            steps += 1
            done = [r for r in queue if r.done]
        wall = time.perf_counter() - t0
        toks = sum(len(r.out) for r in queue)
        print(f"served {len(queue)} requests, {toks} tokens in {wall:.2f}s "
              f"({toks / wall:.1f} tok/s, {steps} decode steps)")
        print(serve_summary(queue))
        return queue


if __name__ == "__main__":
    main()
