"""Step functions (train / prefill / decode) — the units the launcher jits
and the dry-run lowers.

``make_train_step``/``make_serve_step`` close over (cfg, train cfg) and are
pure: state in, state out, donate-able.  Sharding comes from in_shardings /
out_shardings computed by ``repro.launch.mesh.shardings_for``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, OptimizerConfig, ShapeConfig
from repro.models import api
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    remat: str = "dots") -> Callable:
    def train_step(params, opt_state, batch):
        def loss_of(p):
            loss, metrics = api.loss_fn(p, cfg, batch, remat=remat) \
                if cfg.family != "convnet" else api.loss_fn(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        params, opt_state, opt_metrics = adamw.adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One decode step: new token against an existing cache."""

    def serve_step(params, state, tokens, pos):
        return api.decode_step(params, cfg, state, tokens, pos)

    return serve_step


def step_for_shape(cfg: ModelConfig, shape: ShapeConfig,
                   opt_cfg: Optional[OptimizerConfig] = None,
                   remat: str = "dots") -> Tuple[Callable, str]:
    """Returns (step_fn, kind) for a shape cell.

    train  -> train_step(params, opt_state, batch)
    prefill-> prefill_step(params, batch)
    decode -> serve_step(params, state, tokens, pos)
    """
    if shape.mode == "train":
        return make_train_step(cfg, opt_cfg or OptimizerConfig(),
                               remat=remat), "train"
    if shape.mode == "prefill":
        return make_prefill_step(cfg), "prefill"
    return make_serve_step(cfg), "decode"
