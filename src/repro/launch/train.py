"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --batch 8 --seq 128

Wires together: config registry -> mesh + sharding -> synthetic data
pipeline (prefetching) -> jitted train step (donated state) -> checkpoint
manager (async, atomic, auto-resume) -> supervisor heartbeats.  ``--smoke``
selects the reduced config (CPU-runnable, f32); omit it on a real TPU fleet
to train the full config on the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.config import OptimizerConfig, get_arch
from repro.data.pipeline import DataConfig, PrefetchIterator, \
    SyntheticTokenPipeline
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import adamw
from repro.runtime.supervisor import Supervisor
from repro.sharding import activation_rules


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--remat", default="none")
    p.add_argument("--grad-compression", default="none",
                   choices=["none", "int8_ef"])
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--dtype", default="float32",
                   help="param/compute dtype (CPU executes f32 only)")
    args = p.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.model
    cfg = dataclasses.replace(cfg, param_dtype=args.dtype,
                              compute_dtype=args.dtype)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps,
                              grad_compression=args.grad_compression)

    n_dev = jax.device_count()
    mesh = mesh_lib.make_elastic_mesh(n_dev, model_parallel=min(n_dev, 16) if n_dev > 1 else 1)
    print(f"devices={n_dev} mesh={mesh_lib.mesh_name(mesh)} "
          f"arch={cfg.name} params≈{api.param_count(cfg):,}")

    rng = jax.random.key(0)
    with activation_rules(mesh):
        params = api.init_params(rng, cfg)
        opt_state = adamw.init_opt_state(params, opt_cfg)
        step_fn = steps_lib.make_train_step(cfg, opt_cfg, remat=args.remat)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch)
        pipeline = SyntheticTokenPipeline(data_cfg)
        ckpt = CheckpointManager(args.ckpt_dir)
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            start_step, state = ckpt.restore()
            params, opt_state = state["params"], state["opt_state"]
            print(f"resumed from step {start_step}")

        sup = Supervisor(num_workers=1)
        prefetch = PrefetchIterator(pipeline, start_step=start_step)
        losses = []
        t_start = time.perf_counter()
        try:
            for _ in range(start_step, args.steps):
                step_i, host_batch = next(prefetch)
                batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                if cfg.family == "vlm":
                    npre = min(cfg.frontend.num_prefix, args.seq // 2)
                    batch["prefix_embeds"] = jnp.zeros(
                        (args.batch, npre, cfg.d_model), jnp.float32)
                elif cfg.family in ("audio", "encdec"):
                    batch = {"frames": jnp.zeros(
                        (args.batch, args.seq // 2, cfg.d_model),
                        jnp.float32),
                        "tokens": batch["tokens"][:, :args.seq // 2]}
                t0 = time.perf_counter()
                params, opt_state, metrics = jitted(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                sup.heartbeat(0, step_i, dt)
                losses.append(loss)
                if (step_i + 1) % args.log_every == 0:
                    print(f"step {step_i + 1:5d}  loss {loss:8.4f}  "
                          f"gnorm {float(metrics['grad_norm']):7.3f}  "
                          f"lr {float(metrics['lr']):.2e}  {dt * 1e3:7.1f} ms")
                if (step_i + 1) % args.ckpt_every == 0:
                    ckpt.save(step_i + 1,
                              {"params": params, "opt_state": opt_state})
        finally:
            prefetch.close()
            ckpt.wait()
        wall = time.perf_counter() - t_start
        print(f"done: {args.steps - start_step} steps in {wall:.1f}s; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        ckpt.save(args.steps, {"params": params, "opt_state": opt_state})
        ckpt.wait()
        return losses


if __name__ == "__main__":
    main()
