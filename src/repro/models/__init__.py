"""Model zoo: dense/MoE/MLA transformers, RWKV6, Mamba, hybrids, enc-dec,
VLM backbones and the paper's DilatedVGG."""
