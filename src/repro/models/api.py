"""Unified model API: family dispatch + input specs + analytical FLOPs.

Every launcher, test and benchmark goes through this module:

  init_params(key, cfg)                 -> param pytree
  loss_fn(params, cfg, batch)           -> (scalar, metrics)
  forward(params, cfg, batch)           -> (logits, aux)
  prefill(params, cfg, batch)           -> (logits, cache)
  decode_step(params, cfg, state, tokens, pos) -> (logits, state)
  init_decode_state(cfg, batch, max_len)-> ShapeDtypeStruct pytree
  input_specs(cfg, shape)               -> dict[str, ShapeDtypeStruct]
  param_count(cfg, active_only=False)   -> int
  model_flops(cfg, shape)               -> 6*N*D (or 6*N_active*D)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, ShapeConfig
from repro.models import dilated_vgg as DVGG
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import lm as LM

Params = Dict[str, Any]

_LM_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


def _mod(cfg: ModelConfig):
    if cfg.family in _LM_FAMILIES:
        return LM
    if cfg.family in ("encdec", "audio"):
        return ED
    if cfg.family == "convnet":
        return DVGG
    raise ValueError(cfg.family)


def init_params(key, cfg: ModelConfig) -> Params:
    return _mod(cfg).init_params(key, cfg)


def param_shapes(cfg: ModelConfig) -> Params:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def forward(params, cfg: ModelConfig, batch, **kw):
    return _mod(cfg).forward(params, cfg, batch, **kw)


def loss_fn(params, cfg: ModelConfig, batch, **kw):
    return _mod(cfg).loss_fn(params, cfg, batch, **kw)


def prefill(params, cfg: ModelConfig, batch):
    return _mod(cfg).prefill(params, cfg, batch)


def decode_step(params, cfg: ModelConfig, state, tokens, pos):
    return _mod(cfg).decode_step(params, cfg, state, tokens, pos)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    return _mod(cfg).init_decode_state(cfg, batch, max_len)


def allocate_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    spec = init_decode_state(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocates)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for the step function selected by ``shape.mode``.

    train/prefill -> batch dict;  decode -> {tokens, pos, state}.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb_dt = L.dtype_of(cfg.compute_dtype)

    if cfg.family == "convnet":
        net = cfg.convnet
        h, w = net.in_hw
        return {"image": jax.ShapeDtypeStruct((B, h, w, net.in_ch), emb_dt),
                "labels": jax.ShapeDtypeStruct((B, h, w), i32)}

    if cfg.family in ("encdec", "audio"):
        s_enc, s_dec = S // 2, S // 2
        if shape.mode in ("train", "prefill"):
            return {
                "frames": jax.ShapeDtypeStruct((B, s_enc, cfg.d_model), emb_dt),
                "tokens": jax.ShapeDtypeStruct((B, s_dec), i32),
            }
        state = init_decode_state(cfg, B, s_dec)
        return {"tokens": jax.ShapeDtypeStruct((B,), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
                "state": state}

    if shape.mode in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        s_text = S
        if cfg.frontend and cfg.frontend.kind != "none":
            npre = min(cfg.frontend.num_prefix, S // 2)
            s_text = S - npre
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, npre, cfg.d_model), emb_dt)
        batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return batch

    # decode: one new token against a cache of S positions
    state = init_decode_state(cfg, B, S)
    return {"tokens": jax.ShapeDtypeStruct((B,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "state": state}


# ---------------------------------------------------------------------------
# Analytical parameter / FLOP counts
# ---------------------------------------------------------------------------


def _leaf_sizes_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(_leaf_sizes_with_paths(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_leaf_sizes_with_paths(v, f"{prefix}/{i}"))
    else:
        out.append((prefix, int(np.prod(tree.shape))))
    return out


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = 0
    for path, n in _leaf_sizes_with_paths(shapes):
        if active_only and cfg.moe is not None and "ffn_moe/w_" in path:
            # routed experts: only top-k of E are active per token
            n = n * cfg.moe.num_experts_per_tok // cfg.moe.num_experts
        total += n
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for the step.

    train: D = tokens processed (fwd+bwd = 6 N per token)
    prefill: 2 N per token (fwd only)
    decode: 2 N per generated token (D = batch tokens).
    """
    if cfg.family == "convnet":
        return float("nan")
    n_active = param_count(cfg, active_only=True)
    seq = shape.seq_len
    if cfg.family in ("encdec", "audio"):
        # shape convention: S/2 encoder frames + S/2 decoder tokens; each
        # stack (roughly half of N) sees S/2 tokens => N * S/2 overall.
        seq = seq // 2
    tokens = shape.global_batch * (1 if shape.mode == "decode" else seq)
    per_token = 6 * n_active if shape.mode == "train" else 2 * n_active
    return float(per_token) * float(tokens)
