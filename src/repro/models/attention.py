"""Attention mixers: GQA (with RoPE, optional QKV bias) and MLA (DeepSeek-V2).

Cache layouts (per layer):
  gqa: {"k": (B, Hkv, S_max, hd), "v": (B, Hkv, S_max, hd)}
  mla: {"ckv": (B, S_max, kv_lora), "krope": (B, S_max, rope_dim)}
MLA decode uses matrix absorption (q-side W_uk, out-side W_uv) so decode
attends over the *compressed* latent cache — the technique's entire memory
advantage.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import AttentionConfig, ModelConfig
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig) -> Params:
    a = cfg.attention
    dt = L.dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": L.init_linear(k1, d, a.num_heads * a.head_dim, dt, bias=a.qkv_bias),
        "wk": L.init_linear(k2, d, a.num_kv_heads * a.head_dim, dt, bias=a.qkv_bias),
        "wv": L.init_linear(k3, d, a.num_kv_heads * a.head_dim, dt, bias=a.qkv_bias),
        "wo": L.init_linear(k4, a.num_heads * a.head_dim, d, dt),
    }


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    a = cfg.attention
    dt = L.dtype_of(cfg.compute_dtype)
    shp = (batch, a.num_kv_heads, max_len, a.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dt), "v": jax.ShapeDtypeStruct(shp, dt)}


def apply_gqa(p: Params, x: jnp.ndarray, cfg: ModelConfig, *, mode: str,
              cache: Optional[Params] = None, pos=None,
              causal: bool = True) -> Tuple[jnp.ndarray, Optional[Params]]:
    """mode: 'train' | 'prefill' | 'decode'.  x: (B, S, D)."""
    a = cfg.attention
    cd = L.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    H, Hkv, hd = a.num_heads, a.num_kv_heads, a.head_dim

    q = L.linear(p["wq"], x, cd).reshape(B, S, H, hd)
    k = L.linear(p["wk"], x, cd).reshape(B, S, Hkv, hd)
    v = L.linear(p["wv"], x, cd).reshape(B, S, Hkv, hd)

    if mode == "decode":
        positions = jnp.asarray(pos)[None] if jnp.ndim(pos) == 0 else pos
        positions = jnp.broadcast_to(positions.reshape(-1, 1), (B, S))
    else:
        positions = jnp.arange(S)[None, :]
    q = L.apply_rope(q, positions, a.rope_theta)
    k = L.apply_rope(k, positions, a.rope_theta)
    q = q.transpose(0, 2, 1, 3)     # (B,H,S,hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = constrain(q, ("batch", "heads", "seq", None))

    new_cache = None
    if mode == "decode":
        assert cache is not None
        k_c = k.astype(cache["k"].dtype)
        v_c = v.astype(cache["v"].dtype)
        if jnp.ndim(pos) == 0:
            pk = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_c, pos,
                                                     axis=2)
            pv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_c, pos,
                                                     axis=2)
        else:
            # per-slot positions (continuous batching: each slot writes its
            # own cache index) — one update per batch row
            upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice_in_dim(
                c, u, p, axis=1))
            pk = upd(cache["k"], k_c, pos)
            pv = upd(cache["v"], v_c, pos)
        new_cache = {"k": pk, "v": pv}
        pk = constrain(pk, ("batch", "kv_heads", "kv_seq", None))
        pv = constrain(pv, ("batch", "kv_heads", "kv_seq", None))
        kv_len = jnp.broadcast_to(jnp.asarray(pos) + 1, (B,)).astype(jnp.int32)
        out = L.attention(q, pk.astype(cd), pv.astype(cd), causal=False,
                          kv_len=kv_len)
    else:
        out = L.attention(q, k, v, causal=causal)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}

    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    y = L.linear(p["wo"], out, cd)
    return constrain(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Params:
    a = cfg.attention
    dt = L.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim
    p: Params = {}
    if a.q_lora_rank:
        p["wq_a"] = L.init_linear(ks[0], d, a.q_lora_rank, dt)
        p["q_norm"] = L.init_norm(a.q_lora_rank, cfg.norm, dt)
        p["wq_b"] = L.init_linear(ks[1], a.q_lora_rank, a.num_heads * qk_dim, dt)
    else:
        p["wq"] = L.init_linear(ks[0], d, a.num_heads * qk_dim, dt)
    p["wkv_a"] = L.init_linear(ks[2], d, a.kv_lora_rank + a.qk_rope_head_dim, dt)
    p["kv_norm"] = L.init_norm(a.kv_lora_rank, cfg.norm, dt)
    p["wkv_b"] = L.init_linear(
        ks[3], a.kv_lora_rank,
        a.num_heads * (a.qk_nope_head_dim + a.v_head_dim), dt)
    p["wo"] = L.init_linear(ks[4], a.num_heads * a.v_head_dim, d, dt)
    return p


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    a = cfg.attention
    dt = L.dtype_of(cfg.compute_dtype)
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, a.kv_lora_rank), dt),
        "krope": jax.ShapeDtypeStruct((batch, max_len, a.qk_rope_head_dim), dt),
    }


def _mla_q(p: Params, x, a: AttentionConfig, cd) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim
    if "wq_a" in p:
        ql = L.apply_norm(p["q_norm"], L.linear(p["wq_a"], x, cd))
        q = L.linear(p["wq_b"], ql, cd)
    else:
        q = L.linear(p["wq"], x, cd)
    q = q.reshape(B, S, a.num_heads, qk_dim)
    return q[..., :a.qk_nope_head_dim], q[..., a.qk_nope_head_dim:]


def apply_mla(p: Params, x: jnp.ndarray, cfg: ModelConfig, *, mode: str,
              cache: Optional[Params] = None, pos=None,
              causal: bool = True) -> Tuple[jnp.ndarray, Optional[Params]]:
    a = cfg.attention
    cd = L.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    H = a.num_heads
    nope, rope, vdim = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim

    if mode == "decode":
        positions = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1), (B, S))
    else:
        positions = jnp.arange(S)[None, :]

    q_nope, q_rope = _mla_q(p, x, a, cd)
    q_rope = L.apply_rope(q_rope, positions, a.rope_theta)

    kv_a = L.linear(p["wkv_a"], x, cd)
    ckv = L.apply_norm(p["kv_norm"], kv_a[..., :a.kv_lora_rank])
    krope = kv_a[..., a.kv_lora_rank:][:, :, None, :]       # (B,S,1,rope)
    krope = L.apply_rope(krope, positions, a.rope_theta)[:, :, 0, :]

    wkv_b = p["wkv_b"]["w"].astype(cd).reshape(a.kv_lora_rank, H, nope + vdim)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]

    scale = 1.0 / math.sqrt(nope + rope)

    if mode == "decode":
        assert cache is not None and S == 1
        ckv_t = ckv.astype(cache["ckv"].dtype)
        krope_t = krope.astype(cache["krope"].dtype)
        if jnp.ndim(pos) == 0:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv_t, pos, axis=1)
            krope_c = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], krope_t, pos, axis=1)
        else:
            # per-slot positions: one latent-cache update per batch row
            upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice_in_dim(
                c, u, p, axis=0))
            ckv_c = upd(cache["ckv"], ckv_t, pos)
            krope_c = upd(cache["krope"], krope_t, pos)
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        ckv_c = constrain(ckv_c, ("batch", "kv_seq", None))
        # --- absorbed decode over the latent cache ---
        # (f32 accumulation via preferred_element_type; never materialise an
        # f32 copy of the compressed cache)
        q_abs = jnp.einsum("bshn,lhn->bhl", q_nope, w_uk,
                           preferred_element_type=jnp.float32).astype(cd)
        s = jnp.einsum("bhl,btl->bht", q_abs, ckv_c,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bshr,btr->bht", q_rope, krope_c,
                        preferred_element_type=jnp.float32)
        s *= scale
        t_pos = jnp.arange(ckv_c.shape[1])
        pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
        mask = t_pos[None, None, :] <= pos_b[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        probs = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bht,btl->bhl", probs.astype(cd), ckv_c,
                         preferred_element_type=jnp.float32).astype(cd)
        out = jnp.einsum("bhl,lhv->bhv", ctx, w_uv,
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, 1, H * vdim).astype(cd)
    else:
        # --- expanded prefill/train ---
        k_nope = jnp.einsum("btl,lhn->bthn", ckv, w_uk,
                            preferred_element_type=jnp.float32).astype(cd)
        v = jnp.einsum("btl,lhv->bthv", ckv, w_uv,
                       preferred_element_type=jnp.float32).astype(cd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :], (B, S, H, rope))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        q = constrain(q, ("batch", "heads", "seq", None))
        out = L.attention(q, k, v, causal=causal)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vdim)
        new_cache = {"ckv": ckv, "krope": krope} if mode == "prefill" else None

    y = L.linear(p["wo"], out, cd)
    return constrain(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    return init_mla(key, cfg) if cfg.attention.kind == "mla" else init_gqa(key, cfg)


def apply_attention(p, x, cfg, **kw):
    if cfg.attention.kind == "mla":
        return apply_mla(p, x, cfg, **kw)
    return apply_gqa(p, x, cfg, **kw)


def attention_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.attention.kind == "mla":
        return mla_cache_spec(cfg, batch, max_len)
    return gqa_cache_spec(cfg, batch, max_len)
