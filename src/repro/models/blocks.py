"""Residual blocks + scan-over-layers stacking with heterogeneous patterns.

A model is ``prefix blocks + N repetitions of a period`` where a *period* is
the minimal repeating list of (mixer_kind, ffn_kind) layer descriptors:

  qwen/mistral/minitron: period = [("attn", "dense")]
  granite-moe:           period = [("attn", "moe")]
  deepseek-v2:           prefix = [("attn", "dense")], period = [("attn", "moe")]
  rwkv6:                 period = [("rwkv", "rwkv_cm")]
  jamba:                 period of 8: mamba x4, attn@idx4, mamba x3,
                         with MoE on odd indices (16e top-2)

Period parameters are stacked on a leading axis and processed with
``jax.lax.scan`` (bounded compile time for 88-layer models); prefix blocks
are unrolled.  Remat (``jax.checkpoint``) wraps the period body in training.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import rwkv6 as R6
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Pattern
# ---------------------------------------------------------------------------


def layer_descriptors(cfg: ModelConfig) -> List[Tuple[str, str]]:
    mixers = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    out = []
    for m, f in zip(mixers, ffns):
        if m == "rwkv":
            f = "rwkv_cm"
        out.append((m, f))
    return out


def block_pattern(cfg: ModelConfig) -> Tuple[List, List, int]:
    """Returns (prefix_descriptors, period_descriptors, n_periods)."""
    desc = layer_descriptors(cfg)
    n_prefix = cfg.moe.first_k_dense if cfg.moe else 0
    prefix, rest = desc[:n_prefix], desc[n_prefix:]
    n = len(rest)
    for p in range(1, n + 1):
        if n % p == 0 and rest == rest[:p] * (n // p):
            return prefix, rest[:p], n // p
    return prefix, rest, 1


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, mixer: str, ffn: str) -> Params:
    dt = L.dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg.d_model, cfg.norm, dt)}
    if mixer == "attn":
        p["attn"] = ATT.init_attention(k1, cfg)
    elif mixer == "ssm":
        p["ssm"] = SSM.init_ssm(k1, cfg)
    elif mixer == "rwkv":
        p["rwkv_tm"] = R6.init_time_mix(k1, cfg)
    else:
        raise ValueError(mixer)
    p["norm2"] = L.init_norm(cfg.d_model, cfg.norm, dt)
    if ffn == "dense":
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense)
                else cfg.d_ff)
        p["ffn"] = L.init_ffn(k2, cfg.d_model, d_ff, cfg.act, dt)
    elif ffn == "moe":
        p["ffn_moe"] = L.init_moe(k3, cfg, dt)
    elif ffn == "rwkv_cm":
        p["rwkv_cm"] = R6.init_channel_mix(k4, cfg)
    else:
        raise ValueError(ffn)
    return p


def block_cache_spec(cfg: ModelConfig, mixer: str, ffn: str,
                     batch: int, max_len: int) -> Params:
    spec: Params = {}
    if mixer == "attn":
        spec["attn"] = ATT.attention_cache_spec(cfg, batch, max_len)
    elif mixer == "ssm":
        spec["ssm"] = SSM.ssm_cache_spec(cfg, batch)
    elif mixer == "rwkv":
        # includes shift_t (time-mix), shift_c (channel-mix) and wkv state
        spec["rwkv_tm"] = R6.rwkv_cache_spec(cfg, batch)
    return spec


def apply_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                mixer: str, ffn: str, *, mode: str,
                cache: Optional[Params] = None, pos=None,
                causal: bool = True,
                ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    cd = L.dtype_of(cfg.compute_dtype)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Params = {}

    h = L.apply_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        y, c = ATT.apply_attention(p["attn"], h, cfg, mode=mode,
                                   cache=None if cache is None else cache["attn"],
                                   pos=pos, causal=causal)
        if c is not None:
            new_cache["attn"] = c
    elif mixer == "ssm":
        y, c = SSM.apply_ssm(p["ssm"], h, cfg, mode=mode,
                             cache=None if cache is None else cache["ssm"],
                             pos=pos)
        if c is not None:
            new_cache["ssm"] = c
    else:  # rwkv time mix
        y, c = R6.apply_time_mix(p["rwkv_tm"], h, cfg, mode=mode,
                                 cache=None if cache is None else cache["rwkv_tm"])
        if c is not None:
            new_cache["rwkv_tm"] = c
    x = x + y.astype(x.dtype)

    h = L.apply_norm(p["norm2"], x, cfg.norm_eps)
    if ffn == "dense":
        y = L.apply_ffn(p["ffn"], h, cfg.act, cd)
    elif ffn == "moe":
        y, aux = L.apply_moe(p["ffn_moe"], h, cfg, compute_dtype=cd)
    else:  # rwkv channel mix
        y, c = R6.apply_channel_mix(p["rwkv_cm"], h, cfg, mode=mode,
                                    cache=None if cache is None else cache["rwkv_tm"])
        if c is not None:
            new_cache.setdefault("rwkv_tm", {}).update(c)
    x = x + y.astype(x.dtype)
    return x, (new_cache if new_cache else None), aux


# ---------------------------------------------------------------------------
# Stack (prefix + scanned periods)
# ---------------------------------------------------------------------------


def init_stack(key, cfg: ModelConfig) -> Params:
    prefix, period, n_periods = block_pattern(cfg)
    kp, ks = jax.random.split(key)
    params: Params = {}
    if prefix:
        pkeys = jax.random.split(kp, len(prefix))
        params["prefix"] = {
            f"blk{i}": init_block(pkeys[i], cfg, m, f)
            for i, (m, f) in enumerate(prefix)
        }

    def init_period(k):
        keys = jax.random.split(k, len(period))
        return {f"sub{j}": init_block(keys[j], cfg, m, f)
                for j, (m, f) in enumerate(period)}

    params["periods"] = jax.vmap(init_period)(jax.random.split(ks, n_periods))
    return params


def stack_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    prefix, period, n_periods = block_pattern(cfg)
    spec: Params = {}
    if prefix:
        spec["prefix"] = {
            f"blk{i}": block_cache_spec(cfg, m, f, batch, max_len)
            for i, (m, f) in enumerate(prefix)
        }
    per = {f"sub{j}": block_cache_spec(cfg, m, f, batch, max_len)
           for j, (m, f) in enumerate(period)}
    spec["periods"] = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype), per)
    return spec


def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def apply_stack(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                mode: str, cache: Optional[Params] = None, pos=None,
                causal: bool = True, remat: str = "dots",
                ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Run prefix blocks then the scanned periods.

    Returns (x, new_cache (same structure as cache, or None), total_aux).
    """
    prefix, period, n_periods = block_pattern(cfg)
    total_aux = jnp.zeros((), jnp.float32)
    new_cache: Params = {}

    if prefix:
        pc = {}
        for i, (m, f) in enumerate(prefix):
            blk = params["prefix"][f"blk{i}"]
            c_in = None if cache is None else cache["prefix"][f"blk{i}"]
            x, c, aux = apply_block(blk, x, cfg, m, f, mode=mode,
                                    cache=c_in, pos=pos, causal=causal)
            total_aux += aux
            if c is not None:
                pc[f"blk{i}"] = c
        if pc:
            new_cache["prefix"] = pc

    def period_fn(x, scanned):
        p_params, p_cache = scanned
        caches_out = {}
        aux_sum = jnp.zeros((), jnp.float32)
        for j, (m, f) in enumerate(period):
            c_in = None if p_cache is None else p_cache[f"sub{j}"]
            x, c, aux = apply_block(p_params[f"sub{j}"], x, cfg, m, f,
                                    mode=mode, cache=c_in, pos=pos,
                                    causal=causal)
            aux_sum += aux
            if c is not None:
                caches_out[f"sub{j}"] = c
        return x, (caches_out if caches_out else None, aux_sum)

    body = _remat_wrap(period_fn, remat if mode == "train" else "none")
    xs = (params["periods"], cache["periods"] if cache is not None else None)
    x, (period_caches, auxes) = jax.lax.scan(body, x, xs)
    total_aux += jnp.sum(auxes)
    if period_caches is not None:
        new_cache["periods"] = period_caches
    return x, (new_cache if new_cache else None), total_aux
