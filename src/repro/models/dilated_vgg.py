"""DilatedVGG (Yu & Koltun 2015, as used by the paper's FPGA prototype).

VGG-16-style front end with the pool4/pool5 stages removed and dilation
introduced instead, a 'dense1' 1x1 stage, and bilinear upscaling — matching
the layer names in the paper's Figures 5-7 (conv1_1 ... conv4_5, Dense1,
Upscaling).  Functional jnp implementation for smoke tests; the AVSM task
graph is generated from the same ConvNetConfig (repro.core.taskgraph).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ConvLayerConfig, ConvNetConfig, ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def init_params(key, cfg: ModelConfig) -> Params:
    net = cfg.convnet
    dt = L.dtype_of(cfg.param_dtype)
    p: Params = {}
    keys = jax.random.split(key, len(net.layers))
    for k, lay in zip(keys, net.layers):
        if lay.kind in ("conv", "dense"):
            fan_in = lay.kernel * lay.kernel * lay.in_ch
            p[lay.name] = {
                "w": (jax.random.normal(k, (lay.kernel, lay.kernel,
                                            lay.in_ch, lay.out_ch))
                      * (2.0 / fan_in) ** 0.5).astype(dt),
                "b": jnp.zeros((lay.out_ch,), dt),
            }
    return p


def _conv(x, w, b, stride: int, dilation: int):
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype),
        window_strides=(stride, stride),
        padding="SAME",
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            **_) -> Tuple[jnp.ndarray, jnp.ndarray]:
    net = cfg.convnet
    x = batch["image"].astype(L.dtype_of(cfg.compute_dtype))
    for lay in net.layers:
        if lay.kind in ("conv", "dense"):
            x = _conv(x, p[lay.name]["w"], p[lay.name]["b"],
                      lay.stride, lay.dilation)
            x = jax.nn.relu(x.astype(jnp.float32)).astype(x.dtype)
        elif lay.kind == "pool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, lay.kernel, lay.kernel, 1),
                (1, lay.stride, lay.stride, 1), "SAME")
        elif lay.kind == "upsample":
            B, H, W, C = x.shape
            x = jax.image.resize(x, (B, H * lay.stride, W * lay.stride, C),
                                 "bilinear").astype(x.dtype)
        else:
            raise ValueError(lay.kind)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(p: Params, cfg: ModelConfig, batch, **_):
    logits, aux = forward(p, cfg, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "aux": aux, "total": loss}
