"""Encoder-decoder transformer (seamless-m4t backbone: audio family).

Encoder consumes precomputed frame embeddings (modality frontend is a STUB
per the assignment) through bidirectional attention blocks; the decoder is a
causal LM stack whose blocks are augmented with cross-attention over the
encoder output.  Decode shapes lower ``serve_step`` on the decoder.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import attention as ATT
from repro.models import blocks as B
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


def _cross_init(key, cfg: ModelConfig) -> Params:
    dt = L.dtype_of(cfg.param_dtype)
    p = ATT.init_gqa(key, cfg)
    p["norm"] = L.init_norm(cfg.d_model, cfg.norm, dt)
    return p


def _cross_apply(p: Params, x: jnp.ndarray, kv: Tuple[jnp.ndarray, jnp.ndarray],
                 cfg: ModelConfig) -> jnp.ndarray:
    """Cross attention; kv = (k, v) precomputed from encoder output."""
    a = cfg.attention
    cd = L.dtype_of(cfg.compute_dtype)
    B_, S, _ = x.shape
    h = L.apply_norm(p["norm"], x, cfg.norm_eps)
    q = L.linear(p["wq"], h, cd).reshape(B_, S, a.num_heads, a.head_dim)
    q = q.transpose(0, 2, 1, 3)
    k, v = kv
    out = L.attention(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(B_, S, a.num_heads * a.head_dim)
    return x + L.linear(p["wo"], out, cd).astype(x.dtype)


def cross_kv(p: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    a = cfg.attention
    cd = L.dtype_of(cfg.compute_dtype)
    B_, S, _ = enc_out.shape
    k = L.linear(p["wk"], enc_out, cd).reshape(B_, S, a.num_kv_heads, a.head_dim)
    v = L.linear(p["wv"], enc_out, cd).reshape(B_, S, a.num_kv_heads, a.head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = L.dtype_of(cfg.param_dtype)
    enc_cfg = cfg  # same width/heads per the assigned config
    n_enc = cfg.encoder_layers or cfg.num_layers

    def enc_block(k):
        return B.init_block(k, cfg, "attn", "dense")

    def dec_block(k):
        p = B.init_block(k, cfg, "attn", "dense")
        p["cross"] = _cross_init(jax.random.fold_in(k, 7), cfg)
        return p

    return {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "enc_in_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        "encoder": jax.vmap(enc_block)(jax.random.split(ks[1], n_enc)),
        "enc_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        "decoder": jax.vmap(dec_block)(jax.random.split(ks[2], cfg.num_layers)),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
        "lm_head": L.init_linear(ks[3], cfg.d_model, cfg.vocab_size, dt),
    }


def encode(p: Params, cfg: ModelConfig, frames: jnp.ndarray,
           remat: str = "dots") -> jnp.ndarray:
    cd = L.dtype_of(cfg.compute_dtype)
    x = L.apply_norm(p["enc_in_norm"], frames.astype(cd), cfg.norm_eps)
    x = constrain(x, ("batch", "seq", "embed"))

    def body(x, blk):
        x, _, _ = B.apply_block(blk, x, cfg, "attn", "dense",
                                mode="train", causal=False)
        return x, None

    body_fn = B._remat_wrap(body, remat)
    x, _ = jax.lax.scan(body_fn, x, p["encoder"])
    return L.apply_norm(p["enc_norm"], x, cfg.norm_eps)


def _decode_stack(p: Params, cfg: ModelConfig, x, enc_out, *, mode: str,
                  cache=None, pos=None, remat: str = "dots"):
    """Decoder stack with cross-attention; returns (x, new_cache)."""

    def body(carry, scanned):
        x = carry
        blk, blk_cache = scanned
        c_in = None if blk_cache is None else blk_cache
        x, c, _ = B.apply_block(blk, x, cfg, "attn", "dense", mode=mode,
                                cache=c_in, pos=pos, causal=True)
        kv = cross_kv(blk["cross"], enc_out, cfg)
        x = _cross_apply(blk["cross"], x, kv, cfg)
        return x, c

    body_fn = B._remat_wrap(body, remat if mode == "train" else "none")
    x, caches = jax.lax.scan(body_fn, x, (p["decoder"], cache))
    return x, caches


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            mode: str = "train", remat: str = "dots"):
    cd = L.dtype_of(cfg.compute_dtype)
    enc_out = encode(p, cfg, batch["frames"], remat)
    x = L.embed(p["embed"], batch["tokens"], cd)
    x, _ = _decode_stack(p, cfg, x, enc_out, mode="train", remat=remat)
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("...d,dv->...v", x.astype(cd),
                        p["lm_head"]["w"].astype(cd),
                        preferred_element_type=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(p: Params, cfg: ModelConfig, batch, *, remat: str = "dots"):
    logits, aux = forward(p, cfg, batch, remat=remat)
    targets = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "aux": aux, "total": loss}


# ---------------------------------------------------------------------------
# Serving: cache = {"self": stacked kv cache, "cross_kv": precomputed,}
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    a = cfg.attention
    cd = L.dtype_of(cfg.compute_dtype)
    n_dec = cfg.num_layers
    enc_len = max_len  # encoder context as long as decoder history
    self_spec = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_dec,) + s.shape, s.dtype),
        ATT.gqa_cache_spec(cfg, batch, max_len))
    kv_shape = (n_dec, batch, a.num_kv_heads, enc_len, a.head_dim)
    return {
        "self": self_spec,
        "cross_k": jax.ShapeDtypeStruct(kv_shape, cd),
        "cross_v": jax.ShapeDtypeStruct(kv_shape, cd),
    }


def prefill(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    cd = L.dtype_of(cfg.compute_dtype)
    enc_out = encode(p, cfg, batch["frames"], remat="none")
    x = L.embed(p["embed"], batch["tokens"], cd)

    def body(x, blk):
        x, c, _ = B.apply_block(blk, x, cfg, "attn", "dense",
                                mode="prefill", causal=True)
        kv = cross_kv(blk["cross"], enc_out, cfg)
        x = _cross_apply(blk["cross"], x, kv, cfg)
        return x, (c["attn"], kv)

    x, (self_caches, cross_kvs) = jax.lax.scan(body, x, p["decoder"])
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(cd),
                        p["lm_head"]["w"].astype(cd),
                        preferred_element_type=jnp.float32)
    state = {"self": self_caches,
             "cross_k": cross_kvs[0], "cross_v": cross_kvs[1]}
    return logits, state


def decode_step(p: Params, cfg: ModelConfig, state: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    cd = L.dtype_of(cfg.compute_dtype)
    x = L.embed(p["embed"], tokens[:, None], cd)

    def body(x, scanned):
        blk, self_c, ck, cv = scanned
        x, c, _ = B.apply_block(blk, x, cfg, "attn", "dense", mode="decode",
                                cache={"attn": self_c}, pos=pos, causal=True)
        x = _cross_apply(blk["cross"], x, (ck, cv), cfg)
        return x, c["attn"]

    x, self_caches = jax.lax.scan(
        body, x, (p["decoder"], state["self"], state["cross_k"],
                  state["cross_v"]))
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(cd),
                        p["lm_head"]["w"].astype(cd),
                        preferred_element_type=jnp.float32)
    new_state = dict(state)
    new_state["self"] = self_caches
    return logits, new_state
