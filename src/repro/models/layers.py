"""Common model building blocks (pure JAX, functional, scan-friendly).

Parameters are plain nested dicts of jnp arrays.  Every init function has a
matching apply function.  Projections are stored as 2-D ``(d_in, d_out)``
matrices (stacked to ``(L, d_in, d_out)`` by the scan-over-layers wrappers),
which keeps the sharding rules uniform (see ``repro.sharding``).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import AttentionConfig, MoEConfig, ModelConfig

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


import os as _os

# Cast matmul outputs to compute dtype BEFORE GSPMD's cross-shard
# partial-sum reduction: keeps the Megatron-TP all-reduce payload in bf16,
# not f32 (2x ICI traffic).  Beyond-paper optimisation; toggle for A/B in
# the perf loop (REPRO_BF16_AR=0 restores the f32-reduce baseline).
CAST_BEFORE_REDUCE = _os.environ.get("REPRO_BF16_AR", "1") != "0"


def linear(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    # With CAST_BEFORE_REDUCE the dot's *output* dtype is the compute dtype,
    # so GSPMD's cross-shard partial-sum all-reduce runs on bf16 payloads
    # (TPU MXU still accumulates in f32 internally; only the cross-shard
    # reduce is rounded — standard Megatron practice).  A separate
    # cast-after-dot cannot achieve this: GSPMD reduces at the dot output.
    pref = compute_dtype if CAST_BEFORE_REDUCE else jnp.float32
    y = jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                   p["w"].astype(compute_dtype),
                   preferred_element_type=pref)
    if "b" in p:
        y = (y.astype(jnp.float32) + p["b"].astype(jnp.float32))
    return y.astype(compute_dtype)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — online-softmax chunked dot-product attention.
#
# This is the XLA-native twin of the Pallas flash-attention kernel
# (repro/kernels/flash_attention): O(S * chunk) live memory instead of
# O(S^2), numerically identical to full softmax attention.  The dry-run and
# CPU tests use this path; on real TPU the Pallas kernel replaces it
# (cfg-level switch in repro.models.api).
# ---------------------------------------------------------------------------


def _attn_block(q, k, v, mask, scale):
    """One (q-chunk x kv-chunk) block. q:(B,H,Tq,hd) k,v:(B,H,Tk,hd)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -jnp.inf)
    return s


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool, q_offset: int = 0,
                      chunk_q: int = 512, chunk_k: int = 1024,
                      kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Hq, Sq, hd);  k, v: (B, Hkv, Sk, hd) with Hq % Hkv == 0 (GQA).
    ``q_offset``: absolute position of q[0] (for decode / chunked prefill).
    ``kv_len``: optional (B,) actual kv lengths (decode with ragged cache).
    Returns (B, Hq, Sq, hd) in q.dtype.
    """
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    vd = v.shape[-1]
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    # broadcast kv heads to q heads (XLA fuses this; no materialised copy
    # thanks to the einsum below operating per kv-head group)
    qg = q.reshape(B, Hkv, group, Sq, hd)

    chunk_q = min(chunk_q, Sq)
    chunk_k = min(chunk_k, Sk)
    nq, nk = -(-Sq // chunk_q), -(-Sk // chunk_k)
    # pad to multiples
    q_pad = nq * chunk_q - Sq
    k_pad = nk * chunk_k - Sk
    if q_pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))

    q_pos = q_offset + jnp.arange(nq * chunk_q)
    k_pos = jnp.arange(nk * chunk_k)
    kv_valid_len = Sk if kv_len is None else kv_len  # scalar or (B,)

    @jax.checkpoint
    def kv_step(carry, kc):
        # remat: never save the (.., Sq, chunk_k) score/probability blocks —
        # that would reconstitute the full S^2 attention matrix in HBM.
        acc, m, denom = carry      # acc:(B,Hkv,g,Sq',hd) m,denom:(B,Hkv,g,Sq',1)
        ks = jax.lax.dynamic_slice_in_dim(k, kc * chunk_k, chunk_k, axis=2)
        vs = jax.lax.dynamic_slice_in_dim(v, kc * chunk_k, chunk_k, axis=2)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, kc * chunk_k, chunk_k, axis=0)
        # f32 accumulation WITHOUT materialising f32 operand copies
        s = jnp.einsum("bngqd,bnkd->bngqk", qg, ks,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask = q_pos[None, None, None, :, None] >= kp[None, None, None, None, :]
        if kv_len is not None:
            vl = jnp.asarray(kv_valid_len).reshape(B, 1, 1, 1, 1)
            mask = mask & (kp[None, None, None, None, :] < vl)
        elif k_pad:
            mask = mask & (kp[None, None, None, None, :] < Sk)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        # guard rows where everything is masked (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        denom_new = denom * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bngqk,bnkd->bngqd", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr + pv
        return (acc_new, m_new, denom_new), None

    acc0 = jnp.zeros((B, Hkv, group, nq * chunk_q, vd), jnp.float32)
    m0 = jnp.full((B, Hkv, group, nq * chunk_q, 1), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, Hkv, group, nq * chunk_q, 1), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0),
                                      jnp.arange(nk))
    out = acc / jnp.maximum(denom, 1e-30)
    out = out.reshape(B, Hq, nq * chunk_q, vd)[:, :, :Sq]
    return out.astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                   kv_len=None) -> jnp.ndarray:
    """Reference full-materialisation attention (small shapes only)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    vd = v.shape[-1]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, hd)
    s = jnp.einsum("bngqd,bnkd->bngqk", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    mask = mask[None, None, None]
    if kv_len is not None:
        vl = jnp.asarray(kv_len).reshape(B, 1, 1, 1, 1)
        mask = mask & (k_pos[None, None, None, None, :] < vl)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bngqk,bnkd->bngqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, Sq, vd).astype(q.dtype)


def attention(q, k, v, *, causal: bool, q_offset: int = 0, kv_len=None,
              chunked_threshold: int = 1024) -> jnp.ndarray:
    """Dispatch: full softmax for short sequences, online-softmax otherwise."""
    if q.shape[2] * k.shape[2] <= chunked_threshold ** 2:
        return full_attention(q, k, v, causal=causal, q_offset=q_offset,
                              kv_len=kv_len)
    return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                             kv_len=kv_len)


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": init_linear(k1, d_model, d_ff, dtype),
         "w_down": init_linear(k2, d_ff, d_model, dtype)}
    if act == "swiglu":
        p["w_gate"] = init_linear(k3, d_model, d_ff, dtype)
    return p


def apply_ffn(p: Params, x: jnp.ndarray, act: str,
              compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    h = linear(p["w_up"], x, compute_dtype)
    if act == "swiglu":
        g = linear(p["w_gate"], x, compute_dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * h
    elif act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(compute_dtype)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(compute_dtype)
    else:
        raise ValueError(act)
    return linear(p["w_down"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts — grouped, capacity-based, einsum dispatch/combine.
#
# The (group, seq, expert, capacity) dispatch tensors reshard under GSPMD
# into all-to-alls when experts live on the "model" mesh axis (expert
# parallelism); see repro.sharding.  Dropped tokens (over capacity) simply
# contribute zero, standard Switch/T5X semantics.
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    keys = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    scale_in = 1.0 / math.sqrt(d)
    p = {
        "router": init_linear(keys[0], d, e, jnp.float32, scale=scale_in),
        "w_up": (jax.random.normal(keys[1], (e, d, f)) * scale_in).astype(dtype),
        "w_gate": (jax.random.normal(keys[2], (e, d, f)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(keys[3], (e, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if m.num_shared_experts:
        f_sh = m.d_ff_shared or f * m.num_shared_experts
        p["shared"] = init_ffn(keys[4], d, f_sh, "swiglu", dtype)
    return p


def moe_capacity(seq: int, num_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = int(math.ceil(seq * top_k / num_experts * capacity_factor))
    return max(4, min(c, seq * top_k))


MOE_GROUP_SIZE = 4096   # routing-group tokens; capacity scales with the
#                         group, NOT the sequence — otherwise the one-hot
#                         dispatch einsum cost grows as S^2 (32k prefill
#                         made dispatch 10-50x the expert FLOPs)


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              capacity_factor: Optional[float] = None,
              compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (G, S, D) groups of tokens. Returns (out, aux_loss)."""
    m = cfg.moe
    G0, S0, D = x.shape
    # re-group long sequences into fixed-size routing groups
    if S0 > MOE_GROUP_SIZE and S0 % MOE_GROUP_SIZE == 0:
        x = x.reshape(G0 * (S0 // MOE_GROUP_SIZE), MOE_GROUP_SIZE, D)
    G, S, D = x.shape
    E, K = m.num_experts, m.num_experts_per_tok
    cf = m.capacity_factor if capacity_factor is None else capacity_factor
    if cf <= 0:
        C = S * K                      # dropless
    else:
        C = moe_capacity(S, E, K, cf)

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (G,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # (G,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # expert one-hot per choice: (G,S,K,E)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert queue
    # priority: earlier tokens first, then earlier choices
    flat = onehot.reshape(G, S * K, E)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0                   # (G,S*K,E)
    pos = pos.reshape(G, S, K, E)
    within_cap = (pos >= 0) & (pos < C)
    pos = jnp.clip(pos, 0, C - 1).astype(jnp.int32)

    # dispatch one-hot over capacity: (G,S,K,E,C) -> reduce over K
    cap_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * \
        within_cap[..., None] * onehot[..., None]
    dispatch = jnp.sum(cap_oh, axis=2)                            # (G,S,E,C)
    combine = jnp.sum(cap_oh * gate_vals[..., None, None], axis=2)

    from repro.sharding import constrain  # local import avoids cycle

    dispatch = constrain(dispatch, ("batch", None, "expert", None))
    combine = constrain(combine, ("batch", None, "expert", None))
    # expert parallelism: the (E, G, C, *) tensors live expert-sharded on the
    # model axis; GSPMD inserts the dispatch/combine all-to-alls here.
    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(compute_dtype),
                    x.astype(compute_dtype),
                    preferred_element_type=jnp.float32).astype(compute_dtype)
    xe = constrain(xe, ("expert", "batch", None, None))
    up = jnp.einsum("egcd,edf->egcf", xe, p["w_up"].astype(compute_dtype),
                    preferred_element_type=jnp.float32)
    gate = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"].astype(compute_dtype),
                      preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(compute_dtype)
    h = constrain(h, ("expert", "batch", None, None))
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(compute_dtype),
                    preferred_element_type=jnp.float32).astype(compute_dtype)
    ye = constrain(ye, ("expert", "batch", None, None))
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(compute_dtype), ye,
                   preferred_element_type=jnp.float32).astype(compute_dtype)

    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, "swiglu", compute_dtype)
    if (G, S) != (G0, S0):
        y = y.reshape(G0, S0, D)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    density = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))      # (E,)
    router_prob = jnp.mean(probs, axis=(0, 1))                    # (E,)
    aux = E * jnp.sum(density / K * router_prob)
    return y, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def logits_from_embedding(p: Params, x: jnp.ndarray, softcap: float = 0.0,
                          compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    y = jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                   p["table"].astype(compute_dtype),
                   preferred_element_type=jnp.float32)
    if softcap:
        y = jnp.tanh(y / softcap) * softcap
    return y
