"""Decoder-only language model (dense / MoE / SSM / RWKV / hybrid / VLM).

Public surface (used by repro.models.api):
  init_params, forward, loss_fn, init_decode_state, prefill, decode_step
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = L.dtype_of(cfg.param_dtype)
    p: Params = {
        "embed": L.init_embedding(k1, cfg.vocab_size, cfg.d_model, dt),
        "stack": B.init_stack(k2, cfg),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(k3, cfg.d_model, cfg.vocab_size, dt)
    return p


def _head(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    cd = L.dtype_of(cfg.compute_dtype)
    if cfg.tie_embeddings:
        logits = L.logits_from_embedding(p["embed"], x, cfg.logit_softcap, cd)
    else:
        logits = jnp.einsum("...d,dv->...v", x.astype(cd),
                            p["lm_head"]["w"].astype(cd),
                            preferred_element_type=jnp.float32)
        if cfg.logit_softcap:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, ("batch", "seq", "vocab"))


def _embed_inputs(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  ) -> jnp.ndarray:
    cd = L.dtype_of(cfg.compute_dtype)
    x = L.embed(p["embed"], batch["tokens"], cd)
    if cfg.frontend and cfg.frontend.kind != "none" and "prefix_embeds" in batch:
        # modality frontend STUB: precomputed patch/frame embeddings
        pre = batch["prefix_embeds"].astype(cd)
        x = jnp.concatenate([pre, x], axis=1)
    return constrain(x, ("batch", "seq", "embed"))


def forward(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            mode: str = "train", remat: str = "dots",
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x = _embed_inputs(p, cfg, batch)
    x, _, aux = B.apply_stack(p["stack"], x, cfg, mode="train", remat=remat)
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    return _head(p, x, cfg), aux


def hidden_states(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  *, remat: str = "dots") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Final-norm hidden states (pre-head). Returns (h, aux)."""
    x = _embed_inputs(p, cfg, batch)
    x, _, aux = B.apply_stack(p["stack"], x, cfg, mode="train", remat=remat)
    return L.apply_norm(p["final_norm"], x, cfg.norm_eps), aux


def chunked_xent(p: Params, cfg: ModelConfig, h: jnp.ndarray,
                 targets: jnp.ndarray, mask: Optional[jnp.ndarray] = None,
                 chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy without materialising full (B,S,V) logits: scan over
    sequence chunks, computing head projection + log-softmax per chunk."""
    Bz, S, D = h.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    hf = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    tf = jnp.pad(targets, ((0, 0), (0, pad)))
    mf = jnp.ones((Bz, S), jnp.float32) if mask is None else \
        mask.astype(jnp.float32)
    mf = jnp.pad(mf, ((0, 0), (0, pad)))
    hf = hf.reshape(Bz, nc, chunk, D).transpose(1, 0, 2, 3)
    tf = tf.reshape(Bz, nc, chunk).transpose(1, 0, 2)
    mf = mf.reshape(Bz, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(carry, inp):
        hc, tc, mc = inp
        logits = _head(p, hc, cfg).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return (carry[0] + jnp.sum(nll * mc), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hf, tf, mf))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            remat: str = "dots") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux); chunked head+xent keeps the
    (B,S,V) logits tensor out of memory."""
    h, aux = hidden_states(p, cfg, batch, remat=remat)
    n_prefix = h.shape[1] - batch["tokens"].shape[1]
    if n_prefix > 0:
        h = h[:, n_prefix:]
    targets = batch["tokens"][:, 1:]
    mask = batch.get("loss_mask")
    loss = chunked_xent(p, cfg, h[:, :-1], targets,
                        None if mask is None else mask[:, 1:])
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    total = loss + aux_coef * aux
    return total, {"loss": loss, "aux": aux, "total": total}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """ShapeDtypeStruct pytree for the decode cache (allocate with zeros)."""
    return B.stack_cache_spec(cfg, batch, max_len)


def allocate_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    spec = init_decode_state(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def prefill(p: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            ) -> Tuple[jnp.ndarray, Params]:
    """Process the full prompt; returns (last-position logits, cache).

    The returned attention caches hold exactly the prompt (S positions);
    callers growing beyond S must allocate larger caches up front by padding
    the prompt (standard bucket serving).
    """
    x = _embed_inputs(p, cfg, batch)
    x, cache, _ = B.apply_stack(p["stack"], x, cfg, mode="prefill",
                                remat="none")
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    logits = _head(p, x[:, -1:], cfg)
    return logits, cache


def decode_step(p: Params, cfg: ModelConfig, state: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step.  tokens: (B,) int32; pos: scalar or per-slot (B,)
    int32 (cache write index; attention attends to [0, pos], per slot when
    a vector — continuous batching).  Returns (logits (B,V), state)."""
    cd = L.dtype_of(cfg.compute_dtype)
    x = L.embed(p["embed"], tokens[:, None], cd)
    x = constrain(x, ("batch", None, "embed"))
    x, new_cache, _ = B.apply_stack(p["stack"], x, cfg, mode="decode",
                                    cache=state, pos=pos, remat="none")
    x = L.apply_norm(p["final_norm"], x, cfg.norm_eps)
    logits = _head(p, x, cfg)[:, 0]
    return logits, new_cache
