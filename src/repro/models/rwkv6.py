"""RWKV-6 ("Finch", arXiv:2404.05892) time-mix and channel-mix blocks.

Attention-free: the WKV recurrence keeps a per-head (d_k x d_v) state with
*data-dependent per-channel decay*.  Sequence processing uses a chunked
formulation (scan over chunks, closed-form intra-chunk contribution) that is
numerically safe: every exponent is a *difference* of cumulative log-decays
within one chunk, hence <= 0.  The Pallas kernel (repro/kernels/rwkv6_scan)
implements the same chunking; this file is the XLA twin / reference.

Cache layout (decode):
  {"shift_t": (B, D), "shift_c": (B, D), "wkv": (B, H, dk, dv) f32}
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]

STREAMS = ("w", "k", "v", "r", "g")


def num_heads_of(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv.head_dim


def init_time_mix(key, cfg: ModelConfig) -> Params:
    r = cfg.rwkv
    dt = L.dtype_of(cfg.param_dtype)
    d = cfg.d_model
    H, hd = num_heads_of(cfg), r.head_dim
    ks = jax.random.split(key, 16)
    p: Params = {
        "mu_base": (jax.random.uniform(ks[0], (d,)) * 0.1).astype(dt),
        "lora_base_a": (jax.random.normal(ks[1], (d, r.mix_lora * 5)) * 0.01).astype(dt),
        "lora_base_b": (jax.random.normal(ks[2], (5, r.mix_lora, d)) * 0.01).astype(dt),
        "w0": (-6.0 + jax.random.uniform(ks[3], (d,)) * 2.0).astype(jnp.float32),
        "w_lora_a": (jax.random.normal(ks[4], (d, r.decay_lora)) * 0.01).astype(dt),
        "w_lora_b": (jax.random.normal(ks[5], (r.decay_lora, d)) * 0.01).astype(dt),
        "u": (jax.random.normal(ks[6], (H, hd)) * 0.1).astype(jnp.float32),
        "wr": L.init_linear(ks[7], d, d, dt),
        "wk": L.init_linear(ks[8], d, d, dt),
        "wv": L.init_linear(ks[9], d, d, dt),
        "wg": L.init_linear(ks[10], d, d, dt),
        "wo": L.init_linear(ks[11], d, d, dt),
        "ln_x": L.init_norm(d, "layernorm", jnp.float32),
    }
    for i, s in enumerate(STREAMS):
        p[f"mu_{s}"] = (jax.random.uniform(ks[12 + i % 4], (d,)) * 0.1).astype(dt)
    return p


def rwkv_cache_spec(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    d = cfg.d_model
    H, hd = num_heads_of(cfg), cfg.rwkv.head_dim
    return {
        "shift_t": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "shift_c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "wkv": jax.ShapeDtypeStruct((batch, H, hd, hd), jnp.float32),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Return x_{t-1} stream. x: (B,S,D); prev: (B,D) last token of context."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :].astype(x.dtype)
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(x.dtype))
    return shifted


def _ddlerp(p: Params, x: jnp.ndarray, xx: jnp.ndarray, cd) -> Dict[str, jnp.ndarray]:
    """Data-dependent lerp producing the five mixed streams."""
    base = x + xx * (p["mu_base"].astype(cd))
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base,
                               p["lora_base_a"].astype(cd)))
    R = p["lora_base_b"].shape[1]
    out = {}
    for i, s in enumerate(STREAMS):
        li = lora[..., i * R:(i + 1) * R] if lora.shape[-1] == 5 * R else lora
        delta = jnp.einsum("bsr,rd->bsd", li, p["lora_base_b"][i].astype(cd))
        out[s] = x + xx * (p[f"mu_{s}"].astype(cd) + delta)
    return out


def wkv_chunked(r, k, v, logw, u, state0, chunk: int = 16):
    """Chunked WKV recurrence.

    r,k,v: (B,H,S,hd);  logw: (B,H,S,hd) per-channel log-decay (<0);
    u: (H,hd) bonus;  state0: (B,H,hd,hd) or None.
    Returns (out: (B,H,S,hd), state: (B,H,hd,hd)).  All f32.
    """
    B, H, S, hd = r.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S

    def pf(x, val=0.0):
        return jnp.pad(x.astype(jnp.float32),
                       ((0, 0), (0, 0), (0, pad), (0, 0)),
                       constant_values=val)

    rf, kf, vf = pf(r), pf(k), pf(v)
    lw = pf(logw)  # padded decays log(1)=0 -> harmless (k,v are 0 there)
    rf = rf.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    kf = kf.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vf = vf.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    lw = lw.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)

    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32)
          if state0 is None else state0.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    @jax.checkpoint
    def chunk_step(s, inp):
        # remat: the (c,c,hd) pairwise-decay tensors must not be saved per
        # chunk for backward.
        rc, kc, vc, lwc = inp                       # (B,H,c,hd)
        cum = jnp.cumsum(lwc, axis=2)               # inclusive logW
        cum_ex = cum - lwc                          # exclusive logW (W_{t-1})
        # intra-chunk pairwise: exponent cum_ex[t] - cum[i] <= 0 for i < t
        diff = cum_ex[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,t,i,hd)
        decay = jnp.exp(jnp.minimum(diff, 0.0))
        A = jnp.einsum("bhtik,bhtk,bhik->bhti", decay, rc, kc)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        # bonus diagonal
        Au = jnp.einsum("bhtk,bhtk->bht", rc * uf[None, :, None, :], kc)
        out = jnp.einsum("bhti,bhiv->bhtv", A, vc)
        out += Au[..., None] * vc
        # cross-chunk: r_t decayed from chunk start
        out += jnp.einsum("bhtk,bhkv->bhtv", rc * jnp.exp(cum_ex), s)
        # state update: decays from i to end of chunk
        wlast = cum[:, :, -1:, :]                   # logW_c
        kdec = kc * jnp.exp(wlast - cum)            # exponent <= 0
        s_new = s * jnp.exp(wlast.squeeze(2))[:, :, :, None] + \
            jnp.einsum("bhik,bhiv->bhkv", kdec, vc)
        return s_new, out

    s_fin, outs = jax.lax.scan(chunk_step, s0, (rf, kf, vf, lw))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * chunk, hd)[:, :, :S]
    return out, s_fin


def apply_time_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *, mode: str,
                   cache: Optional[Params] = None,
                   ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cd = L.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    H, hd = num_heads_of(cfg), cfg.rwkv.head_dim

    prev = cache["shift_t"] if cache is not None else None
    xx = _token_shift(x, prev) - x
    st = _ddlerp(p, x, xx, cd)

    r = L.linear(p["wr"], st["r"], cd).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = L.linear(p["wk"], st["k"], cd).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = L.linear(p["wv"], st["v"], cd).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(L.linear(p["wg"], st["g"], cd).astype(jnp.float32))

    # data-dependent decay, log-space, clamped for chunk-safe exponents
    wl = jnp.tanh(jnp.einsum("bsd,dr->bsr", st["w"], p["w_lora_a"].astype(cd)))
    wl = jnp.einsum("bsr,rd->bsd", wl, p["w_lora_b"].astype(cd))
    logw = -jnp.exp(jnp.clip(p["w0"][None, None, :] +
                             wl.astype(jnp.float32), -10.0, 1.5))
    logw = jnp.clip(logw, -8.0, -1e-6)
    logw = logw.reshape(B, S, H, hd).transpose(0, 2, 1, 3)

    state0 = cache["wkv"] if cache is not None else None
    if mode == "decode" and S == 1:
        # single-step closed form
        s_prev = state0.astype(jnp.float32)
        r1 = r[:, :, 0].astype(jnp.float32)
        k1 = k[:, :, 0].astype(jnp.float32)
        v1 = v[:, :, 0].astype(jnp.float32)
        kv = k1[..., :, None] * v1[..., None, :]        # (B,H,dk,dv)
        out = jnp.einsum("bhk,bhkv->bhv", r1,
                         s_prev + p["u"].astype(jnp.float32)[None, :, :, None] * kv)
        s_new = jnp.exp(logw[:, :, 0])[..., None] * s_prev + kv
        out = out[:, :, None, :]                        # (B,H,1,dv)
        wkv_out, s_fin = out, s_new
    else:
        wkv_out, s_fin = wkv_chunked(r, k, v, logw, p["u"], state0)

    y = wkv_out.transpose(0, 2, 1, 3).reshape(B, S, D)
    y = L.apply_norm(p["ln_x"], y.astype(jnp.float32))
    y = (y * g).astype(cd)
    y = L.linear(p["wo"], y, cd)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"shift_t": x[:, -1].astype(jnp.float32), "wkv": s_fin}
    return constrain(y, ("batch", "seq", "embed")), new_cache


def init_channel_mix(key, cfg: ModelConfig) -> Params:
    dt = L.dtype_of(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.1).astype(dt),
        "mu_r": (jax.random.uniform(ks[1], (d,)) * 0.1).astype(dt),
        "wk": L.init_linear(ks[0], d, f, dt),
        # named w_down so the sharding rules treat it as the row-parallel
        # (f -> d) projection: its contraction dim must match wk's output
        # sharding on the model axis, else GSPMD all-gathers the full hidden
        "w_down": L.init_linear(ks[1], f, d, dt),
        "wr": L.init_linear(ks[2], d, d, dt),
    }


def apply_channel_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      mode: str, cache: Optional[Params] = None,
                      ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    cd = L.dtype_of(cfg.compute_dtype)
    prev = cache["shift_c"] if cache is not None else None
    xx = _token_shift(x, prev) - x
    xk = x + xx * p["mu_k"].astype(cd)
    xr = x + xx * p["mu_r"].astype(cd)
    h = L.linear(p["wk"], xk, cd)
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(cd)
    h = constrain(h, ("batch", "seq", "mlp"))
    v = L.linear(p["w_down"], h, cd)
    r = jax.nn.sigmoid(L.linear(p["wr"], xr, cd).astype(jnp.float32))
    y = (r * v.astype(jnp.float32)).astype(cd)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"shift_c": x[:, -1].astype(jnp.float32)}
    return constrain(y, ("batch", "seq", "embed")), new_cache
