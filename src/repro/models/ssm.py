"""Mamba selective-SSM mixer (used standalone and inside the Jamba hybrid).

Sequence processing uses a *chunked* selective scan: `lax.scan` over chunks
of the sequence with an associative scan inside each chunk — O(chunk) live
memory for the (B, c, d_inner, d_state) discretised tensors instead of
O(S).  The Pallas kernel (repro/kernels/ssm_scan) implements the same
chunking on TPU; this file is the XLA-native twin and the numeric reference.

Cache layout (decode):
  {"conv": (B, d_conv-1, d_inner) f32, "state": (B, d_inner, d_state) f32}
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def init_ssm(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    dt = L.dtype_of(cfg.param_dtype)
    d, di = cfg.d_model, d_inner_of(cfg)
    dtr = s.resolved_dt_rank(cfg.d_model)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a_init = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                      (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (di,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001))
    inv_softplus = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": L.init_linear(ks[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, di)) /
                   math.sqrt(s.d_conv)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": L.init_linear(ks[2], di, dtr + 2 * s.d_state, dt),
        "dt_proj": {**L.init_linear(ks[3], dtr, di, dt,
                                    scale=dtr ** -0.5),
                    "b": inv_softplus.astype(dt)},
        "A_log": jnp.log(a_init),                       # (di, ds) f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_linear(ks[5], di, d, dt),
    }


def ssm_cache_spec(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    s = cfg.ssm
    di = d_inner_of(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), jnp.float32),
        "state": jax.ShapeDtypeStruct((batch, di, s.d_state), jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv.  x: (B,S,di), w: (K,di).  prev: (B,K-1,di)."""
    K = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    return y + b[None, None, :]


def selective_scan_chunked(u: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                           Bmat: jnp.ndarray, Cmat: jnp.ndarray,
                           D: jnp.ndarray,
                           h0: Optional[jnp.ndarray] = None,
                           chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u, dt: (B,S,di); A: (di,ds); Bmat, Cmat: (B,S,ds); D: (di,).

    Returns (y: (B,S,di), h_final: (B,di,ds)); all math in f32.
    """
    Bsz, S, di = u.shape
    ds = A.shape[-1]
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    uf = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Bf = jnp.pad(Bmat.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cf = jnp.pad(Cmat.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))

    uf = uf.reshape(Bsz, nc, chunk, di)
    dtf = dtf.reshape(Bsz, nc, chunk, di)
    Bf = Bf.reshape(Bsz, nc, chunk, ds)
    Cf = Cf.reshape(Bsz, nc, chunk, ds)

    h_init = (jnp.zeros((Bsz, di, ds), jnp.float32)
              if h0 is None else h0.astype(jnp.float32))

    @jax.checkpoint
    def chunk_step(h, inp):
        # remat: recompute da/dbu/cumulatives in backward — without this the
        # scan saves (B,c,di,ds) residuals per chunk = O(S*di*ds) memory.
        uc, dtc, bc, cc = inp          # (B,c,di) (B,c,di) (B,c,ds) (B,c,ds)
        da = jnp.exp(dtc[..., None] * (-jnp.exp(A))[None, None])  # (B,c,di,ds)
        dbu = (dtc * uc)[..., None] * bc[:, :, None, :]           # (B,c,di,ds)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (da, dbu), axis=1)
        h_t = a_cum * h[:, None] + b_cum                          # (B,c,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", h_t, cc)
        h_new = h_t[:, -1]
        return h_new, y

    xs = (uf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2, 3),
          Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3))
    h_fin, ys = jax.lax.scan(chunk_step, h_init, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, nc * chunk, di)[:, :S]
    y = y + u.astype(jnp.float32) * D[None, None, :]
    return y, h_fin


def apply_ssm(p: Params, x: jnp.ndarray, cfg: ModelConfig, *, mode: str,
              cache: Optional[Params] = None, pos=None,
              ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B,S,D)."""
    s = cfg.ssm
    cd = L.dtype_of(cfg.compute_dtype)
    B, S, D = x.shape
    di = d_inner_of(cfg)
    dtr = s.resolved_dt_rank(cfg.d_model)

    xz = L.linear(p["in_proj"], x, cd)
    u, z = xz[..., :di], xz[..., di:]
    u = constrain(u, ("batch", "seq", "mlp"))

    if mode == "decode":
        assert cache is not None and S == 1
        conv_prev = cache["conv"]
        u_conv = _causal_conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                              prev=conv_prev)
        new_conv = jnp.concatenate(
            [conv_prev[:, 1:], u.astype(jnp.float32)], axis=1)
    else:
        u_conv = _causal_conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        new_conv = None
        if mode == "prefill":
            K = s.d_conv
            tail = jnp.pad(u, ((0, 0), (max(0, K - 1 - S), 0), (0, 0)))
            new_conv = tail[:, -(K - 1):].astype(jnp.float32)

    u_act = jax.nn.silu(u_conv.astype(jnp.float32)).astype(cd)

    xdb = L.linear(p["x_proj"], u_act, cd)
    dt_in = xdb[..., :dtr]
    Bmat = xdb[..., dtr:dtr + s.d_state]
    Cmat = xdb[..., dtr + s.d_state:]
    dt_full = jax.nn.softplus(
        L.linear(p["dt_proj"], dt_in, cd).astype(jnp.float32))

    h0 = cache["state"] if (mode == "decode" and cache is not None) else None
    y, h_fin = selective_scan_chunked(
        u_act, dt_full, p["A_log"].astype(jnp.float32), Bmat, Cmat,
        p["D"], h0=h0)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    out = L.linear(p["out_proj"], y, cd)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv, "state": h_fin}
    return constrain(out, ("batch", "seq", "embed")), new_cache
