"""``repro.obs`` — the unified observability layer.

One subsystem, four pieces, threaded through every simulation layer:

* :mod:`repro.obs.probe` — the zero-overhead-when-disabled
  instrumentation API (:class:`Probe` with counter/gauge/histogram
  handles + span events).  Hook points live in the DES engine
  (``core/sim/engine.py``), the serving simulator and fused Monte-Carlo
  path (``serve_sim``), the DSE sweep loop (``core/dse.py``), and the
  worker pool (``core/parallel.py``); everything defaults to
  ``probe=None`` and hot paths pay a single ``is not None`` branch, so
  uninstrumented runs stay bit-exact and at-speed.
* :mod:`repro.obs.series` — NumPy-backed :class:`MetricSeries` with
  configurable sampling, mergeable across Monte-Carlo seeds into
  mean/95%-CI bands (:func:`merge_series`).
* :mod:`repro.obs.trace` — the unified Perfetto/Chrome
  :class:`TraceBuilder` (span tracks + counter tracks) behind
  ``repro.core.sim.trace``'s public exporters, plus
  :func:`validate_trace`.
* :mod:`repro.obs.artifacts` / :mod:`repro.obs.compare` — per-run
  ``runs/<name>/`` bundles (metrics.json, trace.json, summary.md) and
  the ``python -m repro.obs.compare`` regression-diff CLI.
"""
from repro.obs.series import (HistogramSummary, MergedSeries, MetricSeries,
                              merge_series)
from repro.obs.probe import Counter, Gauge, Probe, get_probe, set_probe
from repro.obs.trace import TraceBuilder, validate_trace
from repro.obs.artifacts import (load_bundle, print_bundle, report_summary,
                                 write_bundle)
from repro.obs.compare import compare, diff, flatten

__all__ = [
    "MetricSeries", "MergedSeries", "HistogramSummary", "merge_series",
    "Probe", "Counter", "Gauge", "set_probe", "get_probe",
    "TraceBuilder", "validate_trace",
    "write_bundle", "load_bundle", "print_bundle", "report_summary",
    "compare", "diff", "flatten",
]
