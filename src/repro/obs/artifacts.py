"""Per-run artifact bundles: ``runs/<name>/`` directories.

A bundle is the machine-readable record of one simulation run:

* ``metrics.json`` — report summary (throughput, latency percentiles,
  utilization), probe counters/gauges/histograms, and every (decimated)
  metric series;
* ``trace.json``   — Chrome trace-event JSON (spans + counter tracks),
  loadable in the Perfetto UI;
* ``summary.md``   — the human-readable one-pager.

:func:`write_bundle` assembles all three from whatever the caller has —
a :class:`~repro.serve_sim.simulator.ServingReport`, a bare
:class:`~repro.core.sim.engine.SimResult`, a
:class:`~repro.obs.probe.Probe`, or any combination.  Bundles are diffed
against each other (or against ``BENCH_*.json``) by
:mod:`repro.obs.compare`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
import time
from typing import Dict, Optional

from repro.obs.trace import TraceBuilder, validate_trace


def _stats_dict(s) -> Dict:
    """LatencyStats (or any flat dataclass) -> plain dict."""
    if dataclasses.is_dataclass(s):
        return dataclasses.asdict(s)
    return dict(s)


def report_summary(report) -> Dict:
    """JSON-able scalar summary of a ``ServingReport`` (duck-typed so
    core stays free of serve_sim imports)."""
    return {
        "workload": report.workload,
        "scheduler": report.scheduler,
        "cost_model": report.cost_model,
        "replicas": report.replicas,
        "slots": report.slots,
        "n_requests": report.n_requests,
        "duration_s": report.duration,
        "output_tokens": report.output_tokens,
        "throughput_rps": report.throughput_rps,
        "throughput_tps": report.throughput_tps,
        "replica_util": report.replica_util,
        "ttft": _stats_dict(report.ttft),
        "tpot": _stats_dict(report.tpot),
        "e2e": _stats_dict(report.e2e),
        "queue_delay": _stats_dict(report.queue_delay),
    }


def _summary_md(name: str, metrics: Dict, trace_tracks: int,
                n_trace_events: int) -> str:
    lines = [f"# run: {name}", ""]
    rep = metrics.get("report")
    if rep:
        lines += [
            f"`{rep['cost_model']}` | scheduler `{rep['scheduler']}` | "
            f"workload `{rep['workload']}` | "
            f"{rep['replicas']}x{rep['slots']} slots",
            "",
            f"- **{rep['n_requests']} requests** in "
            f"{rep['duration_s']:.2f}s simulated "
            f"({rep['throughput_rps']:.1f} req/s, "
            f"{rep['throughput_tps']:.0f} tok/s, "
            f"util {rep['replica_util']:.1%})",
            f"- TTFT p50/p95/p99: {rep['ttft']['p50'] * 1e3:.1f} / "
            f"{rep['ttft']['p95'] * 1e3:.1f} / "
            f"{rep['ttft']['p99'] * 1e3:.1f} ms",
            f"- TPOT p50/p99: {rep['tpot']['p50'] * 1e3:.2f} / "
            f"{rep['tpot']['p99'] * 1e3:.2f} ms",
            f"- E2E p99: {rep['e2e']['p99']:.2f} s | queue-delay p99: "
            f"{rep['queue_delay']['p99'] * 1e3:.1f} ms",
            "",
        ]
    probe = metrics.get("probe")
    if probe:
        if probe.get("counters"):
            lines.append("## Counters (final values)")
            lines.append("")
            for k in sorted(probe["counters"]):
                lines.append(f"- `{k}` = {probe['counters'][k]:g}")
            lines.append("")
        if probe.get("histograms"):
            lines.append("## Histograms")
            lines.append("")
            for k in sorted(probe["histograms"]):
                h = probe["histograms"][k]
                if h["count"]:
                    lines.append(
                        f"- `{k}`: n={h['count']} mean={h['mean']:.4g} "
                        f"p50={h['p50']:.4g} p99={h['p99']:.4g} "
                        f"max={h['max']:.4g}")
                else:
                    lines.append(f"- `{k}`: n=0")
            lines.append("")
    lines += [
        "## Artifacts",
        "",
        "- `metrics.json` — summary + probe metrics + series "
        f"({len(metrics.get('probe', {}).get('series', {}))} series)",
        f"- `trace.json` — {n_trace_events} trace events, "
        f"{trace_tracks} counter tracks "
        "(open in [ui.perfetto.dev](https://ui.perfetto.dev) or "
        "`chrome://tracing`)",
        "",
        f"Recorded {metrics['created']} on {metrics['host']['platform']} "
        f"(python {metrics['host']['python']}).",
    ]
    return "\n".join(lines) + "\n"


def write_bundle(name: str, out_dir: str = "runs",
                 report=None, sim_result=None, probe=None,
                 extra: Optional[Dict] = None) -> str:
    """Write a ``<out_dir>/<name>/`` bundle; returns the bundle path.

    Any of ``report`` (a ServingReport — its embedded ``sim_result`` is
    used for the replica span tracks and its request rows for the
    queue-depth/lane tracks via the serving exporter), ``sim_result`` (a
    bare engine result), and ``probe`` may be given.  ``extra`` is
    merged into ``metrics.json`` verbatim (e.g. sweep config).
    """
    from repro.core.sim.trace import serving_trace_builder, trace_builder

    path = os.path.join(out_dir, name)
    os.makedirs(path, exist_ok=True)

    # ---- trace.json -----------------------------------------------------
    if report is not None:
        tb = serving_trace_builder(report)
    elif sim_result is not None:
        tb = trace_builder(sim_result)
    else:
        tb = TraceBuilder()
    if probe is not None:
        end = None
        if report is not None:
            end = report.duration
        elif sim_result is not None:
            end = sim_result.makespan
        tb.add_probe(probe, end_time=end)
    problems = validate_trace(tb.events)
    if problems:               # never ship a malformed trace silently
        raise RuntimeError(f"bundle {name}: invalid trace: "
                           + "; ".join(problems[:5]))
    tb.to_json(os.path.join(path, "trace.json"))

    # ---- metrics.json ---------------------------------------------------
    metrics: Dict = {
        "name": name,
        "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "host": {"python": platform.python_version(),
                 "platform": platform.platform()},
    }
    if report is not None:
        metrics["report"] = report_summary(report)
    if sim_result is not None:
        metrics["sim"] = {"makespan_s": sim_result.makespan,
                          "n_records": len(sim_result.records)}
    if probe is not None:
        metrics["probe"] = probe.to_metrics()
    if extra:
        metrics["extra"] = extra
    with open(os.path.join(path, "metrics.json"), "w") as f:
        json.dump(metrics, f, indent=1)

    # ---- summary.md -----------------------------------------------------
    with open(os.path.join(path, "summary.md"), "w") as f:
        f.write(_summary_md(name, metrics, len(tb.counter_tracks()),
                            len(tb.events)))
    return path


def load_bundle(path: str) -> Dict:
    """Load a bundle's ``metrics.json`` (``path`` may be the bundle
    directory or the metrics file itself)."""
    if os.path.isdir(path):
        path = os.path.join(path, "metrics.json")
    with open(path) as f:
        return json.load(f)


def print_bundle(path: str, file=None) -> None:
    """Echo a bundle's summary.md to ``file`` (stdout)."""
    d = path if os.path.isdir(path) else os.path.dirname(path)
    md = os.path.join(d, "summary.md")
    if os.path.exists(md):
        with open(md) as f:
            print(f.read(), file=file or sys.stdout)


__all__ = ["write_bundle", "load_bundle", "print_bundle", "report_summary"]
