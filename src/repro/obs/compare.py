"""Regression triage: diff two run bundles (or BENCH_*.json files).

::

    python -m repro.obs.compare runs/baseline runs/candidate
    python -m repro.obs.compare runs/candidate BENCH_pr7.json --threshold 10
    python -m repro.obs.compare BENCH_pr6.json BENCH_pr7.json

Both inputs are flattened to dotted-path numeric leaves
(``report.ttft.p99``, ``probe.counters.serve/queue_arrivals``,
``serve_sim_10k.requests_per_sec``) and compared key-by-key.  Direction
is inferred from the key name — throughput-like metrics regress when
they drop, latency/wall-time-like metrics regress when they rise — and
changes beyond ``--threshold`` percent are flagged.  When the two
documents share no exact keys (a run bundle vs a BENCH file), leaf
basenames are matched instead, so ``…requests_per_sec`` lines up across
formats.  ``--fail-on-regression`` exits 1 when anything regressed —
the CI hook.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

#: key-name fragments implying "higher is better"
_HIGHER = ("throughput", "per_sec", "_rps", "_tps", "speedup", "util",
           "rate", "hits")
#: key-name fragments implying "lower is better"
_LOWER = ("ttft", "tpot", "e2e", "delay", "latency", "wall", "seconds",
          "duration", "_ms", "_s", "p50", "p95", "p99", "mean", "max",
          "misses", "rollback", "bytes", "overhead")


def _direction(key: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 unknown."""
    low = key.lower()
    for frag in _HIGHER:
        if frag in low:
            return +1
    for frag in _LOWER:
        if frag in low:
            return -1
    return 0


#: flattened subtrees that are raw sample arrays, not comparable scalars
_SKIP_SUBTREES = ("series.", "host.", "baseline_")


def flatten(doc, prefix: str = "") -> Dict[str, float]:
    """Dotted-path -> numeric-leaf view of a JSON document; list leaves
    and metadata/series subtrees are skipped."""
    out: Dict[str, float] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            key = f"{prefix}{k}"
            if any((key + ".").startswith(s) or f".{s}" in key + "."
                   for s in _SKIP_SUBTREES):
                continue
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                out[key] = float(v)
            elif isinstance(v, dict):
                out.update(flatten(v, prefix=key + "."))
    return out


def _load(path: str) -> Dict:
    """A bundle directory, a metrics.json, or a BENCH_*.json."""
    if os.path.isdir(path):
        path = os.path.join(path, "metrics.json")
    with open(path) as f:
        doc = json.load(f)
    # BENCH files carry {baseline_*, current}; compare the current run
    if "current" in doc and "pr" in doc:
        return doc["current"]
    return doc


def diff(a: Dict[str, float], b: Dict[str, float],
         threshold_pct: float = 5.0) -> List[Tuple]:
    """Rows ``(key, a, b, pct_change, flag)`` for keys in both docs;
    ``flag`` is 'regression', 'improvement', 'changed', or ''.

    Falls back to basename matching when the exact-key intersection is
    empty (bundle-vs-BENCH: different schemas, shared metric names).
    """
    keys = sorted(set(a) & set(b))
    if not keys and a and b:
        by_base_a = {k.rsplit(".", 1)[-1]: k for k in sorted(a)}
        by_base_b = {k.rsplit(".", 1)[-1]: k for k in sorted(b)}
        shared = sorted(set(by_base_a) & set(by_base_b))
        rows = []
        for base in shared:
            ka, kb = by_base_a[base], by_base_b[base]
            rows.append((f"{ka} ~ {kb}",) + _row(base, a[ka], b[kb],
                                                 threshold_pct)[1:])
        return rows
    return [_row(k, a[k], b[k], threshold_pct) for k in keys]


def _row(key: str, va: float, vb: float,
         threshold_pct: float) -> Tuple:
    if va == 0.0:
        pct = 0.0 if vb == 0.0 else float("inf")
    else:
        pct = (vb - va) / abs(va) * 100.0
    flag = ""
    if abs(pct) >= threshold_pct:
        d = _direction(key)
        if d > 0:
            flag = "regression" if pct < 0 else "improvement"
        elif d < 0:
            flag = "regression" if pct > 0 else "improvement"
        else:
            flag = "changed"
    return (key, va, vb, pct, flag)


def format_diff(rows: List[Tuple], only_flagged: bool = False) -> str:
    if not rows:
        return "(no comparable metrics)"
    width = max(len(r[0]) for r in rows)
    lines = []
    mark = {"regression": "✗", "improvement": "✓", "changed": "~", "": " "}
    for key, va, vb, pct, flag in rows:
        if only_flagged and not flag:
            continue
        pct_s = f"{pct:+8.1f}%" if pct != float("inf") else "     new"
        lines.append(f" {mark[flag]} {key:<{width}}  {va:>12.4g} -> "
                     f"{vb:>12.4g}  {pct_s}  {flag}")
    return "\n".join(lines) if lines else "(no flagged changes)"


def compare(path_a: str, path_b: str, threshold_pct: float = 5.0,
            only_flagged: bool = False,
            file=None) -> Tuple[int, int]:
    """Print the diff; returns ``(n_regressions, n_rows)``."""
    out = file or sys.stdout
    a = flatten(_load(path_a))
    b = flatten(_load(path_b))
    rows = diff(a, b, threshold_pct=threshold_pct)
    print(f"compare: {path_a} (a) vs {path_b} (b), "
          f"threshold {threshold_pct:g}%", file=out)
    print(format_diff(rows, only_flagged=only_flagged), file=out)
    n_reg = sum(1 for r in rows if r[4] == "regression")
    n_imp = sum(1 for r in rows if r[4] == "improvement")
    print(f"{len(rows)} metrics compared: {n_reg} regressions, "
          f"{n_imp} improvements", file=out)
    return n_reg, len(rows)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.compare",
        description="Diff two run bundles or BENCH_*.json files.")
    p.add_argument("a", help="baseline: bundle dir, metrics.json, "
                             "or BENCH_*.json")
    p.add_argument("b", help="candidate: bundle dir, metrics.json, "
                             "or BENCH_*.json")
    p.add_argument("--threshold", type=float, default=5.0,
                   help="flag changes beyond this percent (default 5)")
    p.add_argument("--flagged-only", action="store_true",
                   help="print only flagged rows")
    p.add_argument("--fail-on-regression", action="store_true",
                   help="exit 1 if any metric regressed")
    args = p.parse_args(argv)
    n_reg, _ = compare(args.a, args.b, threshold_pct=args.threshold,
                       only_flagged=args.flagged_only)
    return 1 if (args.fail_on_regression and n_reg) else 0


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = ["flatten", "diff", "format_diff", "compare", "main"]
