"""Probe API: zero-overhead-when-disabled instrumentation handles.

Every instrumented site in the simulation stack follows one pattern::

    def __init__(self, ..., probe: Optional[Probe] = None):
        self._p_queue = probe.counter("serve/queue_depth") if probe else None

    # hot path
    if self._p_queue is not None:
        self._p_queue.add(now, 1)

With ``probe=None`` (the default everywhere) the handle is ``None`` and
the hot path pays exactly one predictable local ``is not None`` branch —
no allocation, no call, no float arithmetic — so instrumented-off runs
are bit-exact and at-speed (guarded by parity tests and the CI
perf-smoke floors).  Probes only ever *read* simulation state, so even
instrumented-on runs produce bit-identical results; instrumentation
changes what is recorded, never what happens.

A :class:`Probe` is a namespace of handles:

* :meth:`counter` — cumulative running total (``add(t, delta)``); deltas
  may be negative (queue depth), the track records the running value;
* :meth:`gauge` — instantaneous level (``set(t, value)``);
* :meth:`histogram` — scalar distribution without a time axis
  (``observe(value)``): job latencies, per-point sweep times;
* :meth:`span` / :meth:`event` — explicit trace events for phases the
  engine's task records don't cover;
* :meth:`child` — a namespaced sub-probe (``seed3/serve/queue_depth``),
  used per Monte-Carlo seed so cross-seed series merge cleanly.

``sample_every`` decimates series storage (see
:mod:`repro.obs.series`); counters stay exact because they record
running totals.  A process-global probe (:func:`set_probe` /
:func:`get_probe`) lets pervasively-shared infrastructure
(``repro.core.parallel``) report into whatever run is active without
threading a parameter through every call chain.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.obs.series import HistogramSummary, MetricSeries, merge_series


class Counter:
    """Cumulative counter handle over one :class:`MetricSeries`.

    ``add`` keeps the decimation bookkeeping in handle-local slots
    (instead of calling :meth:`MetricSeries.sample` and reaching through
    the series) — with ``sample_every`` > 1 the common case is one
    method call plus a few single-level slot writes, which is what keeps
    instrumented-on hot loops within the overhead budget.  The pending
    (decimated-away) last update lives on the handle; :meth:`flush`
    pushes it into the series so tracks reach the end of the run.

    ``_left`` counts down from ``sample_every`` to the next kept sample,
    and the pending value is always ``self.value`` itself, so the common
    (skipped) path is five slot operations and a branch.
    """

    __slots__ = ("series", "value", "_every", "_left", "_last_t")

    def __init__(self, series: MetricSeries):
        self.series = series
        self.value = 0.0
        self._every = series.sample_every
        self._left = self._every
        self._last_t = 0.0

    def add(self, t: float, delta: float = 1.0) -> None:
        self.value += delta
        n = self._left - 1
        if n > 0:
            self._left = n
            self._last_t = t
        else:
            self._left = self._every
            self.series._append(t, self.value)

    def flush(self) -> None:
        if self._left != self._every:
            self._left = self._every
            self.series._append(self._last_t, self.value)
        self.series.flush()


class Gauge:
    """Instantaneous-level handle over one :class:`MetricSeries` (same
    handle-local countdown fast path as :class:`Counter`)."""

    __slots__ = ("series", "value", "_every", "_left", "_last_t")

    def __init__(self, series: MetricSeries):
        self.series = series
        self.value = 0.0
        self._every = series.sample_every
        self._left = self._every
        self._last_t = 0.0

    def set(self, t: float, value: float) -> None:
        self.value = value
        n = self._left - 1
        if n > 0:
            self._left = n
            self._last_t = t
        else:
            self._left = self._every
            self.series._append(t, value)

    def flush(self) -> None:
        if self._left != self._every:
            self._left = self._every
            self.series._append(self._last_t, self.value)
        self.series.flush()


class Probe:
    """One run's instrumentation namespace (see module docstring)."""

    def __init__(self, name: str = "run", sample_every: int = 1):
        self.name = name
        self.sample_every = max(int(sample_every), 1)
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, HistogramSummary] = {}
        self._spans: List[Tuple] = []      # (name, t0, t1, track, args)
        self._events: List[Tuple] = []     # (name, t, args)
        self._children: Dict[str, "Probe"] = {}
        self._t0 = time.perf_counter()

    # ---- handle constructors (memoized by name) -------------------------

    def counter(self, name: str, unit: Optional[str] = None) -> Counter:
        h = self._counters.get(name)
        if h is None:
            h = self._counters[name] = Counter(MetricSeries(
                name, kind="counter", unit=unit,
                sample_every=self.sample_every))
        return h

    def gauge(self, name: str, unit: Optional[str] = None) -> Gauge:
        h = self._gauges.get(name)
        if h is None:
            h = self._gauges[name] = Gauge(MetricSeries(
                name, kind="gauge", unit=unit,
                sample_every=self.sample_every))
        return h

    def histogram(self, name: str,
                  unit: Optional[str] = None) -> HistogramSummary:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = HistogramSummary(
                name, unit=unit, sample_every=self.sample_every)
        return h

    # ---- explicit trace events ------------------------------------------

    def span(self, name: str, t0: float, t1: float, track: str = "spans",
             **args) -> None:
        self._spans.append((name, t0, t1, track, args or None))

    def event(self, name: str, t: float, **args) -> None:
        self._events.append((name, t, args or None))

    # ---- children (per-seed / per-component namespaces) -----------------

    def child(self, name: str) -> "Probe":
        c = self._children.get(name)
        if c is None:
            c = self._children[name] = Probe(
                name, sample_every=self.sample_every)
        return c

    @property
    def children(self) -> Dict[str, "Probe"]:
        return self._children

    # ---- host-side clock -------------------------------------------------

    def elapsed(self) -> float:
        """Wall seconds since this probe was created — the time axis for
        host-side series (pool activity, sweep progress), as opposed to
        the simulation clock used by engine/serving series."""
        return time.perf_counter() - self._t0

    # ---- collection ------------------------------------------------------

    def flush(self) -> None:
        """Force-record decimation-pending samples on every series (and
        recursively on children) so tracks reach the end of the run."""
        for h in self._counters.values():
            h.flush()
        for h in self._gauges.values():
            h.flush()
        for c in self._children.values():
            c.flush()

    def all_series(self, prefix: str = "") -> Dict[str, MetricSeries]:
        """Every series, flattened; child series get ``<child>/`` name
        prefixes."""
        out: Dict[str, MetricSeries] = {}
        for name, h in self._counters.items():
            out[prefix + name] = h.series
        for name, h in self._gauges.items():
            out[prefix + name] = h.series
        for cname, c in self._children.items():
            out.update(c.all_series(prefix=f"{prefix}{cname}/"))
        return out

    def all_histograms(self, prefix: str = "") -> Dict[str,
                                                       HistogramSummary]:
        out: Dict[str, HistogramSummary] = {}
        for name, h in self._histograms.items():
            out[prefix + name] = h
        for cname, c in self._children.items():
            out.update(c.all_histograms(prefix=f"{prefix}{cname}/"))
        return out

    def all_spans(self) -> List[Tuple]:
        return list(self._spans)

    def all_events(self) -> List[Tuple]:
        return list(self._events)

    def merged_child_series(self, grid_points: int = 256):
        """Merge same-named series across children into mean/CI bands —
        the Monte-Carlo cross-seed view (``seed0/x .. seedK/x`` ->
        ``x``)."""
        groups: Dict[str, List[MetricSeries]] = {}
        for c in self._children.values():
            for name, s in c.all_series().items():
                if len(s):
                    groups.setdefault(name, []).append(s)
        return {name: merge_series(members, grid_points=grid_points)
                for name, members in groups.items()}

    def to_metrics(self) -> Dict:
        """JSON-able snapshot: final counter/gauge values, histogram
        summaries, and every (decimated) series."""
        self.flush()
        counters = {}
        gauges = {}

        def walk(p: "Probe", prefix: str) -> None:
            for name, h in p._counters.items():
                counters[prefix + name] = h.value
            for name, h in p._gauges.items():
                gauges[prefix + name] = h.value
            for cname, c in p._children.items():
                walk(c, f"{prefix}{cname}/")

        walk(self, "")
        return {
            "name": self.name,
            "sample_every": self.sample_every,
            "counters": counters,
            "gauges": gauges,
            "histograms": {name: h.to_dict()
                           for name, h in self.all_histograms().items()},
            "series": {name: s.to_dict()
                       for name, s in self.all_series().items()},
        }

    def __repr__(self) -> str:
        return (f"Probe({self.name!r}, counters={len(self._counters)}, "
                f"gauges={len(self._gauges)}, "
                f"histograms={len(self._histograms)}, "
                f"children={len(self._children)})")


# ---------------------------------------------------------------------------
# Process-global probe (for shared infrastructure like the worker pool)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[Probe] = None


def set_probe(probe: Optional[Probe]) -> Optional[Probe]:
    """Install ``probe`` as the process-global probe (None to clear).
    Returns the previous probe so callers can restore it."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = probe
    return prev


def get_probe() -> Optional[Probe]:
    """The process-global probe, or None when observability is off."""
    return _GLOBAL


__all__ = ["Probe", "Counter", "Gauge", "set_probe", "get_probe"]
