"""NumPy-backed time-series metrics: the substrate under ``repro.obs``.

A :class:`MetricSeries` is one named stream of ``(t, value)`` samples —
the raw material every probe handle (counter, gauge) appends to.  Storage
is a pair of growable float64 arrays, so a 10M-event run with sampled
instrumentation costs two array writes per kept sample and nothing else.

``sample_every=N`` keeps every Nth update.  Decimation is *safe by
construction* for both handle kinds: counters record their running
cumulative total (so a kept sample is exact regardless of how many
updates were skipped), and gauges record the current level (skipped
samples are just a coarser view of the same trajectory).  The final
update is always captured via :meth:`flush`, so a counter track never
truncates before the end of the run.

:func:`merge_series` folds K per-seed series (e.g. queue depth per
Monte-Carlo seed) into a :class:`MergedSeries` — mean and a 95%
normal-approximation CI band over a common time grid, using
previous-value (step) interpolation, which is the exact semantics of
counter/gauge tracks.
"""
from __future__ import annotations

from math import sqrt
from typing import Dict, List, Optional, Sequence

import numpy as np


class MetricSeries:
    """One named (t, value) sample stream.

    ``kind`` is ``"counter"`` (cumulative running total — Perfetto
    counter-track semantics) or ``"gauge"`` (instantaneous level).  The
    distinction matters to consumers (rate computation, merge semantics),
    not to storage.
    """

    __slots__ = ("name", "kind", "unit", "sample_every", "_t", "_v", "n",
                 "_skip", "_last_t", "_last_v")

    def __init__(self, name: str, kind: str = "gauge",
                 unit: Optional[str] = None, sample_every: int = 1,
                 capacity: int = 64):
        if kind not in ("counter", "gauge"):
            raise ValueError(f"series {name}: unknown kind {kind!r}")
        if sample_every < 1:
            raise ValueError(f"series {name}: sample_every must be >= 1")
        self.name = name
        self.kind = kind
        self.unit = unit
        self.sample_every = sample_every
        cap = max(int(capacity), 16)
        self._t = np.empty(cap, np.float64)
        self._v = np.empty(cap, np.float64)
        self.n = 0
        self._skip = 0            # updates since the last kept sample
        self._last_t = 0.0        # most recent update (kept or not)
        self._last_v = 0.0

    def _grow(self) -> None:
        cap = 2 * len(self._t)
        t = np.empty(cap, np.float64)
        v = np.empty(cap, np.float64)
        t[:self.n] = self._t[:self.n]
        v[:self.n] = self._v[:self.n]
        self._t = t
        self._v = v

    def _append(self, t: float, v: float) -> None:
        i = self.n
        if i >= len(self._t):
            self._grow()
        self._t[i] = t
        self._v[i] = v
        self.n = i + 1

    def sample(self, t: float, v: float) -> None:
        """Record one update; kept every ``sample_every``-th call."""
        self._last_t = t
        self._last_v = v
        self._skip += 1
        if self._skip >= self.sample_every:
            self._skip = 0
            self._append(t, v)

    def flush(self) -> None:
        """Force-record the most recent update if decimation skipped it
        (``_skip > 0`` means an unkept update is pending; call at end of
        run so the track reaches the final time)."""
        if self._skip:
            self._skip = 0
            self._append(self._last_t, self._last_v)

    @property
    def t(self) -> np.ndarray:
        return self._t[:self.n]

    @property
    def values(self) -> np.ndarray:
        return self._v[:self.n]

    def __len__(self) -> int:
        return self.n

    def value_at(self, t: float) -> float:
        """Step-interpolated value at time ``t`` (0.0 before the first
        sample)."""
        i = int(np.searchsorted(self.t, t, side="right")) - 1
        return float(self._v[i]) if i >= 0 else 0.0

    def to_dict(self) -> Dict:
        """JSON-able form (lists, not arrays)."""
        return {"kind": self.kind, "unit": self.unit,
                "t": [float(x) for x in self.t],
                "v": [float(x) for x in self.values]}

    @classmethod
    def from_dict(cls, name: str, doc: Dict) -> "MetricSeries":
        s = cls(name, kind=doc.get("kind", "gauge"), unit=doc.get("unit"),
                capacity=max(len(doc["t"]), 16))
        n = len(doc["t"])
        s._t[:n] = doc["t"]
        s._v[:n] = doc["v"]
        s.n = n
        return s

    def __repr__(self) -> str:
        return (f"MetricSeries({self.name!r}, kind={self.kind!r}, "
                f"n={self.n})")


class MergedSeries:
    """Cross-seed summary of K same-named series on a common time grid.

    ``mean``/``ci_lo``/``ci_hi`` are per-grid-point mean and 95%
    normal-approximation CI of the mean over the K step-interpolated
    member series (mean ± 1.96·std/√K, sample std; the band collapses to
    the mean for K < 2).
    """

    __slots__ = ("name", "kind", "t", "mean", "ci_lo", "ci_hi", "n_members")

    def __init__(self, name: str, kind: str, t: np.ndarray,
                 mean: np.ndarray, ci_lo: np.ndarray, ci_hi: np.ndarray,
                 n_members: int):
        self.name = name
        self.kind = kind
        self.t = t
        self.mean = mean
        self.ci_lo = ci_lo
        self.ci_hi = ci_hi
        self.n_members = n_members

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "n_members": self.n_members,
                "t": [float(x) for x in self.t],
                "mean": [float(x) for x in self.mean],
                "ci_lo": [float(x) for x in self.ci_lo],
                "ci_hi": [float(x) for x in self.ci_hi]}

    def __repr__(self) -> str:
        return (f"MergedSeries({self.name!r}, n_members={self.n_members}, "
                f"grid={len(self.t)})")


def _step_resample(s: MetricSeries, grid: np.ndarray) -> np.ndarray:
    """Previous-value interpolation of ``s`` onto ``grid`` (0 before the
    first sample) — the exact reading of a counter/gauge track."""
    idx = np.searchsorted(s.t, grid, side="right") - 1
    out = np.where(idx >= 0, s.values[np.maximum(idx, 0)], 0.0)
    return out.astype(np.float64)


def merge_series(members: Sequence[MetricSeries],
                 grid_points: int = 256) -> MergedSeries:
    """Merge K same-metric series into mean/95%-CI bands on a common
    ``grid_points``-point time grid spanning the union of their ranges."""
    members = [m for m in members if len(m)]
    if not members:
        raise ValueError("merge_series needs at least one non-empty series")
    name = members[0].name
    kind = members[0].kind
    t_lo = min(float(m.t[0]) for m in members)
    t_hi = max(float(m.t[-1]) for m in members)
    if t_hi <= t_lo:
        grid = np.asarray([t_lo], np.float64)
    else:
        grid = np.linspace(t_lo, t_hi, max(2, grid_points))
    rows = np.stack([_step_resample(m, grid) for m in members])
    k = len(members)
    mean = rows.mean(axis=0)
    if k < 2:
        return MergedSeries(name, kind, grid, mean, mean.copy(),
                            mean.copy(), k)
    std = rows.std(axis=0, ddof=1)
    hw = 1.96 * std / sqrt(k)
    return MergedSeries(name, kind, grid, mean, mean - hw, mean + hw, k)


class HistogramSummary:
    """Streaming scalar distribution: count / sum / min / max plus a
    decimated sample reservoir for percentiles (every ``sample_every``-th
    observation is kept, so percentile estimates stay cheap on hot
    paths)."""

    __slots__ = ("name", "unit", "count", "total", "min", "max",
                 "sample_every", "_skip", "_vals", "n")

    def __init__(self, name: str, unit: Optional[str] = None,
                 sample_every: int = 1):
        self.name = name
        self.unit = unit
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.sample_every = max(int(sample_every), 1)
        self._skip = 0
        self._vals = np.empty(16, np.float64)
        self.n = 0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._skip += 1
        if self._skip >= self.sample_every:
            self._skip = 0
            if self.n >= len(self._vals):
                new = np.empty(2 * len(self._vals), np.float64)
                new[:self.n] = self._vals[:self.n]
                self._vals = new
            self._vals[self.n] = v
            self.n += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self.n:
            return 0.0
        return float(np.percentile(self._vals[:self.n], q))

    def to_dict(self) -> Dict:
        out = {"count": self.count, "sum": self.total, "mean": self.mean,
               "unit": self.unit}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            out["p50"] = self.percentile(50)
            out["p99"] = self.percentile(99)
        return out

    def __repr__(self) -> str:
        return (f"HistogramSummary({self.name!r}, count={self.count}, "
                f"mean={self.mean:g})")


__all__ = ["MetricSeries", "MergedSeries", "HistogramSummary",
           "merge_series"]
