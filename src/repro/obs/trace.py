"""Unified Chrome trace-event / Perfetto exporter.

One :class:`TraceBuilder` supersedes the two ad-hoc emitters that used
to live in ``repro.core.sim.trace`` (which are now thin wrappers over
this class).  It produces trace-event JSON loadable in the Perfetto UI
or ``chrome://tracing``:

* **metadata** events (``ph="M"``) naming processes and threads;
* **complete spans** (``ph="X"``) with ``ts``/``dur`` in microseconds
  (simulation times are seconds; durations are clamped to >= 1e-3 µs so
  zero-length tasks stay visible);
* **counter tracks** (``ph="C"``) — one per metric, fed either sample
  by sample or wholesale from a :class:`repro.obs.series.MetricSeries`.

:func:`validate_trace` is the schema checker used by tests and the CI
obs-smoke job: every event carries ``ph``/``pid``/``ts`` (metadata
excepted), spans have non-negative ``dur``, and each counter track is
monotone in ``ts``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

_US = 1e6                  # seconds -> microseconds
_MIN_DUR_S = 1e-9          # clamp so zero-duration spans stay visible


class TraceBuilder:
    """Incremental builder for one trace-event JSON document."""

    def __init__(self) -> None:
        self._events: List[Dict] = []
        self._threads: Dict[Tuple[int, int], str] = {}
        self._processes: Dict[int, str] = {}

    # ---- metadata -------------------------------------------------------

    def process(self, pid: int, name: str) -> "TraceBuilder":
        if pid not in self._processes:
            self._processes[pid] = name
            self._events.append({"ph": "M", "pid": pid,
                                 "name": "process_name",
                                 "args": {"name": name}})
        return self

    def thread(self, pid: int, tid: int, name: str) -> "TraceBuilder":
        if (pid, tid) not in self._threads:
            self._threads[(pid, tid)] = name
            self._events.append({"ph": "M", "pid": pid, "tid": tid,
                                 "name": "thread_name",
                                 "args": {"name": name}})
        return self

    # ---- spans ----------------------------------------------------------

    def span(self, pid: int, tid: int, name: str, t0: float, t1: float,
             cat: Optional[str] = None,
             args: Optional[Dict] = None) -> "TraceBuilder":
        """One complete span; ``t0``/``t1`` in simulation seconds."""
        ev: Dict = {"ph": "X", "pid": pid, "tid": tid, "name": name,
                    "ts": t0 * _US,
                    "dur": max(t1 - t0, _MIN_DUR_S) * _US}
        if cat is not None:
            ev["cat"] = cat
        if args is not None:
            ev["args"] = args
        self._events.append(ev)
        return self

    def add_records(self, records: Sequence, pid: int = 0,
                    include_args: bool = True) -> "TraceBuilder":
        """Emit engine ``TaskRecord`` spans, one thread per resource.

        This is the span-emission path shared by ``chrome_trace`` and
        ``serving_chrome_trace``; ``include_args`` controls whether the
        per-task layer/bytes/flops payload is attached (the serving
        replica track omits it to keep 10k-request traces small).
        """
        resources = sorted({r.task.resource for r in records})
        tid_of = {res: i for i, res in enumerate(resources)}
        for res, tid in tid_of.items():
            self.thread(pid, tid, res)
        for rec in records:
            task = rec.task
            args = ({"layer": task.layer, "bytes": task.nbytes,
                     "flops": task.flops} if include_args else None)
            self.span(pid, tid_of[task.resource], task.name,
                      rec.start, rec.end, cat=task.kind, args=args)
        return self

    # ---- counter tracks -------------------------------------------------

    def counter(self, pid: int, name: str, t: float, value: float,
                key: str = "value") -> "TraceBuilder":
        """One counter sample at simulation time ``t`` (seconds)."""
        self._events.append({"ph": "C", "pid": pid, "name": name,
                             "ts": t * _US, "args": {key: value}})
        return self

    def add_series(self, series, pid: int, name: Optional[str] = None,
                   key: Optional[str] = None,
                   end_time: Optional[float] = None) -> "TraceBuilder":
        """A whole counter track from a :class:`MetricSeries`.

        ``end_time`` (seconds) re-emits the final value there so the
        track spans the full run instead of truncating at the last
        sample — Perfetto draws counters as steps, so without this the
        track visually ends early.
        """
        track = name if name is not None else series.name
        k = key if key is not None else (series.unit or "value")
        t = series.t
        v = series.values
        for i in range(len(series)):
            self.counter(pid, track, float(t[i]), float(v[i]), key=k)
        if end_time is not None and len(series) \
                and end_time > float(t[-1]):
            self.counter(pid, track, end_time, float(v[-1]), key=k)
        return self

    # ---- probe ingestion ------------------------------------------------

    def add_probe(self, probe, pid: int = 10,
                  end_time: Optional[float] = None) -> "TraceBuilder":
        """All of a probe's series as counter tracks under one process,
        plus its explicit spans/events (spans grouped by ``track`` name
        onto threads of ``pid + 1``)."""
        probe.flush()
        series = probe.all_series()
        if series:
            self.process(pid, f"metrics:{probe.name}")
            for s in series.values():
                if len(s):
                    self.add_series(s, pid, end_time=end_time)
        spans = probe.all_spans()
        events = probe.all_events()
        if spans or events:
            span_pid = pid + 1
            self.process(span_pid, f"spans:{probe.name}")
            tids: Dict[str, int] = {}
            for (sname, t0, t1, track, args) in spans:
                tid = tids.setdefault(track, len(tids))
                self.thread(span_pid, tid, track)
                self.span(span_pid, tid, sname, t0, t1, args=args)
            for (ename, t, args) in events:
                ev: Dict = {"ph": "i", "pid": span_pid, "tid": 0, "s": "p",
                            "name": ename, "ts": t * _US}
                if args:
                    ev["args"] = args
                self._events.append(ev)
        return self

    # ---- output ---------------------------------------------------------

    @property
    def events(self) -> List[Dict]:
        return self._events

    def counter_tracks(self) -> Dict[Tuple[int, str], int]:
        """Sample counts per (pid, name) counter track — used by the
        obs-smoke job to assert '>= 3 counter tracks'."""
        out: Dict[Tuple[int, str], int] = {}
        for ev in self._events:
            if ev["ph"] == "C":
                k = (ev["pid"], ev["name"])
                out[k] = out.get(k, 0) + 1
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps({"traceEvents": self._events,
                           "displayTimeUnit": "ms"})
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text


def validate_trace(doc) -> List[str]:
    """Schema-check a trace document; returns a list of problems (empty
    when valid).

    ``doc`` may be the JSON text, a parsed dict, or a list of events.
    Checks: every event has ``ph``; non-metadata events have ``pid`` and
    numeric ``ts``; spans have numeric non-negative ``dur``; counter
    events carry numeric ``args``; each (pid, name) counter track is
    monotone non-decreasing in ``ts``.
    """
    if isinstance(doc, str):
        doc = json.loads(doc)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    problems: List[str] = []
    counter_last: Dict[Tuple[int, str], float] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing ph")
            continue
        if ph == "M":
            if ev.get("name") not in ("process_name", "thread_name"):
                problems.append(f"event {i}: metadata name {ev.get('name')!r}")
            continue
        if "pid" not in ev:
            problems.append(f"event {i} ({ph}): missing pid")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i} ({ph}): missing/non-numeric ts")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: span with bad dur {dur!r}")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                problems.append(f"event {i}: counter with bad args")
            k = (ev.get("pid"), ev.get("name"))
            last = counter_last.get(k)
            if last is not None and ts < last:
                problems.append(
                    f"event {i}: counter track {k} ts went backwards "
                    f"({ts} < {last})")
            counter_last[k] = ts
    return problems


__all__ = ["TraceBuilder", "validate_trace"]
