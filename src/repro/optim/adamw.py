"""AdamW optimizer (decoupled weight decay), schedules, global-norm clipping
and int8 gradient compression with error feedback — pure JAX, pytree-based.

Optimizer state is a pytree parallel to params:
  {"m": f32 tree, "v": f32 tree, "step": scalar, ("ef": error-feedback tree)}
so it shards exactly like the parameters (see repro.sharding).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import OptimizerConfig

Params = Any


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def init_opt_state(params: Params, cfg: OptimizerConfig) -> Dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compression == "int8_ef":
        state["ef"] = jax.tree.map(zeros32, params)
    return state


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback.
#
# Quantize grads to int8 with a per-tensor scale before the cross-replica
# reduction; the quantization residual is fed back into the next step
# (error feedback keeps convergence).  Under `jax.grad` the reduction is
# inserted by GSPMD, so we model compression as quantize->dequantize around
# the mean — on a real fleet this pairs with an int8 all-reduce custom call;
# the EF mechanics and convergence behaviour are identical.
# ---------------------------------------------------------------------------


def compress_decompress(g: jnp.ndarray, ef: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    deq = q * scale
    return deq, gf - deq


def apply_compression(grads: Params, state: Dict) -> Tuple[Params, Dict]:
    if "ef" not in state:
        return grads, state
    out = jax.tree.map(compress_decompress, grads, state["ef"])
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(state)
    new_state["ef"] = ef
    return deq, new_state


import re as _re

_DECAY_EXEMPT = (r"norm", r"/scale$", r"/bias$", r"/b$", r"/mu_", r"/w0$",
                 r"/A_log$", r"/D$", r"/u$")


def _decay_mask(path: str) -> float:
    return 0.0 if any(_re.search(t, path) for t in _DECAY_EXEMPT) else 1.0


def _paths(tree, prefix="") -> Any:
    if isinstance(tree, dict):
        return {k: _paths(v, f"{prefix}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_paths(v, f"{prefix}/{i}")
                          for i, v in enumerate(tree))
    return prefix


def adamw_update(params: Params, grads: Params, state: Dict,
                 cfg: OptimizerConfig) -> Tuple[Params, Dict, Dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, state = apply_compression(grads, state)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    paths = _paths(params)

    def upd(p, g, m, v, path):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        delta = delta + cfg.weight_decay * _decay_mask(path) \
            * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], paths)
    p_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(state)
    new_state.update({"m": m_new, "v": v_new, "step": step})
    return p_new, new_state, {"grad_norm": gnorm, "lr": lr}
