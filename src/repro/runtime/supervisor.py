"""Fault-tolerance runtime: heartbeats, failure detection, straggler
mitigation policy, restart-from-checkpoint and elastic re-mesh planning.

On a real fleet each host runs a heartbeat agent; the supervisor aggregates
them and drives the restart/elastic policy.  In this single-process
container the WorkerPool is *simulated* (deterministic failure/straggler
injection hooks used by tests and the fault-tolerance example), but the
policy layer — what to do when a worker dies or lags — is the production
logic, and `plan_elastic_mesh` is what `launch/train.py --elastic` calls.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    step: int = 0
    step_time_ema: float = 0.0
    alive: bool = True


@dataclass
class SupervisorConfig:
    heartbeat_interval: float = 1.0
    failure_timeout: float = 5.0          # missed-heartbeat window
    straggler_factor: float = 1.8         # x median step time => straggler
    straggler_patience: int = 3           # consecutive slow steps
    min_workers: int = 1


class Supervisor:
    """Aggregates heartbeats; decides restart / evict / rebalance."""

    def __init__(self, num_workers: int, cfg: SupervisorConfig = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or SupervisorConfig()
        self.clock = clock
        now = clock()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i, now) for i in range(num_workers)}
        self._slow_counts: Dict[int, int] = {i: 0 for i in range(num_workers)}
        self.events: List[Tuple[float, str, int]] = []

    # -------------------------------------------------------------- inputs

    def heartbeat(self, worker_id: int, step: int,
                  step_time: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        w.step = step
        if step_time is not None:
            w.step_time_ema = (0.7 * w.step_time_ema + 0.3 * step_time
                               if w.step_time_ema else step_time)

    # ------------------------------------------------------------- policy

    def check(self) -> Dict[str, List[int]]:
        """Returns {'failed': [...], 'stragglers': [...]}."""
        now = self.clock()
        failed, stragglers = [], []
        alive = [w for w in self.workers.values() if w.alive]
        times = sorted(w.step_time_ema for w in alive if w.step_time_ema > 0)
        median = times[len(times) // 2] if times else 0.0
        for w in alive:
            if now - w.last_heartbeat > self.cfg.failure_timeout:
                w.alive = False
                failed.append(w.worker_id)
                self.events.append((now, "failure", w.worker_id))
                continue
            if median > 0 and w.step_time_ema > \
                    self.cfg.straggler_factor * median:
                self._slow_counts[w.worker_id] += 1
                if self._slow_counts[w.worker_id] >= \
                        self.cfg.straggler_patience:
                    stragglers.append(w.worker_id)
                    self.events.append((now, "straggler", w.worker_id))
            else:
                self._slow_counts[w.worker_id] = 0
        return {"failed": failed, "stragglers": stragglers}

    def alive_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.alive)

    def evict(self, worker_id: int) -> None:
        self.workers[worker_id].alive = False
        self.events.append((self.clock(), "evicted", worker_id))


# ---------------------------------------------------------------------------
# Elastic re-mesh planning
# ---------------------------------------------------------------------------


def plan_elastic_mesh(alive_devices: int, model_parallel: int,
                      global_batch: int) -> Dict[str, int]:
    """Largest (data, model) mesh fitting the surviving devices, keeping
    model_parallel if possible (params keep their TP layout => cheap
    reshard), shrinking data-parallel ways; global batch is preserved by
    raising per-device batch / grad-accumulation.
    """
    mp = model_parallel
    while mp > 1 and alive_devices < mp:
        mp //= 2
    data = max(1, alive_devices // mp)
    # data ways must divide the global batch: take the largest divisor
    while global_batch % data != 0:
        data -= 1
    used = data * mp
    # per-device micro-batching: accumulate so per-step per-device batch
    # stays close to the healthy-fleet value
    healthy_per_dev = max(1, global_batch // max(alive_devices // mp, 1))
    per_dev = global_batch // data
    grad_accum = 1
    while per_dev // grad_accum > healthy_per_dev * 2 \
            and (global_batch % (data * (grad_accum + 1)) == 0):
        grad_accum += 1
    return {"data": data, "model": mp, "devices_used": used,
            "grad_accum": grad_accum}


# ---------------------------------------------------------------------------
# Straggler mitigation policies
# ---------------------------------------------------------------------------


@dataclass
class MitigationAction:
    kind: str             # "none" | "rebalance" | "evict_and_remesh"
    detail: str = ""


def mitigate_stragglers(stragglers: List[int], persistent: bool
                        ) -> MitigationAction:
    """Policy: transient stragglers get data-rebalance (smaller shard via
    backup-task semantics); persistent ones are evicted and the job
    re-meshed from the last checkpoint."""
    if not stragglers:
        return MitigationAction("none")
    if not persistent:
        return MitigationAction(
            "rebalance",
            f"shrink data shard of workers {stragglers} by 50% "
            f"(backup-task dispatch)")
    return MitigationAction(
        "evict_and_remesh",
        f"evict {stragglers}, restore latest checkpoint on elastic mesh")
