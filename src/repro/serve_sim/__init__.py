"""Virtual serving subsystem: traffic-driven simulation at the concept phase.

Extends the paper's single-step virtual models to the ROADMAP's serving
question: request arrival processes (``workload``), per-request
prefill/decode cost models derived from compiled task graphs (``cost``),
pluggable batching policies (``scheduler``), an event-driven serving
simulator with tail-latency metrics (``simulator``), and an SLO-aware
capacity planner (``capacity``).  The measured counterpart of the virtual
continuous-batching scheduler is ``repro.launch.serve.BatchedServer``.

Quickstart::

    from repro.serve_sim import (ContinuousBatchingScheduler, LengthDist,
                                 ServingCostModelBuilder, SLO,
                                 poisson_workload, simulate_serving)

    cost = ServingCostModelBuilder(cfg).model_for(system)
    report = simulate_serving(cost, ContinuousBatchingScheduler,
                              poisson_workload(4.0, 1000), slots=8)
    print(report.summary())
"""
from repro.serve_sim.capacity import (SLO, CapacityPlan, CapacityPlanner,
                                      ClusterCapacityPlanner, RedundancyPlan)
from repro.serve_sim.cluster import (ClusterReport, ClusterSimulator,
                                     MonteCarloClusterReport,
                                     MonteCarloClusterSimulator, ReplicaPool,
                                     simulate_cluster)
from repro.serve_sim.cost import (PhaseProfile, ServingCostModel,
                                  ServingCostModelBuilder,
                                  profile_from_graph)
from repro.serve_sim.faults import (CompiledFaults, FailureModel,
                                    ReplicaFault, RetryPolicy,
                                    compile_faults)
from repro.serve_sim.monte_carlo import (MonteCarloServingReport,
                                         MonteCarloServingSimulator,
                                         SeedStats, monte_carlo_serving)
from repro.serve_sim.router import (ROUTERS, AutoscalerPolicy,
                                    CircuitBreaker, CircuitBreakerPolicy,
                                    HealthCheckPolicy, HedgePolicy,
                                    LeastLoadedRouter, PassThroughRouter,
                                    RoundRobinRouter, RouterPolicy,
                                    StickyRouter, WeightedRouter,
                                    make_router)
from repro.serve_sim.scheduler import (SCHEDULERS, BatchScheduler,
                                       BucketedPrefillScheduler,
                                       ContinuousBatchingScheduler,
                                       LoadSheddingScheduler, Shed,
                                       StaticBatchScheduler, make_scheduler)
from repro.serve_sim.simulator import (LaneStateArrays, LatencyStats,
                                       RequestMetrics, ServingReport,
                                       ServingSimulator, simulate_serving)
from repro.serve_sim.workload import (ClosedLoopWorkload, LengthDist,
                                      OpenLoopWorkload, Request, RequestBatch,
                                      Workload, bursty_workload,
                                      bursty_workload_batch, diurnal_workload,
                                      diurnal_workload_batch,
                                      poisson_workload,
                                      poisson_workload_batch, trace_workload,
                                      trace_workload_batch)

__all__ = [
    "SLO", "CapacityPlan", "CapacityPlanner", "ClusterCapacityPlanner",
    "RedundancyPlan",
    "ClusterReport", "ClusterSimulator", "MonteCarloClusterReport",
    "MonteCarloClusterSimulator", "ReplicaPool", "simulate_cluster",
    "ROUTERS", "AutoscalerPolicy", "CircuitBreaker", "CircuitBreakerPolicy",
    "HealthCheckPolicy", "HedgePolicy", "LeastLoadedRouter",
    "PassThroughRouter", "RoundRobinRouter", "RouterPolicy", "StickyRouter",
    "WeightedRouter", "make_router",
    "PhaseProfile", "ServingCostModel", "ServingCostModelBuilder",
    "profile_from_graph",
    "CompiledFaults", "FailureModel", "ReplicaFault", "RetryPolicy",
    "compile_faults",
    "MonteCarloServingReport", "MonteCarloServingSimulator", "SeedStats",
    "monte_carlo_serving",
    "SCHEDULERS", "BatchScheduler", "BucketedPrefillScheduler",
    "ContinuousBatchingScheduler", "LoadSheddingScheduler", "Shed",
    "StaticBatchScheduler", "make_scheduler",
    "LaneStateArrays", "LatencyStats", "RequestMetrics", "ServingReport",
    "ServingSimulator", "simulate_serving",
    "ClosedLoopWorkload", "LengthDist", "OpenLoopWorkload", "Request",
    "RequestBatch", "Workload", "bursty_workload", "bursty_workload_batch",
    "diurnal_workload", "diurnal_workload_batch",
    "poisson_workload", "poisson_workload_batch", "trace_workload",
    "trace_workload_batch",
]
