"""SLO-aware capacity planning over the virtual serving simulator.

The paper's top-down flow asks "what hardware annotation meets the
target?"; the serving analog asks **"what is the smallest deployment that
meets the latency SLO under this traffic?"**.  :class:`CapacityPlanner`
answers it by bisecting over replica count (or batch slots per replica)
and re-running the seeded serving simulation at each probe — every probe
is a full tail-latency estimate, not a closed-form approximation, so
burstiness and scheduler behaviour are captured.

Monotonicity note: tail latency is *not* perfectly monotone in capacity
(batching dynamics can shift percentiles slightly), so the planner runs a
doubling phase to find a feasible upper bound, then bisects — the result
is the smallest probed configuration that met the SLO with all smaller
probed configurations failing, which is the operational question.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.serve_sim.cost import ServingCostModel
from repro.serve_sim.scheduler import BatchScheduler
from repro.serve_sim.simulator import ServingReport, simulate_serving
from repro.serve_sim.workload import Workload


@dataclass(frozen=True)
class SLO:
    """Latency targets (seconds); ``inf`` disables a term.

    ``availability`` is a degraded-mode floor (fraction of replica-seconds
    up over the run; 0.0 disables it): under a fault profile a deployment
    only counts as feasible when it also keeps the fleet available — this
    is what makes the planner's answer an N+1-style redundancy sizing
    rather than a pure latency sizing."""

    ttft_p99: float = math.inf
    tpot_p99: float = math.inf
    e2e_p99: float = math.inf
    availability: float = 0.0

    def satisfied_by(self, report: ServingReport) -> bool:
        return (report.ttft.p99 <= self.ttft_p99
                and report.tpot.p99 <= self.tpot_p99
                and report.e2e.p99 <= self.e2e_p99
                and report.availability >= self.availability)

    def satisfied_by_ci(self, report) -> bool:
        """CI-conservative attainment for a seed-batched
        :class:`~repro.serve_sim.monte_carlo.MonteCarloServingReport`:
        every constrained metric must meet its target at the *upper* 95%
        confidence bound of the cross-seed mean (availability at the
        *lower* bound), so one lucky draw cannot declare a configuration
        feasible."""
        return (report.stat("ttft_p99").ci_hi <= self.ttft_p99
                and report.stat("tpot_p99").ci_hi <= self.tpot_p99
                and report.stat("e2e_p99").ci_hi <= self.e2e_p99
                and (self.availability <= 0.0
                     or report.stat("availability").ci_lo
                     >= self.availability))

    def __str__(self) -> str:
        terms = []
        if math.isfinite(self.ttft_p99):
            terms.append(f"TTFT p99<={self.ttft_p99 * 1e3:.0f}ms")
        if math.isfinite(self.tpot_p99):
            terms.append(f"TPOT p99<={self.tpot_p99 * 1e3:.1f}ms")
        if math.isfinite(self.e2e_p99):
            terms.append(f"E2E p99<={self.e2e_p99:.1f}s")
        if self.availability > 0.0:
            terms.append(f"avail>={self.availability:.3%}")
        return " & ".join(terms) or "no SLO"


@dataclass
class CapacityPlan:
    """Outcome of one planning run."""

    axis: str                      # "replicas" | "slots"
    value: int                     # smallest feasible probe (or cap if none)
    feasible: bool
    #: the winning probe's :class:`ServingReport` — or, when the planner
    #: ran with ``num_seeds > 1``, its ``MonteCarloServingReport``
    report: Optional[object]
    probes: Dict[int, bool] = field(default_factory=dict)

    def __str__(self) -> str:
        status = "meets SLO" if self.feasible else "INFEASIBLE at cap"
        return f"{self.axis}={self.value} ({status}, {len(self.probes)} probes)"


class CapacityPlanner:
    """Finds the smallest deployment meeting an :class:`SLO`.

    ``workload_factory`` must return a *fresh, identically-seeded* workload
    per call (closed-loop workloads are stateful); likewise
    ``scheduler_factory`` returns a fresh policy per replica.

    With ``num_seeds > 1`` the factory must instead return a
    ``repro.serve_sim.workload.RequestBatch`` with that many rows; every
    probe then runs the seed-batched Monte-Carlo simulator and the
    bisection decides feasibility on the cross-seed confidence interval
    (:meth:`SLO.satisfied_by_ci`) instead of a single draw — a
    configuration only counts as feasible when the upper 95% bound of
    each constrained p99 meets its target.
    """

    def __init__(self, cost: ServingCostModel,
                 scheduler_factory: Callable[[], BatchScheduler],
                 workload_factory: Callable[[], Workload],
                 slo: SLO, num_seeds: int = 1,
                 failures=None, retry=None):
        """``failures``/``retry`` (see
        :class:`~repro.serve_sim.faults.FailureModel` /
        :class:`~repro.serve_sim.faults.RetryPolicy`) inject the same
        fault profile into every probe, so the plan answers "what is the
        smallest deployment that meets the SLO *while replicas churn*" —
        with an ``SLO.availability`` floor and ``num_seeds > 1`` this is
        an N+1 redundancy bisection against the cross-seed CI."""
        if num_seeds < 1:
            raise ValueError("need num_seeds >= 1")
        self.cost = cost
        self.scheduler_factory = scheduler_factory
        self.workload_factory = workload_factory
        self.slo = slo
        self.num_seeds = num_seeds
        self.failures = failures
        self.retry = retry

    def _evaluate(self, replicas: int, slots: int):
        if self.num_seeds > 1:
            from repro.serve_sim.monte_carlo import MonteCarloServingSimulator
            from repro.serve_sim.workload import RequestBatch

            batch = self.workload_factory()
            if not isinstance(batch, RequestBatch):
                raise TypeError(
                    "num_seeds > 1 needs a workload_factory returning a "
                    f"RequestBatch, got {type(batch)!r}")
            if batch.num_seeds != self.num_seeds:
                raise ValueError(f"batch has {batch.num_seeds} seed rows, "
                                 f"planner wants {self.num_seeds}")
            return MonteCarloServingSimulator(
                self.cost, self.scheduler_factory, batch,
                replicas=replicas, slots=slots,
                failures=self.failures, retry=self.retry).run()
        return simulate_serving(self.cost, self.scheduler_factory,
                                self.workload_factory(),
                                replicas=replicas, slots=slots,
                                failures=self.failures, retry=self.retry)

    def _feasible(self, report) -> bool:
        if self.num_seeds > 1:
            return self.slo.satisfied_by_ci(report)
        return self.slo.satisfied_by(report)

    def plan(self, axis: str = "replicas", lo: int = 1, cap: int = 64,
             replicas: int = 1, slots: int = 8) -> CapacityPlan:
        """Bisect ``axis`` in ``[lo, cap]`` for the smallest SLO-feasible
        value; the other dimension is fixed (``replicas`` / ``slots``)."""
        if axis not in ("replicas", "slots"):
            raise ValueError("axis must be 'replicas' or 'slots'")

        def evaluate(v: int):
            return self._evaluate(v if axis == "replicas" else replicas,
                                  v if axis == "slots" else slots)

        value, ok, probes, reports = _plan_bisect(
            evaluate, self._feasible, lo, cap)
        return CapacityPlan(axis=axis, value=value, feasible=ok,
                            report=reports.get(value), probes=probes)


def _plan_bisect(evaluate: Callable[[int], object],
                 is_feasible: Callable[[object], bool],
                 lo: int, cap: int):
    """Shared doubling-then-bisect search for the smallest feasible value
    in ``[lo, cap]`` (see the monotonicity note in the module docstring).
    Returns ``(value, feasible, probes, reports)``; when nothing in range
    is feasible, ``value`` is ``cap`` with ``feasible=False``."""
    if lo < 1 or cap < lo:
        raise ValueError(f"need 1 <= lo <= cap, got lo={lo}, cap={cap}")

    probes: Dict[int, bool] = {}
    reports: Dict[int, object] = {}

    def feasible(v: int) -> bool:
        if v not in probes:
            r = evaluate(v)
            reports[v] = r
            probes[v] = is_feasible(r)
        return probes[v]

    # doubling phase: find a feasible upper bound
    hi = lo
    while hi < cap and not feasible(hi):
        hi = min(cap, hi * 2)
    if not feasible(hi):
        return hi, False, probes, reports
    # bisect down to the smallest feasible probe
    lo_infeasible = max((v for v, ok in probes.items() if not ok),
                        default=lo - 1)
    best = hi
    lo_b, hi_b = lo_infeasible + 1, hi
    while lo_b < hi_b:
        mid = (lo_b + hi_b) // 2
        if feasible(mid):
            best = mid
            hi_b = mid
        else:
            lo_b = mid + 1
    return best, True, probes, reports


@dataclass
class RedundancyPlan:
    """Outcome of an N+k redundancy comparison
    (:meth:`ClusterCapacityPlanner.plan_redundancy`)."""

    base: int                       # the N of N+k (replicas per pool)
    options: Dict[int, bool]        # extra k -> SLO-feasible?
    choice: Optional[int]           # smallest feasible k (None: none were)
    reports: Dict[int, object] = field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        return self.choice is not None

    def __str__(self) -> str:
        opts = ", ".join(f"N+{k}:{'ok' if ok else 'MISS'}"
                         for k, ok in sorted(self.options.items()))
        if self.choice is None:
            return f"no N+k option meets the SLO (N={self.base}; {opts})"
        return f"N+{self.choice} meets the SLO (N={self.base}; {opts})"


class ClusterCapacityPlanner:
    """Cluster mode of the capacity planner: sizes *per-pool* replica
    counts for a heterogeneous routed cluster under a fault profile.

    ``pools_factory(n)`` must return the cluster's pool list scaled to
    ``n`` replicas per pool (each pool carrying its own cost model and
    :class:`~repro.serve_sim.faults.FailureModel`); ``workload_factory``
    returns a fresh workload per probe — or, with ``num_seeds > 1``, a
    ``RequestBatch`` with that many seed rows, in which case every probe
    runs the Monte-Carlo cluster simulator and feasibility is decided on
    cross-seed confidence bounds (:meth:`SLO.satisfied_by_ci` — the
    availability floor reads the *lower* CI bound, so one lucky fault
    draw cannot declare a redundancy level sufficient).

    Remaining keyword arguments (``health=``, ``hedge=``, ``breaker=``,
    ``autoscaler=``, ``engine=`` ...) are forwarded to every
    :class:`~repro.serve_sim.cluster.ClusterSimulator` probe.
    """

    def __init__(self, pools_factory: Callable[[int], list],
                 workload_factory: Callable[[], object],
                 slo: SLO,
                 router_factory: Optional[Callable[[], object]] = None,
                 num_seeds: int = 1,
                 **cluster_kwargs):
        if num_seeds < 1:
            raise ValueError("need num_seeds >= 1")
        self.pools_factory = pools_factory
        self.workload_factory = workload_factory
        self.slo = slo
        self.router_factory = router_factory
        self.num_seeds = num_seeds
        self.cluster_kwargs = cluster_kwargs

    def _evaluate(self, n: int):
        from repro.serve_sim.cluster import (ClusterSimulator,
                                             MonteCarloClusterSimulator)
        from repro.serve_sim.workload import RequestBatch

        pools = self.pools_factory(n)
        if self.num_seeds > 1:
            batch = self.workload_factory()
            if not isinstance(batch, RequestBatch):
                raise TypeError(
                    "num_seeds > 1 needs a workload_factory returning a "
                    f"RequestBatch, got {type(batch)!r}")
            if batch.num_seeds != self.num_seeds:
                raise ValueError(f"batch has {batch.num_seeds} seed rows, "
                                 f"planner wants {self.num_seeds}")
            return MonteCarloClusterSimulator(
                pools, batch, router_factory=self.router_factory,
                **self.cluster_kwargs).run()
        router = (self.router_factory()
                  if self.router_factory is not None else None)
        return ClusterSimulator(pools, self.workload_factory(), router,
                                **self.cluster_kwargs).run()

    def _feasible(self, report) -> bool:
        if self.num_seeds > 1:
            return self.slo.satisfied_by_ci(report)
        return self.slo.satisfied_by(report)

    def plan(self, lo: int = 1, cap: int = 64) -> CapacityPlan:
        """Smallest per-pool replica count in ``[lo, cap]`` meeting the
        SLO (doubling then bisection, like the single-pool planner)."""
        value, ok, probes, reports = _plan_bisect(
            self._evaluate, self._feasible, lo, cap)
        return CapacityPlan(axis="replicas_per_pool", value=value,
                            feasible=ok, report=reports.get(value),
                            probes=probes)

    def plan_redundancy(self, base: int,
                        extras=(0, 1, 2)) -> RedundancyPlan:
        """The N+1-vs-N+2 question: probe ``base + k`` replicas per pool
        for each ``k`` in ``extras`` and pick the smallest feasible
        overprovision — with ``num_seeds > 1`` each verdict is backed by
        the cross-seed CI availability bound."""
        if base < 1:
            raise ValueError("base must be >= 1")
        options: Dict[int, bool] = {}
        reports: Dict[int, object] = {}
        choice: Optional[int] = None
        for k in sorted(set(int(e) for e in extras)):
            if k < 0:
                raise ValueError("extras must be >= 0")
            r = self._evaluate(base + k)
            reports[k] = r
            ok = self._feasible(r)
            options[k] = ok
            if ok and choice is None:
                choice = k
        return RedundancyPlan(base=base, options=options, choice=choice,
                              reports=reports)
