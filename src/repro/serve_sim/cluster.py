"""Resilient cluster serving: a routing tier over heterogeneous pools.

The ROADMAP's fleet-scale question — *which balancer policy, health-check
interval, hedging budget and redundancy level actually hold the
availability SLO under zone-correlated churn?* — is answered here the
way the paper answers hardware questions: on a virtual model, before any
cluster exists.  A :class:`ClusterSimulator` composes named
:class:`ReplicaPool`\\ s (each a :class:`~repro.serve_sim.simulator.
ServingSimulator` with its own chip-variant cost model, slot count,
scheduler and :class:`~repro.serve_sim.faults.FailureModel`) on **one**
shared DES engine, behind a pluggable
:class:`~repro.serve_sim.router.RouterPolicy`, and layers the
resilience machinery on top:

* **health checks** — periodic probes with hysteresis
  (:class:`~repro.serve_sim.router.HealthCheckPolicy`) drive replicas in
  and out of the routing rotation, so crashes are *detected* with
  realistic lag rather than omnisciently avoided;
* **failover** — a request cancelled by a replica crash re-enters
  through the router (PR 9's epoch-invalidation rollback + retry heap
  decide *when*; the router decides *where*), under a router-level
  ``retry_budget``;
* **hedging** — a request still unfinished after a p99-derived delay is
  duplicated to a second pool; first completion wins, the loser is
  cancelled at its next scheduler boundary (the same instants on every
  engine, so dict-vs-fast golden parity survives cancellation);
* **circuit breakers** — per-pool error-rate trips with half-open
  probing (:class:`~repro.serve_sim.router.CircuitBreakerPolicy`);
* **autoscaling** — a reactive
  :class:`~repro.serve_sim.router.AutoscalerPolicy` orders replicas
  (active after a scale-up lag) and drains them on low pressure, so
  N+1-vs-N+2 and policy trade-offs come out as availability/goodput/
  cost numbers in the :class:`ClusterReport`.

Parity contract (``tests/test_cluster.py``): a 1-pool cluster with
pass-through routing and no health checks reproduces the standalone
:class:`~repro.serve_sim.simulator.ServingSimulator` report bit-exactly
on every engine — the cluster hooks are bookkeeping-only on that path
(no RNG draws, no extra heap events at decision points).

:class:`MonteCarloClusterSimulator` runs the cluster across a
seed-batched :class:`~repro.serve_sim.workload.RequestBatch` (per-seed
fault schedules decorrelated per pool) and reports cross-seed
:class:`~repro.serve_sim.monte_carlo.SeedStats`, which the
:class:`~repro.serve_sim.capacity.ClusterCapacityPlanner` consumes for
CI-conservative availability sizing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.engine import DynamicSimulator, SimResult, Simulator, Task
from repro.serve_sim.cost import ServingCostModel
from repro.serve_sim.faults import FailureModel, RetryPolicy
from repro.serve_sim.router import (AutoscalerPolicy, CircuitBreaker,
                                    CircuitBreakerPolicy, HealthCheckPolicy,
                                    HedgeDelayTracker, HedgePolicy,
                                    RouterPolicy, RoundRobinRouter)
from repro.serve_sim.scheduler import (BatchScheduler,
                                       ContinuousBatchingScheduler, InFlight)
from repro.serve_sim.simulator import (LaneStateArrays, LatencyStats,
                                       ServingReport, ServingSimulator)
from repro.serve_sim.workload import Request, RequestBatch, Workload

__all__ = [
    "ReplicaPool", "ClusterSimulator", "ClusterReport", "simulate_cluster",
    "MonteCarloClusterSimulator", "MonteCarloClusterReport",
]


@dataclass(frozen=True)
class ReplicaPool:
    """One homogeneous pool inside a heterogeneous cluster.

    A pool is a chip variant deployed as ``replicas`` identical serving
    replicas with ``slots`` batch slots each, its own scheduler policy
    and (optionally) its own fault profile — e.g. ``zone-a`` on the
    incumbent chip and ``zone-c`` on the faster annotated variant.

    ``weight`` feeds :class:`~repro.serve_sim.router.WeightedRouter`
    (default: capacity scaled by chip speed).  ``cost_rate`` is the
    pool's cost per replica-second (relative units) — the autoscaler's
    enabled-seconds integral times this rate is the pool's cost in the
    :class:`ClusterReport`.  ``max_replicas`` is autoscaler headroom:
    replicas beyond ``replicas`` exist but start drained.
    """

    name: str
    cost: ServingCostModel
    replicas: int
    slots: int = 8
    scheduler: Callable[[], BatchScheduler] = ContinuousBatchingScheduler
    failures: object = None
    retry: Optional[RetryPolicy] = None
    weight: Optional[float] = None
    cost_rate: float = 1.0
    max_replicas: Optional[int] = None

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise ValueError("ReplicaPool.name must be a non-empty string")
        if not isinstance(self.replicas, int) or self.replicas < 1:
            raise ValueError(f"ReplicaPool.replicas must be an int >= 1, "
                             f"got {self.replicas!r}")
        if not isinstance(self.slots, int) or self.slots < 1:
            raise ValueError(f"ReplicaPool.slots must be an int >= 1, "
                             f"got {self.slots!r}")
        w = self.weight
        if w is not None and not (isinstance(w, (int, float))
                                  and math.isfinite(w) and w > 0):
            raise ValueError(f"ReplicaPool.weight must be finite and > 0, "
                             f"got {w!r}")
        cr = self.cost_rate
        if not (isinstance(cr, (int, float)) and math.isfinite(cr)
                and cr >= 0):
            raise ValueError(f"ReplicaPool.cost_rate must be finite and "
                             f">= 0, got {cr!r}")
        mr = self.max_replicas
        if mr is not None and (not isinstance(mr, int) or mr < self.replicas):
            raise ValueError("ReplicaPool.max_replicas must be an int >= "
                             f"replicas ({self.replicas}), got {mr!r}")


@dataclass
class ClusterReport:
    """Cluster-wide serving estimate: per-pool reports + routing metrics.

    ``availability`` here is *request-level* — completed / offered — the
    quantity an availability SLO constrains at the routing tier (a
    cluster can keep serving through replica churn; what users see is
    whether their request completed).  ``fleet_availability`` is the
    replica-seconds-up fraction the per-pool fault windows imply, for
    comparison against the single-pool notion.
    """

    workload: str
    router: str
    pools: Dict[str, ServingReport]
    replicas: int                       # total built replicas, all pools
    duration: float                     # shared-engine makespan, seconds
    n_offered: int                      # requests routed (excl. retries)
    n_requests: int                     # completed cluster-wide
    output_tokens: int
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    queue_delay: LatencyStats
    replica_util: float
    availability: float                 # completed / offered
    fleet_availability: float           # replica-seconds up (fault windows)
    # ---- resilience / routing metrics -----------------------------------
    n_failures: int = 0
    n_retries: int = 0
    n_failovers: int = 0                # retries re-routed through the router
    retries_suppressed: int = 0         # retry fired while a twin still ran
    n_failopen: int = 0                 # routed with zero routable pools
    n_abandoned: int = 0
    n_shed: int = 0
    n_lost: Dict[str, int] = field(default_factory=dict)
    hedges_issued: int = 0
    hedges_won: int = 0                 # the duplicate finished first
    hedge_waste_tokens: int = 0         # tokens decoded by losing copies
    breaker_trips: Dict[str, int] = field(default_factory=dict)
    breaker_open_time: Dict[str, float] = field(default_factory=dict)
    time_out_of_rotation: Dict[str, float] = field(default_factory=dict)
    n_routed: Dict[str, int] = field(default_factory=dict)
    scale_events: List[Tuple] = field(default_factory=list)
    enabled_seconds: Dict[str, float] = field(default_factory=dict)
    cost: float = 0.0                   # sum_i enabled_seconds_i * rate_i
    events: List[Tuple] = field(default_factory=list)

    @property
    def throughput_rps(self) -> float:
        return self.n_requests / self.duration if self.duration > 0 else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.output_tokens / self.duration if self.duration > 0 else 0.0

    @property
    def goodput_rps(self) -> float:
        return self.throughput_rps

    @property
    def attempt_rps(self) -> float:
        if self.duration <= 0:
            return 0.0
        return (self.n_requests + self.n_retries) / self.duration

    @property
    def abandonment_rate(self) -> float:
        """Fraction of offered requests the cluster never completed."""
        if self.n_offered <= 0:
            return 0.0
        return (self.n_offered - self.n_requests) / self.n_offered

    @property
    def n_lost_total(self) -> int:
        return sum(self.n_lost.values())

    def summary(self) -> str:
        shares = "+".join(f"{name}:{self.n_routed.get(name, 0)}"
                          for name in self.pools)
        s = (
            f"cluster[{self.router}|{self.workload}] "
            f"{len(self.pools)} pools / {self.replicas} replicas: "
            f"{self.n_requests}/{self.n_offered} reqs in "
            f"{self.duration:.1f}s ({self.throughput_rps:.2f} req/s, "
            f"util={self.replica_util:.1%}, "
            f"availability={self.availability:.4%})\n"
            f"  TTFT p50/p99 = {self.ttft.p50 * 1e3:.0f}/"
            f"{self.ttft.p99 * 1e3:.0f} ms   "
            f"E2E p99 = {self.e2e.p99:.2f} s   routed {shares}")
        if (self.n_failures or self.n_failovers or self.hedges_issued
                or self.n_lost or self.scale_events):
            trips = sum(self.breaker_trips.values())
            s += (
                f"\n  resilience: {self.n_failures} failures, "
                f"{self.n_failovers} failovers "
                f"({self.retries_suppressed} suppressed), "
                f"{self.hedges_issued} hedges ({self.hedges_won} won), "
                f"{trips} breaker trips, "
                f"{self.n_lost_total} lost {dict(self.n_lost)}, "
                f"{len(self.scale_events)} scale events, "
                f"cost={self.cost:.0f}")
        return s


class ClusterSimulator:
    """Routes one workload over heterogeneous replica pools on a shared
    DES engine.

    ``pools`` is a list of :class:`ReplicaPool` specs; ``router`` a
    :class:`~repro.serve_sim.router.RouterPolicy` (default round-robin).
    ``health`` / ``hedge`` / ``breaker`` / ``autoscaler`` switch on the
    corresponding machinery; all default off, and with exactly one pool,
    a pass-through router and everything off, the run is bit-identical
    to the standalone :class:`ServingSimulator` (the golden contract).

    ``fault_seed``: ``None`` or a scalar/tuple is forwarded verbatim to
    every pool (the parity configuration); a *list* supplies one
    override per pool (how the Monte-Carlo wrapper decorrelates pools
    per seed).
    """

    def __init__(self, pools: Sequence[ReplicaPool], workload: Workload,
                 router: Optional[RouterPolicy] = None, *,
                 health: Optional[HealthCheckPolicy] = None,
                 hedge: Optional[HedgePolicy] = None,
                 breaker: Optional[CircuitBreakerPolicy] = None,
                 autoscaler: Optional[AutoscalerPolicy] = None,
                 phase_tasks: int = 0,
                 engine: str = "fast",
                 probe=None,
                 record_events: bool = False,
                 fault_seed=None):
        pools = list(pools)
        if not pools:
            raise ValueError("need at least one ReplicaPool")
        names = [p.name for p in pools]
        if len(set(names)) != len(names):
            raise ValueError(f"pool names must be unique, got {names}")
        if isinstance(fault_seed, list) and len(fault_seed) != len(pools):
            raise ValueError(f"fault_seed list has {len(fault_seed)} "
                             f"entries for {len(pools)} pools")
        self.pools = pools
        self.workload = workload
        self.router = router if router is not None else RoundRobinRouter()
        self.health = health
        self.autoscaler = autoscaler
        self.record_events = record_events
        self.probe = probe
        P = self._n_pools = len(pools)

        # One engine for the whole cluster: pools share its heap, task
        # ids and (dict-graph mode) the completion dispatcher below.
        if phase_tasks and engine == "fast":
            self._sim = DynamicSimulator()
        elif phase_tasks:
            self._sim = Simulator(on_complete=self._task_done)
        else:
            self._sim = Simulator()

        try:
            expected = int(workload.n_requests)
        except Exception:
            expected = -1
        self._expected = expected if expected >= 0 else (1 << 62)

        # ---- pool runtimes ----------------------------------------------
        self._rts: List[ServingSimulator] = []
        for i, spec in enumerate(pools):
            n_built = spec.replicas
            if autoscaler is not None and spec.max_replicas is not None:
                n_built = spec.max_replicas
            fs = fault_seed[i] if isinstance(fault_seed, list) else fault_seed
            rt = ServingSimulator(
                spec.cost, spec.scheduler, workload,
                replicas=n_built, slots=spec.slots,
                record_events=record_events, phase_tasks=phase_tasks,
                engine=engine, probe=probe, failures=spec.failures,
                retry=spec.retry, fault_seed=fs, sim=self._sim,
                res_prefix=f"{spec.name}/", obs_ns=f"cluster/{spec.name}")
            if P > 1 and expected > 16 * P:
                # each pool serves only a share of the trace; shrink the
                # (grow-on-demand) per-pool metric columns accordingly
                rt.lane_state = LaneStateArrays(
                    capacity=expected // P + 64)
            # bind the cluster hooks (bookkeeping-only on the hot path)
            rt._route_hook = self._route_new
            rt._retry_hook = self._make_retry_hook(i)
            rt._abandon_hook = self._make_abandon_hook(i)
            rt._shed_hook = self._make_shed_hook(i)
            rt._finish_hook = self._make_finish_hook(i)
            if autoscaler is not None and n_built > spec.replicas:
                rt._enabled = [r < spec.replicas for r in range(n_built)]
            self._rts.append(rt)

        # ---- per-request routing state ----------------------------------
        n0 = min(self._expected, 1 << 20)
        n0 = max(n0, 16)
        self._completed = bytearray(n0)
        self._lost = bytearray(n0)
        self._hedged = bytearray(n0)
        self._live = [0] * n0
        self._fails = [0] * n0
        self._where = [-1] * n0
        self._pending_retry = [0] * n0
        self._copies: Dict[int, List[int]] = {}

        # ---- counters ----------------------------------------------------
        self.n_offered = 0
        self.n_completed = 0
        self._resolved = 0
        self.n_failovers = 0
        self.retries_suppressed = 0
        self.n_failopen = 0
        self.hedges_issued = 0
        self.hedges_won = 0
        self.hedge_waste_tokens = 0
        self.n_lost: Dict[str, int] = {}
        self.n_routed = [0] * P
        self.routing_events: List[Tuple] = []
        self._pending_routes = 0
        self._pending_retry_total = 0
        self._pending_hedges = 0

        # ---- health-check state -----------------------------------------
        if health is not None:
            self._in_rot = [[True] * len(rt.replicas) for rt in self._rts]
            self._h_bad = [[0] * len(rt.replicas) for rt in self._rts]
            self._h_good = [[0] * len(rt.replicas) for rt in self._rts]
            self._out_since = [[0.0] * len(rt.replicas) for rt in self._rts]
            self._rotation = [rt.n_enabled() for rt in self._rts]
        else:
            self._rotation = None
        self._t_out = [0.0] * P

        # ---- circuit breakers -------------------------------------------
        self._breakers = ([CircuitBreaker(breaker) for _ in pools]
                          if breaker is not None else None)

        # ---- hedging -----------------------------------------------------
        self._hedge = hedge
        self._hedge_tracker = (HedgeDelayTracker(hedge)
                               if hedge is not None else None)

        # ---- autoscaler / cost accounting -------------------------------
        self._pending_orders = [0] * P
        self._en_count = [rt.n_enabled() for rt in self._rts]
        self._en_seconds = [0.0] * P
        self._en_last = [0.0] * P
        self.scale_events: List[Tuple] = []

        # router fast path: with no rotation/breaker/scaling machinery,
        # every pool is always routable
        self._all_pools = list(range(P))
        self._static_routing = (health is None and breaker is None
                                and autoscaler is None)

        # default weighted-router weights: capacity scaled by chip speed
        self._weights: List[float] = []
        for spec in pools:
            w = spec.weight
            if w is None:
                try:
                    step = float(spec.cost.decode_step_time(1, 512))
                except Exception:
                    step = 1.0
                w = spec.replicas * spec.slots / max(step, 1e-12)
            self._weights.append(float(w))

        if probe is not None:
            self._p_rot = [probe.gauge(f"cluster/{spec.name}/in_rotation",
                                       unit="replicas") for spec in pools]
            self._p_en = [probe.gauge(f"cluster/{spec.name}/enabled",
                                      unit="replicas") for spec in pools]
            self._p_failover = probe.counter("cluster/router/failovers")
            self._p_hedges = probe.counter("cluster/router/hedges")
            self._p_lost = probe.counter("cluster/router/lost",
                                         unit="requests")

    # ---- engine plumbing -------------------------------------------------

    def _task_done(self, task: Task, now: float) -> None:
        """Dict-graph mode: dispatch a phase-tail completion to the pool
        that injected it (task ids are unique across the shared engine)."""
        for rt in self._rts:
            h = rt._tail_handlers.pop(task.tid, None)
            if h is not None:
                h(now)
                return

    def _ensure(self, rid: int) -> None:
        n = len(self._live)
        if rid < n:
            return
        grow = max(rid + 1 - n, n)
        self._completed.extend(b"\0" * grow)
        self._lost.extend(b"\0" * grow)
        self._hedged.extend(b"\0" * grow)
        self._live.extend([0] * grow)
        self._fails.extend([0] * grow)
        self._where.extend([-1] * grow)
        self._pending_retry.extend([0] * grow)

    # ---- router view of the cluster -------------------------------------

    def pool_load(self, i: int) -> float:
        """Queued + in-flight requests at pool ``i`` — what a balancer
        observes at its own edge (not the pool's internal fault state)."""
        rt = self._rts[i]
        return len(rt.pending) + sum(len(rep.active) for rep in rt.replicas)

    def pool_capacity(self, i: int) -> float:
        """Healthy capacity: in-rotation replicas times slots."""
        return self._rot_count(i) * self.pools[i].slots

    def pool_weight(self, i: int) -> float:
        return self._weights[i]

    def _rot_count(self, i: int) -> int:
        if self._rotation is not None:
            return self._rotation[i]
        return self._en_count[i]

    def _routable(self, now: float) -> List[int]:
        if self._static_routing:
            return self._all_pools
        out = []
        bks = self._breakers
        for i in range(self._n_pools):
            if self._rot_count(i) <= 0:
                continue
            if bks is not None and not bks[i].allow(now):
                continue
            out.append(i)
        if not out:
            # fail open: a router with nowhere to go still routes (the
            # alternative is silently dropping traffic); counted so the
            # report shows how often the cluster flew blind
            self.n_failopen += 1
            return self._all_pools
        return out

    def _pick(self, cands: List[int], req: Request, now: float) -> int:
        j = self.router.pick(cands, self, req)
        if self._breakers is not None:
            self._breakers[j].on_route(now)
        return j

    # ---- arrivals and routing -------------------------------------------

    def _route_new(self, req: Request) -> None:
        """Entry point for every first-attempt arrival (initial trace and
        closed-loop follow-ups re-entering via the pool route hook)."""
        self._pending_routes += 1
        self._sim.at(max(0.0, req.t_arrive),
                     lambda r=req: self._dispatch(r))

    def _dispatch(self, req: Request) -> None:
        now = self._sim.now
        self._pending_routes -= 1
        self.n_offered += 1
        rid = req.rid
        self._ensure(rid)
        j = self._pick(self._routable(now), req, now)
        self._live[rid] = 1
        self._where[rid] = j
        self.n_routed[j] += 1
        rt = self._rts[j]
        rt._n_offered += 1
        if self.record_events:
            self.routing_events.append(("route", rid, j))
        rt._arrive(req, now)
        hp = self._hedge
        if hp is not None and self._n_pools > 1:
            d = self._hedge_tracker.delay
            if d < math.inf:
                self._pending_hedges += 1
                self._sim.at(now + d, lambda r=req: self._maybe_hedge(r))

    def _maybe_hedge(self, req: Request) -> None:
        self._pending_hedges -= 1
        rid = req.rid
        # still on its first attempt, unfinished, and unhedged?  (a
        # request in retry limbo has live == 0; hedging it would race
        # the failover path for no benefit)
        if self._completed[rid] or self._hedged[rid] or self._live[rid] != 1:
            return
        hp = self._hedge
        if self.hedges_issued + 1 > hp.max_fraction * max(1, self.n_offered):
            return                      # hedging budget exhausted
        now = self._sim.now
        origin = self._where[rid]
        cands = [i for i in self._routable(now) if i != origin]
        if not cands:
            return
        j = self._pick(cands, req, now)
        self._hedged[rid] = 1
        self.hedges_issued += 1
        self._copies[rid] = [origin, j]
        self._live[rid] += 1
        if self.record_events:
            self.routing_events.append(("hedge", rid, origin, j))
        self._rts[j]._arrive(req, now)

    # ---- pool hook factories --------------------------------------------

    def _make_finish_hook(self, i: int):
        def on_finish(fl: InFlight, now: float) -> bool:
            rid = fl.req.rid
            if self._completed[rid]:
                # the losing hedge copy reached a scheduler boundary
                # after the winner finished: swallow it (no metrics row,
                # no closed-loop follow-up) and account the waste
                self._live[rid] -= 1
                self._rts[i]._cancelled_rids.discard(rid)
                self.hedge_waste_tokens += fl.generated
                return False
            self._completed[rid] = 1
            self._live[rid] -= 1
            self.n_completed += 1
            self._resolved += 1
            if self._breakers is not None:
                self._breakers[i].record_success(now)
            tr = self._hedge_tracker
            if tr is not None:
                tr.observe(now - fl.req.t_arrive)
            copies = self._copies.pop(rid, None)
            if copies is not None:
                other = copies[0] if copies[1] == i else copies[1]
                if i == copies[1]:
                    self.hedges_won += 1
                if self.record_events:
                    self.routing_events.append(("hedge_win", rid, i))
                if self._live[rid] > 0:
                    if self._rts[other].cancel_request(rid, now) == "queued":
                        self._live[rid] -= 1
            return True
        return on_finish

    def _make_retry_hook(self, i: int):
        def on_retry(req: Request, t_retry: float) -> None:
            # the pool already drew backoff/jitter and passed the
            # deadline check (RNG stream parity with standalone); the
            # cluster only redirects the re-enqueue through the router
            rid = req.rid
            now = self._sim.now
            self._live[rid] -= 1
            if self._where[rid] == i:
                self._where[rid] = -1
            if self._breakers is not None:
                self._breakers[i].record_error(now)
            self._pending_retry[rid] += 1
            self._pending_retry_total += 1
            self._sim.at(t_retry,
                         lambda r=req, o=i: self._route_retry(o, r))
        return on_retry

    def _make_abandon_hook(self, i: int):
        def on_abandon(req: Request) -> None:
            rid = req.rid
            self._live[rid] -= 1
            if self._where[rid] == i:
                self._where[rid] = -1
            if self._breakers is not None:
                self._breakers[i].record_error(self._sim.now)
            if (not self._completed[rid] and self._live[rid] <= 0
                    and self._pending_retry[rid] == 0):
                self._mark_lost(rid, "abandoned")
        return on_abandon

    def _make_shed_hook(self, i: int):
        def on_shed(reqs: Sequence[Request]) -> None:
            # admission control, not a failure: sheds do not feed the
            # breaker's error window
            for req in reqs:
                rid = req.rid
                self._live[rid] -= 1
                if self._where[rid] == i:
                    self._where[rid] = -1
                if (not self._completed[rid] and self._live[rid] <= 0
                        and self._pending_retry[rid] == 0):
                    self._mark_lost(rid, "shed")
        return on_shed

    def _route_retry(self, origin: int, req: Request) -> None:
        rid = req.rid
        self._pending_retry[rid] -= 1
        self._pending_retry_total -= 1
        if self._completed[rid] or self._lost[rid]:
            return
        if self._live[rid] > 0:
            # a hedge twin (or an earlier failover) is still running —
            # re-injecting would duplicate the request
            self.retries_suppressed += 1
            return
        rb = self.router.retry_budget
        if rb is not None and self._fails[rid] >= rb:
            self._mark_lost(rid, "budget")
            return
        self._fails[rid] += 1
        now = self._sim.now
        cands = self._routable(now)
        if len(cands) > 1 and origin in cands:
            # prefer failing over *away* from the pool that just lost it
            cands = [c for c in cands if c != origin]
        j = self._pick(cands, req, now)
        if j != origin:
            # a same-pool re-route is a plain retry (already counted by
            # the pool); only a cross-pool re-route is a failover
            self.n_failovers += 1
        self._live[rid] = 1
        self._where[rid] = j
        if self.record_events:
            self.routing_events.append(("failover", rid, origin, j))
        self._rts[j]._arrive(req, now)

    def _mark_lost(self, rid: int, kind: str) -> None:
        if self._lost[rid] or self._completed[rid]:
            return
        self._lost[rid] = 1
        self._resolved += 1
        self.n_lost[kind] = self.n_lost.get(kind, 0) + 1
        if self.record_events:
            self.routing_events.append(("lost", rid, kind))

    # ---- periodic machinery ---------------------------------------------

    def _tick_alive(self) -> bool:
        """Whether the health/autoscaler chains should keep running.
        Ending them lets the event heap drain — stuck requests (e.g. a
        permanently-down pool with no retries) end the run exactly as
        they do standalone, instead of ticking forever."""
        if self._resolved >= self._expected:
            return False
        if (self._pending_routes or self._pending_retry_total
                or self._pending_hedges):
            return True
        scaler = self.autoscaler is not None
        for p, rt in enumerate(self._rts):
            for rep in rt.replicas:
                if rep.busy:
                    return True
            if self._pending_orders[p]:
                return True
            if (scaler and rt.pending
                    and self._en_count[p] < len(rt.replicas)):
                return True
        return False

    def _health_tick(self) -> bool:
        now = self._sim.now
        hp = self.health
        for i, rt in enumerate(self._rts):
            en = rt._enabled
            in_rot = self._in_rot[i]
            bad, good = self._h_bad[i], self._h_good[i]
            out_since = self._out_since[i]
            down, speed = rt._down, rt._speed
            count = 0
            for r in range(len(in_rot)):
                ok = (not down[r]) and speed[r] <= hp.max_slow_factor
                if ok:
                    good[r] += 1
                    bad[r] = 0
                    if not in_rot[r] and good[r] >= hp.healthy_after:
                        in_rot[r] = True
                        self._t_out[i] += now - out_since[r]
                else:
                    bad[r] += 1
                    good[r] = 0
                    if in_rot[r] and bad[r] >= hp.unhealthy_after:
                        in_rot[r] = False
                        out_since[r] = now
                if in_rot[r] and (en is None or en[r]):
                    count += 1
            self._rotation[i] = count
        if self.probe is not None:
            self._obs_emit(now)
        return self._tick_alive()

    def _scale_tick(self) -> bool:
        now = self._sim.now
        pol = self.autoscaler
        for i, rt in enumerate(self._rts):
            en_ct = self._en_count[i]
            depth = len(rt.pending) / max(1, en_ct)
            if depth > pol.up_threshold:
                room = len(rt.replicas) - en_ct - self._pending_orders[i]
                k = min(pol.step, room)
                for _ in range(max(0, k)):
                    self._pending_orders[i] += 1
                    self._sim.at(now + pol.scale_up_lag,
                                 lambda p=i: self._activate(p))
            elif (depth < pol.down_threshold
                    and self._pending_orders[i] == 0
                    and en_ct > pol.min_replicas):
                for _ in range(min(pol.step, en_ct - pol.min_replicas)):
                    self._drain(i)
        if self.probe is not None:
            self._obs_emit(now)
        return self._tick_alive()

    def _activate(self, i: int) -> None:
        """A scale-up order arrives (after the boot/warm-up lag)."""
        self._pending_orders[i] -= 1
        rt = self._rts[i]
        en = rt._enabled
        if en is None:
            return
        for r in range(len(en)):
            if not en[r]:
                self._set_enabled(i, r, True)
                return

    def _drain(self, i: int) -> None:
        rt = self._rts[i]
        en = rt._enabled
        if en is None:
            en = rt._enabled = [True] * len(rt.replicas)
        for r in range(len(en) - 1, -1, -1):
            if en[r]:
                self._set_enabled(i, r, False)
                return

    def _set_enabled(self, i: int, r: int, flag: bool) -> None:
        now = self._sim.now
        self._en_seconds[i] += self._en_count[i] * (now - self._en_last[i])
        self._en_last[i] = now
        self._en_count[i] += 1 if flag else -1
        self.scale_events.append((now, self.pools[i].name,
                                  1 if flag else -1))
        self._rts[i].set_replica_enabled(r, flag, now)
        if self.record_events:
            self.routing_events.append(
                ("scale", self.pools[i].name, r, flag))

    # ---- observability ---------------------------------------------------

    def _obs_emit(self, now: float) -> None:
        for i in range(self._n_pools):
            self._p_rot[i].set(now, float(self._rot_count(i)))
            self._p_en[i].set(now, float(self._en_count[i]))
        for h, v in ((self._p_failover, self.n_failovers),
                     (self._p_hedges, self.hedges_issued),
                     (self._p_lost, sum(self.n_lost.values()))):
            h.value = v = float(v)
            h.series._append(now, v)

    # ---- entry point -----------------------------------------------------

    def run(self) -> ClusterReport:
        # fault schedules first (pool order): at tied timestamps fault
        # events beat arrivals, matching the standalone contract
        for rt in self._rts:
            rt._arm_faults()
        if self.health is not None:
            self._sim.every(self.health.interval, self._health_tick)
        if self.autoscaler is not None:
            self._sim.every(self.autoscaler.interval, self._scale_tick)
        for req in self.workload.initial():
            self._route_new(req)
        sim_result = self._sim.run()
        return self._build_report(sim_result)

    def _build_report(self, sim_result: SimResult) -> ClusterReport:
        end_t = max(sim_result.makespan, self._sim.now)
        pools = self.pools
        pool_reports: Dict[str, ServingReport] = {}
        for spec, rt in zip(pools, self._rts):
            pool_reports[spec.name] = rt._build_report(sim_result,
                                                       flush=False)

        # cluster latency populations: every pool's metric columns, as
        # one population (identical arithmetic to LaneStateArrays.stats)
        def cat(name: str) -> np.ndarray:
            return np.concatenate(
                [getattr(rt.lane_state, name)[:rt.lane_state.n]
                 for rt in self._rts])

        t_arrive, t_first = cat("t_arrive"), cat("t_first")
        t_done, out = cat("t_done"), cat("output")
        mask = out > 1
        tpot = ((t_done[mask] - t_first[mask]) / (out[mask] - 1)
                if mask.any() else np.empty(0))
        ttft = LatencyStats.of(t_first - t_arrive)
        tpot_s = LatencyStats.of(tpot)
        e2e = LatencyStats.of(t_done - t_arrive)
        qd = LatencyStats.of(cat("t_admit") - t_arrive)

        total_reps = sum(len(rt.replicas) for rt in self._rts)
        util = 0.0
        if sim_result.makespan > 0 and total_reps:
            busy = sum(sim_result.resource_busy.get(rt._res(r.index), 0.0)
                       for rt in self._rts for r in rt.replicas)
            util = busy / (total_reps * sim_result.makespan)

        fleet_av = 1.0
        if total_reps:
            fleet_av = sum(pool_reports[s.name].availability
                           * len(rt.replicas)
                           for s, rt in zip(pools, self._rts)) / total_reps

        trips: Dict[str, int] = {}
        open_time: Dict[str, float] = {}
        if self._breakers is not None:
            for spec, b in zip(pools, self._breakers):
                b.finalize(end_t)
                trips[spec.name] = b.n_trips
                open_time[spec.name] = b.time_open

        t_out: Dict[str, float] = {}
        if self.health is not None:
            for i, spec in enumerate(pools):
                extra = sum(end_t - self._out_since[i][r]
                            for r in range(len(self._in_rot[i]))
                            if not self._in_rot[i][r])
                t_out[spec.name] = self._t_out[i] + extra

        en_seconds: Dict[str, float] = {}
        cost = 0.0
        for i, spec in enumerate(pools):
            secs = (self._en_seconds[i]
                    + self._en_count[i] * (end_t - self._en_last[i]))
            en_seconds[spec.name] = secs
            cost += secs * spec.cost_rate

        if self.probe is not None:
            self._obs_emit(end_t)
            self.probe.flush()

        reports = list(pool_reports.values())
        return ClusterReport(
            workload=self.workload.name,
            router=self.router.name,
            pools=pool_reports,
            replicas=total_reps,
            duration=sim_result.makespan,
            n_offered=self.n_offered,
            n_requests=self.n_completed,
            output_tokens=sum(rt._total_out_tokens for rt in self._rts),
            ttft=ttft, tpot=tpot_s, e2e=e2e, queue_delay=qd,
            replica_util=util,
            availability=(self.n_completed / self.n_offered
                          if self.n_offered else 1.0),
            fleet_availability=fleet_av,
            n_failures=sum(r.n_failures for r in reports),
            n_retries=sum(r.n_retries for r in reports),
            n_failovers=self.n_failovers,
            retries_suppressed=self.retries_suppressed,
            n_failopen=self.n_failopen,
            n_abandoned=sum(r.n_abandoned for r in reports),
            n_shed=sum(r.n_shed for r in reports),
            n_lost=dict(self.n_lost),
            hedges_issued=self.hedges_issued,
            hedges_won=self.hedges_won,
            hedge_waste_tokens=self.hedge_waste_tokens,
            breaker_trips=trips,
            breaker_open_time=open_time,
            time_out_of_rotation=t_out,
            n_routed={s.name: n for s, n in zip(pools, self.n_routed)},
            scale_events=list(self.scale_events),
            enabled_seconds=en_seconds,
            cost=cost,
            events=self.routing_events)


def simulate_cluster(pools: Sequence[ReplicaPool], workload: Workload,
                     router: Optional[RouterPolicy] = None,
                     **kwargs) -> ClusterReport:
    """One-shot convenience wrapper around :class:`ClusterSimulator`."""
    return ClusterSimulator(pools, workload, router, **kwargs).run()


# ---------------------------------------------------------------------------
# Monte-Carlo cluster simulation
# ---------------------------------------------------------------------------


@dataclass
class MonteCarloClusterReport:
    """Cross-seed cluster estimate: per-seed reports + summary stats."""

    workload: str
    router: str
    pool_names: Tuple[str, ...]
    seeds: Tuple[int, ...]
    reports: List[ClusterReport]
    stats: Dict[str, "object"]

    @property
    def num_seeds(self) -> int:
        return len(self.seeds)

    def stat(self, name: str):
        return self.stats[name]

    @property
    def availability(self):
        return self.stats["availability"]

    @property
    def throughput_rps(self):
        return self.stats["throughput_rps"]

    @property
    def cost(self):
        return self.stats["cost"]

    def summary(self) -> str:
        a = self.stats["availability"]
        x = self.stats["throughput_rps"]
        e = self.stats["e2e_p99"]
        c = self.stats["cost"]
        return (
            f"mc-cluster[{self.router}|{self.workload}] "
            f"{len(self.pool_names)} pools, {self.num_seeds} seeds: "
            f"{x.mean:.2f} ± {x.half_width:.2f} req/s, "
            f"availability = {a.mean:.4%} ± {a.half_width:.4%} "
            f"(CI lo {a.ci_lo:.4%}), E2E p99 = {e.mean:.2f} ± "
            f"{e.half_width:.2f} s, cost = {c.mean:.0f}")


class MonteCarloClusterSimulator:
    """Runs a :class:`ClusterSimulator` per seed row of a
    :class:`~repro.serve_sim.workload.RequestBatch` and reduces the
    reports to cross-seed :class:`~repro.serve_sim.monte_carlo.SeedStats`.

    Each seed gets an independent fault draw per pool — pool ``i``
    compiles its :class:`~repro.serve_sim.faults.FailureModel` under
    seed ``(model.seed, i, scenario_seed)`` so pools never share outage
    schedules by accident; explicit :class:`ReplicaFault` lists stay
    deterministic across seeds (matching the standalone Monte-Carlo
    convention).  ``router_factory`` builds a *fresh* router per seed
    (routers carry mutable pick state).
    """

    def __init__(self, pools: Sequence[ReplicaPool], batch: RequestBatch,
                 router_factory: Optional[Callable[[], RouterPolicy]] = None,
                 **cluster_kwargs):
        if not isinstance(batch, RequestBatch):
            raise TypeError(f"need a RequestBatch, got {type(batch)!r}")
        if "fault_seed" in cluster_kwargs:
            raise ValueError("fault_seed is derived per seed; "
                             "set FailureModel.seed instead")
        self.pools = list(pools)
        self.batch = batch
        self.router_factory = (router_factory if router_factory is not None
                               else RoundRobinRouter)
        self.cluster_kwargs = cluster_kwargs

    def _fault_seeds(self, seed: int) -> list:
        return [((spec.failures.seed, i, seed)
                 if isinstance(spec.failures, FailureModel) else None)
                for i, spec in enumerate(self.pools)]

    def run(self) -> MonteCarloClusterReport:
        from repro.serve_sim.monte_carlo import SeedStats, _cross_seed_stats

        reports: List[ClusterReport] = []
        for k in range(self.batch.num_seeds):
            seed = int(self.batch.seeds[k])
            sim = ClusterSimulator(
                self.pools, self.batch.workload(k),
                router=self.router_factory(),
                fault_seed=self._fault_seeds(seed),
                **self.cluster_kwargs)
            reports.append(sim.run())

        stats = _cross_seed_stats(reports)
        for key, fn in (
                ("cost", lambda r: r.cost),
                ("n_failovers", lambda r: float(r.n_failovers)),
                ("hedges_issued", lambda r: float(r.hedges_issued)),
                ("hedges_won", lambda r: float(r.hedges_won)),
                ("fleet_availability", lambda r: r.fleet_availability),
                ("n_lost", lambda r: float(r.n_lost_total))):
            stats[key] = SeedStats.of([fn(r) for r in reports])
        r0 = reports[0]
        return MonteCarloClusterReport(
            workload=self.batch.name, router=r0.router,
            pool_names=tuple(p.name for p in self.pools),
            seeds=tuple(int(s) for s in self.batch.seeds),
            reports=reports, stats=stats)
