"""Per-request serving cost models derived from compiled task graphs.

The serving simulator needs two quantities per scheduler decision:

  * ``prefill_time(n_tokens)``          — processing a prompt of n tokens;
  * ``decode_step_time(n_active, ctx)`` — one batched decode step for
    ``n_active`` slots whose cached contexts total ``ctx`` tokens.

Both are derived from the same artifact every estimator backend consumes —
the hardware-adapted :class:`~repro.core.taskgraph.compiler.CompiledGraph`
— by estimating a small set of calibration shape cells and fitting the
affine model

    T_prefill(s)    = F_p + P_p * s
    T_decode(b, c)  = F_d + P_d * b + C_d * b * c

(F: fixed launch/latency floor, P: per-token compute/memory, C: per
cached-token KV/state read).  Because calibration graphs carry
:class:`~repro.core.taskgraph.anno.RateAnno`s, a what-if sweep point
re-annotates the cached graphs in O(n_tasks) (``reannotate``) instead of
recompiling — the paper's click-of-a-button loop, extended from "one
training step" to "a serving fleet under traffic".

:class:`ServingCostModel` itself is a plain dataclass, so tests and the
capacity planner can also construct synthetic models directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.config import ModelConfig, ShapeConfig
from repro.core.estimator import get_backend
from repro.core.hw import SystemDescription
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops
from repro.core.taskgraph.compiler import (CompiledGraph, CompilePlan,
                                           compile_ops, reannotate,
                                           structural_key)


@dataclass(frozen=True)
class ServingCostModel:
    """Affine per-request cost surface for one (model, system) pair."""

    name: str = "serving_cost"
    prefill_fixed: float = 0.0       # seconds per prefill launch
    prefill_per_token: float = 1e-4  # seconds per prompt token
    decode_fixed: float = 0.0        # seconds per decode step (launch floor)
    decode_per_token: float = 1e-4   # seconds per active slot per step
    decode_per_ctx_token: float = 0.0   # seconds per cached token per step

    def prefill_time(self, n_tokens: int) -> float:
        return self.prefill_fixed + self.prefill_per_token * max(0, n_tokens)

    def decode_step_time(self, n_active: int, total_ctx: int) -> float:
        if n_active <= 0:
            return 0.0
        return (self.decode_fixed + self.decode_per_token * n_active
                + self.decode_per_ctx_token * max(0, total_ctx))


def _solve_decode(t11: float, t21: float, t22: float,
                  b1: int, b2: int, c1: int, c2: int
                  ) -> Tuple[float, float, float]:
    """Fit T(b,c) = F + P*b + C*b*c from three calibration estimates."""
    c_d = max(0.0, (t22 - t21) / (b2 * (c2 - c1)))
    p_d = max(0.0, (t21 - t11) / (b2 - b1) - c_d * c1)
    f_d = max(0.0, t11 - p_d * b1 - c_d * b1 * c1)
    return f_d, p_d, c_d


class ServingCostModelBuilder:
    """Builds :class:`ServingCostModel`s from compiled calibration graphs.

    One builder per (model config, compile plan, shard plan); call
    :meth:`model_for` per system.  Calibration graphs are cached by the
    system's *structural* key (on-chip capacity, array alignment) and
    re-annotated for systems that differ only in physical annotations —
    the same trick :class:`~repro.core.dse.DesignSpaceExplorer` uses, so
    a serving sweep over chip variants costs O(n_tasks) per point.
    """

    def __init__(self, cfg: ModelConfig,
                 plan: Optional[CompilePlan] = None,
                 shard: Optional[ShardPlan] = None,
                 backend: str = "analytic",
                 calib_batches: Tuple[int, int] = (1, 8),
                 calib_ctx: Tuple[int, int] = (512, 4096)):
        b1, b2 = calib_batches
        c1, c2 = calib_ctx
        if b2 <= b1 or c2 <= c1:
            raise ValueError("need calib_batches[1] > [0] and calib_ctx[1] > [0]")
        self.cfg = cfg
        self.plan = plan or CompilePlan()
        self.shard = shard or ShardPlan(data=1, model=1)
        self.backend = backend
        self.calib_batches = (b1, b2)
        self.calib_ctx = (c1, c2)
        # structural_key -> {cell_name: CompiledGraph}
        self._cache: Dict[Tuple, Dict[str, CompiledGraph]] = {}
        self.stats = {"compiles": 0, "reannotations": 0}

    def _cells(self) -> Dict[str, ShapeConfig]:
        b1, b2 = self.calib_batches
        c1, c2 = self.calib_ctx
        return {
            "decode_b1c1": ShapeConfig("decode_b1c1", c1, b1, "decode"),
            "decode_b2c1": ShapeConfig("decode_b2c1", c1, b2, "decode"),
            "decode_b2c2": ShapeConfig("decode_b2c2", c2, b2, "decode"),
            "prefill_c1": ShapeConfig("prefill_c1", c1, 1, "prefill"),
            "prefill_c2": ShapeConfig("prefill_c2", c2, 1, "prefill"),
        }

    def _graphs(self, system: SystemDescription) -> Dict[str, CompiledGraph]:
        key = structural_key(system)
        hit = self._cache.get(key)
        if hit is None:
            graphs = {
                name: compile_ops(lm_step_ops(self.cfg, cell, self.shard),
                                  system, self.plan)
                for name, cell in self._cells().items()
            }
            self.stats["compiles"] += len(graphs)
            self._cache[key] = graphs
            return graphs
        if next(iter(hit.values())).system is system:
            return hit
        self.stats["reannotations"] += len(hit)
        return {name: reannotate(g, system) for name, g in hit.items()}

    def model_for(self, system: SystemDescription) -> ServingCostModel:
        graphs = self._graphs(system)
        est = get_backend(self.backend)
        t = {name: est.estimate(g).step_time for name, g in graphs.items()}
        b1, b2 = self.calib_batches
        c1, c2 = self.calib_ctx
        f_d, p_d, c_d = _solve_decode(
            t["decode_b1c1"], t["decode_b2c1"], t["decode_b2c2"],
            b1, b2, c1, c2)
        p_p = max(0.0, (t["prefill_c2"] - t["prefill_c1"]) / (c2 - c1))
        f_p = max(0.0, t["prefill_c1"] - p_p * c1)
        return ServingCostModel(
            name=f"{system.name}", prefill_fixed=f_p, prefill_per_token=p_p,
            decode_fixed=f_d, decode_per_token=p_d, decode_per_ctx_token=c_d)
