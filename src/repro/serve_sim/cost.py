"""Per-request serving cost models derived from compiled task graphs.

The serving simulator needs two quantities per scheduler decision:

  * ``prefill_time(n_tokens)``          — processing a prompt of n tokens;
  * ``decode_step_time(n_active, ctx)`` — one batched decode step for
    ``n_active`` slots whose cached contexts total ``ctx`` tokens.

Both are derived from the same artifact every estimator backend consumes —
the hardware-adapted :class:`~repro.core.taskgraph.compiler.CompiledGraph`
— by estimating a small set of calibration shape cells and fitting the
affine model

    T_prefill(s)    = F_p + P_p * s
    T_decode(b, c)  = F_d + P_d * b + C_d * b * c

(F: fixed launch/latency floor, P: per-token compute/memory, C: per
cached-token KV/state read).  Because calibration graphs carry
:class:`~repro.core.taskgraph.anno.RateAnno`s, a what-if sweep point
re-annotates the cached graphs in O(n_tasks) (``reannotate``) instead of
recompiling — the paper's click-of-a-button loop, extended from "one
training step" to "a serving fleet under traffic".

:class:`ServingCostModel` itself is a plain dataclass, so tests and the
capacity planner can also construct synthetic models directly.

For the serving simulator's task-graph mode (``phase_tasks=N``), a model
can additionally carry :class:`PhaseProfile`\\ s — per-chunk compute/DMA
shares derived from the *compiled* prefill/decode graphs (real task
kinds and durations grouped into N chunks), so injected phase graphs
show the calibration graphs' actual compute/DMA interleaving instead of
a synthetic equal split.  Profiles are shape-normalized: chunk durations
are fractions of the phase total, so the affine surface still sets every
phase's exact duration and profile-on metrics match profile-off ones.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.core.config import ModelConfig, ShapeConfig
from repro.core.estimator import get_backend
from repro.core.hw import SystemDescription
from repro.core.taskgraph.builders import ShardPlan, lm_step_ops
from repro.core.taskgraph.compiler import (CompiledGraph, CompilePlan,
                                           compile_ops, reannotate,
                                           structural_key)


@dataclass(frozen=True)
class PhaseProfile:
    """Compiled-graph chunk structure for one phase kind.

    ``compute[i]`` is chunk i's share of the phase duration (shares sum
    to 1); ``dma[i]`` is the duration of the KV/weight DMA overlapping
    chunk i, as a fraction of the same total.  Built by
    :meth:`ServingCostModelBuilder.model_for` from the calibration
    graphs' real task kinds/durations via :func:`profile_from_graph`.
    """

    compute: Tuple[float, ...]
    dma: Tuple[float, ...]

    def __post_init__(self):
        if not self.compute or len(self.compute) != len(self.dma):
            raise ValueError("profile needs matching non-empty chunk tuples")

    def chunk_durations(self, dur: float) -> Tuple[List[float], List[float]]:
        """Scale the profile to a phase of total duration ``dur``.

        The last compute chunk absorbs the sequential-accumulation
        residue so the chunk chain's end lands exactly on ``dur`` — the
        same exactness contract the affine equal split keeps.
        """
        comp = [dur * f for f in self.compute]
        s = 0.0
        for d in comp[:-1]:
            s += d
        comp[-1] = dur - s
        return comp, [dur * f for f in self.dma]


def profile_from_graph(graph: CompiledGraph, n_chunks: int) -> PhaseProfile:
    """Group a compiled phase graph's tasks into ``n_chunks`` chunks.

    Walks tasks in compiled order (the engines' deterministic dispatch
    order), splitting compute/collective time greedily into chunks of
    roughly equal compute and attributing each DMA to the chunk active
    when it issues — preserving the graph's compute/DMA interleaving and
    its exact compute-vs-DMA ratio.  All durations are normalized by the
    total compute time, so ``chunk_durations(T)`` reproduces a phase of
    total compute ``T`` with proportionally scaled DMA overlap.
    """
    if n_chunks < 1:
        raise ValueError("n_chunks must be >= 1")
    durs = graph.durations
    kinds = [t.kind for t in graph.tasks]
    total = sum(float(durs[i]) for i, k in enumerate(kinds) if k != "dma")
    if total <= 0.0:
        frac = 1.0 / n_chunks
        return PhaseProfile(compute=(frac,) * n_chunks,
                            dma=(0.0,) * n_chunks)
    comp = [0.0] * n_chunks
    dma = [0.0] * n_chunks
    target = total / n_chunks
    ci = 0
    cum = 0.0
    for i, k in enumerate(kinds):
        d = float(durs[i])
        if k == "dma":
            dma[ci] += d
        else:
            comp[ci] += d
            cum += d
            if ci < n_chunks - 1 and cum >= (ci + 1) * target:
                ci += 1
    return PhaseProfile(compute=tuple(c / total for c in comp),
                        dma=tuple(x / total for x in dma))


@dataclass(frozen=True)
class ServingCostModel:
    """Affine per-request cost surface for one (model, system) pair.

    ``prefill_profile``/``decode_profile`` optionally describe how a
    phase of a given total duration decomposes into compiled-graph
    chunks (task-graph serving mode); ``None`` keeps the synthetic
    equal split.
    """

    name: str = "serving_cost"
    prefill_fixed: float = 0.0       # seconds per prefill launch
    prefill_per_token: float = 1e-4  # seconds per prompt token
    decode_fixed: float = 0.0        # seconds per decode step (launch floor)
    decode_per_token: float = 1e-4   # seconds per active slot per step
    decode_per_ctx_token: float = 0.0   # seconds per cached token per step
    prefill_profile: Optional[PhaseProfile] = None
    decode_profile: Optional[PhaseProfile] = None

    def prefill_time(self, n_tokens: int) -> float:
        return self.prefill_fixed + self.prefill_per_token * max(0, n_tokens)

    def decode_step_time(self, n_active: int, total_ctx: int) -> float:
        if n_active <= 0:
            return 0.0
        return (self.decode_fixed + self.decode_per_token * n_active
                + self.decode_per_ctx_token * max(0, total_ctx))

    def scaled(self, factor: float) -> "ServingCostModel":
        """A copy with every cost coefficient multiplied by ``factor``.

        ``factor > 1`` models a uniformly slower system — the brownout
        what-if behind degraded-mode capacity planning (what does the SLO
        look like if the fleet runs at half speed?); ``factor < 1`` a
        faster chip variant.  Profiles are shape-normalized fractions, so
        they carry over unchanged."""
        if factor <= 0:
            raise ValueError("factor must be > 0")
        return replace(
            self, name=f"{self.name}*{factor:g}",
            prefill_fixed=self.prefill_fixed * factor,
            prefill_per_token=self.prefill_per_token * factor,
            decode_fixed=self.decode_fixed * factor,
            decode_per_token=self.decode_per_token * factor,
            decode_per_ctx_token=self.decode_per_ctx_token * factor)


def _solve_decode(t11: float, t21: float, t22: float,
                  b1: int, b2: int, c1: int, c2: int
                  ) -> Tuple[float, float, float]:
    """Fit T(b,c) = F + P*b + C*b*c from three calibration estimates."""
    c_d = max(0.0, (t22 - t21) / (b2 * (c2 - c1)))
    p_d = max(0.0, (t21 - t11) / (b2 - b1) - c_d * c1)
    f_d = max(0.0, t11 - p_d * b1 - c_d * b1 * c1)
    return f_d, p_d, c_d


class ServingCostModelBuilder:
    """Builds :class:`ServingCostModel`s from compiled calibration graphs.

    One builder per (model config, compile plan, shard plan); call
    :meth:`model_for` per system.  Calibration graphs are cached by the
    system's *structural* key (on-chip capacity, array alignment) and
    re-annotated for systems that differ only in physical annotations —
    the same trick :class:`~repro.core.dse.DesignSpaceExplorer` uses, so
    a serving sweep over chip variants costs O(n_tasks) per point.
    """

    def __init__(self, cfg: ModelConfig,
                 plan: Optional[CompilePlan] = None,
                 shard: Optional[ShardPlan] = None,
                 backend: str = "analytic",
                 calib_batches: Tuple[int, int] = (1, 8),
                 calib_ctx: Tuple[int, int] = (512, 4096)):
        b1, b2 = calib_batches
        c1, c2 = calib_ctx
        if b2 <= b1 or c2 <= c1:
            raise ValueError("need calib_batches[1] > [0] and calib_ctx[1] > [0]")
        self.cfg = cfg
        self.plan = plan or CompilePlan()
        self.shard = shard or ShardPlan(data=1, model=1)
        self.backend = backend
        self.calib_batches = (b1, b2)
        self.calib_ctx = (c1, c2)
        # structural_key -> {cell_name: CompiledGraph}
        self._cache: Dict[Tuple, Dict[str, CompiledGraph]] = {}
        self.stats = {"compiles": 0, "reannotations": 0}

    def _cells(self) -> Dict[str, ShapeConfig]:
        b1, b2 = self.calib_batches
        c1, c2 = self.calib_ctx
        return {
            "decode_b1c1": ShapeConfig("decode_b1c1", c1, b1, "decode"),
            "decode_b2c1": ShapeConfig("decode_b2c1", c1, b2, "decode"),
            "decode_b2c2": ShapeConfig("decode_b2c2", c2, b2, "decode"),
            "prefill_c1": ShapeConfig("prefill_c1", c1, 1, "prefill"),
            "prefill_c2": ShapeConfig("prefill_c2", c2, 1, "prefill"),
        }

    def _graphs(self, system: SystemDescription) -> Dict[str, CompiledGraph]:
        key = structural_key(system)
        hit = self._cache.get(key)
        if hit is None:
            graphs = {
                name: compile_ops(lm_step_ops(self.cfg, cell, self.shard),
                                  system, self.plan)
                for name, cell in self._cells().items()
            }
            self.stats["compiles"] += len(graphs)
            self._cache[key] = graphs
            return graphs
        if next(iter(hit.values())).system is system:
            return hit
        self.stats["reannotations"] += len(hit)
        return {name: reannotate(g, system) for name, g in hit.items()}

    def model_for(self, system: SystemDescription,
                  phase_chunks: int = 0) -> ServingCostModel:
        """Fit the affine surface; with ``phase_chunks=N > 0`` also attach
        :class:`PhaseProfile`\\ s derived from the large-shape calibration
        graphs (``prefill_c2``/``decode_b2c2``) so the task-graph serving
        mode injects the compiled chunk structure."""
        graphs = self._graphs(system)
        est = get_backend(self.backend)
        t = {name: est.estimate(g).step_time for name, g in graphs.items()}
        b1, b2 = self.calib_batches
        c1, c2 = self.calib_ctx
        f_d, p_d, c_d = _solve_decode(
            t["decode_b1c1"], t["decode_b2c1"], t["decode_b2c2"],
            b1, b2, c1, c2)
        p_p = max(0.0, (t["prefill_c2"] - t["prefill_c1"]) / (c2 - c1))
        f_p = max(0.0, t["prefill_c1"] - p_p * c1)
        pp = dp = None
        if phase_chunks > 0:
            pp = profile_from_graph(graphs["prefill_c2"], phase_chunks)
            dp = profile_from_graph(graphs["decode_b2c2"], phase_chunks)
        return ServingCostModel(
            name=f"{system.name}", prefill_fixed=f_p, prefill_per_token=p_p,
            decode_fixed=f_d, decode_per_token=p_d, decode_per_ctx_token=c_d,
            prefill_profile=pp, decode_profile=dp)
