"""Seeded fault injection for the virtual serving simulator.

The ROADMAP's million-user deployment is planned today under a
failure-free assumption; this module removes it at the concept phase,
the same way the paper's virtual models remove the "hardware exists"
assumption.  A :class:`FailureModel` draws per-replica failure windows
(MTBF/MTTR exponentials, crash or slow-degrade modes, optional
correlated zone outages) from a seeded generator;
:func:`compile_faults` normalizes either a model or an explicit list of
:class:`ReplicaFault` windows into a :class:`CompiledFaults` event
schedule the serving simulator injects as DES events.  A
:class:`RetryPolicy` governs what happens to requests in flight on a
crashed replica: bounded retries with exponential backoff + seeded
jitter, and per-request deadline abandonment.

Determinism contract: the same ``(model, seed)`` pair produces the same
windows bit-for-bit, and the scalar and fused Monte-Carlo serving paths
share this module's event schedule, availability arithmetic, and jitter
RNG stream — so availability/goodput under faults is bit-identical
across paths (``tests/test_faults.py`` enforces it).

Event ordering at equal timestamps (the tie-break contract audited by
the parity tests): fault/repair events fire before arrivals, arrivals
before retries, retries and completions in schedule order; within the
fault schedule, a repair at time ``t`` precedes a failure at ``t``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "ReplicaFault", "FailureModel", "RetryPolicy", "CompiledFaults",
    "compile_faults",
]


@dataclass(frozen=True)
class ReplicaFault:
    """One explicit failure window: ``replica`` is down (crash mode) or
    degraded (slow mode) on ``[t_fail, t_repair)``."""

    replica: int
    t_fail: float
    t_repair: float

    def __post_init__(self):
        if self.replica < 0:
            raise ValueError("replica must be >= 0")
        if not (0.0 <= self.t_fail < self.t_repair):
            raise ValueError(
                f"need 0 <= t_fail < t_repair, got "
                f"[{self.t_fail}, {self.t_repair})")


@dataclass(frozen=True)
class FailureModel:
    """Seeded per-replica failure process.

    Each replica alternates up/down: up-times are exponential with mean
    ``mtbf`` seconds, down-times exponential with mean ``mttr`` seconds,
    drawn per replica (in replica order) from ``default_rng(seed)`` up
    to ``horizon`` seconds of simulated time.

    ``mode``:
      * ``"crash"`` — the replica drops its in-flight requests (they are
        retried per the :class:`RetryPolicy`) and admits nothing until
        repair; downtime counts against availability.
      * ``"slow"``  — a brownout: phases *started* during the window run
        ``slow_factor`` times slower; nothing is cancelled and
        availability stays 1.0 (the degradation shows up in the latency
        percentiles instead).

    ``zone_size > 1`` groups replicas into consecutive zones sharing one
    outage process (modeling a rack/PSU domain): each outage takes down
    the whole zone with probability ``correlated_p``, otherwise one
    uniformly drawn member.
    """

    mtbf: float = 300.0
    mttr: float = 10.0
    mode: str = "crash"
    slow_factor: float = 4.0
    zone_size: int = 0
    correlated_p: float = 0.0
    seed: int = 0
    horizon: float = 3600.0

    def __post_init__(self):
        if not (math.isfinite(self.mtbf) and self.mtbf > 0):
            raise ValueError("mtbf must be finite and > 0")
        if not (math.isfinite(self.mttr) and self.mttr > 0):
            raise ValueError("mttr must be finite and > 0")
        if self.mode not in ("crash", "slow"):
            raise ValueError(f"unknown failure mode {self.mode!r}")
        if not (math.isfinite(self.slow_factor) and self.slow_factor >= 1.0):
            raise ValueError("slow_factor must be finite and >= 1.0")
        if not isinstance(self.zone_size, int) or self.zone_size < 0:
            raise ValueError("zone_size must be an int >= 0")
        if not (0.0 <= self.correlated_p <= 1.0):
            raise ValueError("correlated_p must be in [0, 1]")
        if not (math.isfinite(self.horizon) and self.horizon > 0):
            raise ValueError("horizon must be finite and > 0")

    def windows(self, replicas: int, seed=None) -> List[ReplicaFault]:
        """Draw the failure windows for ``replicas`` replicas.

        ``seed`` overrides the model's own seed (the Monte-Carlo
        simulator passes ``(self.seed, scenario_seed)`` so each seed
        gets an independent but reproducible draw).
        """
        rng = np.random.default_rng(self.seed if seed is None else seed)
        out: List[ReplicaFault] = []
        if self.zone_size > 1:
            zones = [list(range(z, min(z + self.zone_size, replicas)))
                     for z in range(0, replicas, self.zone_size)]
            for zone in zones:
                t = float(rng.exponential(self.mtbf))
                while t < self.horizon:
                    d = float(rng.exponential(self.mttr))
                    if rng.random() < self.correlated_p:
                        victims = zone
                    else:
                        victims = [zone[int(rng.integers(len(zone)))]]
                    for r in victims:
                        out.append(ReplicaFault(r, t, t + d))
                    t += d + float(rng.exponential(self.mtbf))
        else:
            for r in range(replicas):
                t = float(rng.exponential(self.mtbf))
                while t < self.horizon:
                    d = float(rng.exponential(self.mttr))
                    out.append(ReplicaFault(r, t, t + d))
                    t += d + float(rng.exponential(self.mtbf))
        return out


@dataclass(frozen=True)
class RetryPolicy:
    """What happens to a request whose replica crashed under it.

    Attempt ``a`` (1-based; the first failure makes ``a = 1``) is
    re-enqueued after ``backoff * backoff_factor**(a-1)`` seconds,
    multiplied by ``1 + jitter * u`` with ``u ~ U[0,1)`` from the seeded
    fault RNG stream.  The request is abandoned when it has already
    failed ``max_attempts`` times, or when the retry would land more
    than ``deadline`` seconds after its original arrival.
    """

    max_attempts: int = 3
    backoff: float = 0.05
    backoff_factor: float = 2.0
    jitter: float = 0.5
    deadline: float = math.inf

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not (math.isfinite(self.backoff) and self.backoff >= 0):
            raise ValueError("backoff must be finite and >= 0")
        if not (math.isfinite(self.backoff_factor)
                and self.backoff_factor >= 1.0):
            raise ValueError("backoff_factor must be finite and >= 1")
        if not (math.isfinite(self.jitter) and self.jitter >= 0):
            raise ValueError("jitter must be finite and >= 0")
        if math.isnan(self.deadline) or self.deadline <= 0:
            raise ValueError("deadline must be > 0 (inf allowed)")


def _merge_windows(spans: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Union of possibly-overlapping ``(t_fail, t_repair)`` spans."""
    spans.sort()
    merged: List[Tuple[float, float]] = []
    for lo, hi in spans:
        if merged and lo <= merged[-1][1]:
            if hi > merged[-1][1]:
                merged[-1] = (merged[-1][0], hi)
        else:
            merged.append((lo, hi))
    return merged


class CompiledFaults:
    """A normalized, per-run failure schedule.

    ``events`` is the time-sorted DES injection list of ``(t, code, r)``
    with code ``0`` = repair and ``1`` = failure — so a repair at time
    ``t`` is processed before a failure at the same ``t`` (a replica
    that flaps at one instant ends that instant *down*, never admits a
    request for zero time).  Per-replica windows are pre-merged, so
    fail/repair events strictly alternate per replica.

    Both serving paths (scalar DES and fused Monte-Carlo) consume the
    same instance, and both compute availability through
    :meth:`availability` — one shared arithmetic, bit-identical results.
    """

    __slots__ = ("windows", "events", "mode", "slow_factor", "jitter_seed")

    def __init__(self, windows: List[ReplicaFault], mode: str,
                 slow_factor: float, jitter_seed) -> None:
        self.windows = windows
        self.mode = mode
        self.slow_factor = slow_factor
        self.jitter_seed = jitter_seed      # seeds the retry-jitter RNG
        events: List[Tuple[float, int, int]] = []
        for w in windows:
            events.append((w.t_fail, 1, w.replica))
            events.append((w.t_repair, 0, w.replica))
        events.sort()
        self.events = events

    def rng(self) -> np.random.Generator:
        """Fresh retry-jitter generator (one per simulation run)."""
        return np.random.default_rng(self.jitter_seed)

    def n_failures(self, makespan: float) -> int:
        """Failure windows that began by ``makespan``."""
        return sum(1 for w in self.windows if w.t_fail <= makespan)

    def availability(self, makespan: float, replicas: int) -> float:
        """Fraction of replica-seconds the fleet was up over the run.

        Slow-degrade windows don't count as downtime (the replica is
        still serving, just slower)."""
        if self.mode != "crash" or makespan <= 0.0 or not self.windows:
            return 1.0
        down = 0.0
        for w in self.windows:
            lo = min(w.t_fail, makespan)
            hi = min(w.t_repair, makespan)
            if hi > lo:
                down += hi - lo
        return 1.0 - down / (replicas * makespan)


FaultSpec = Union[FailureModel, Sequence[ReplicaFault]]


def compile_faults(failures: FaultSpec, replicas: int,
                   seed=None) -> Optional[CompiledFaults]:
    """Normalize a fault spec into a :class:`CompiledFaults` schedule.

    ``failures`` is a :class:`FailureModel` (windows drawn from its seed,
    or from ``seed`` when given) or an explicit :class:`ReplicaFault`
    sequence (deterministic — identical every Monte-Carlo seed).
    Overlapping windows on one replica are merged.  Returns ``None`` for
    an empty schedule so callers can skip the fault machinery entirely.
    """
    if isinstance(failures, FailureModel):
        raw = failures.windows(replicas, seed=seed)
        mode, slow_factor = failures.mode, failures.slow_factor
        jitter_seed = failures.seed if seed is None else seed
    else:
        raw = list(failures)
        mode, slow_factor = "crash", 1.0
        jitter_seed = 0 if seed is None else seed
    per_rep: dict = {}
    for w in raw:
        if not isinstance(w, ReplicaFault):
            raise TypeError(f"expected ReplicaFault, got {type(w).__name__}")
        if w.replica >= replicas:
            raise ValueError(
                f"fault window names replica {w.replica} but the "
                f"simulation has {replicas}")
        per_rep.setdefault(w.replica, []).append((w.t_fail, w.t_repair))
    windows = [ReplicaFault(r, lo, hi)
               for r in sorted(per_rep)
               for lo, hi in _merge_windows(per_rep[r])]
    if not windows:
        return None
    return CompiledFaults(windows, mode, slow_factor, jitter_seed)
