"""Seed-batched Monte-Carlo serving simulation.

Tail-latency questions (p99 TTFT under stochastic traffic) need many
traffic seeds per design point; looping the scalar
:class:`~repro.serve_sim.simulator.ServingSimulator` makes each seed pay
the full event-machinery cost (DES heap, ``Request``/``InFlight``
objects, closure dispatch).  This module makes seed replication cheap by
splitting the hot loop along the tentpole's policy/advance seam:

* **generation** — a :class:`~repro.serve_sim.workload.RequestBatch`
  pre-generates all ``K`` seeds' arrival/length arrays without building
  a single ``Request`` object;
* **state advance** — per-request timestamps live in
  :class:`~repro.serve_sim.simulator.LaneStateArrays` columns (one SoA
  per seed), latency populations and cross-seed summaries reduce to
  vectorized column arithmetic, and fused decode-leap spans accumulate
  via ``np.add.accumulate`` (:func:`~repro.serve_sim.simulator._leap_spans`);
* **policy** — the branchy per-event decisions.  For the stock
  :class:`~repro.serve_sim.scheduler.ContinuousBatchingScheduler` under
  the stock affine :class:`~repro.serve_sim.cost.ServingCostModel` the
  decision sequence is replayed by a specialized tight loop
  (:func:`_simulate_continuous_fast`) with plain-list replica state and
  no event heap — bit-identical to the scalar simulator by construction
  (golden tests in ``tests/test_monte_carlo.py``), several times faster
  per seed.  Everything else (custom schedulers, overridden cost
  methods, unsorted traces) falls back to the scalar simulator per seed,
  so parity is unconditional.

Cross-seed lock-step arrays (advance all seeds in one NumPy/`jax.vmap`
step) were evaluated and deliberately not used for the event loop: the
decode-leap fusion that makes the scalar path fast makes the per-seed
step *irregular* (each seed leaps a different number of steps per
event), so a lock-step backend must either desugar to ~per-token steps
(1e6+ tiny masked array ops for a 10k-request trace — slower than the
tight loop) or give up fusion.  The array batching therefore lives where
the work really is uniform: workload generation, leap-span
accumulation, per-seed metric columns, and cross-seed statistics.  See
ROADMAP for the `lax.scan` regular-step design that would change this.

The emitted :class:`MonteCarloServingReport` carries one
:class:`~repro.serve_sim.simulator.ServingReport` per seed plus
:class:`SeedStats` (mean / sample std / 95% normal-approximation CI over
seeds) for every TTFT/TPOT/E2E/queue-delay percentile, which
``DesignSpaceExplorer.sweep_serving(num_seeds=K)`` and the capacity
planner's CI-conservative bisection consume.
"""
from __future__ import annotations

from bisect import bisect_left, bisect_right, insort
from collections import deque
from dataclasses import dataclass
from heapq import heappop, heappush
from math import sqrt
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve_sim.cost import ServingCostModel
from repro.serve_sim.faults import (FailureModel, RetryPolicy,
                                    compile_faults)
from repro.serve_sim.scheduler import (BatchScheduler,
                                       ContinuousBatchingScheduler)
from repro.serve_sim.simulator import (LaneStateArrays, ServingReport,
                                       ServingSimulator, _LazyRequests,
                                       _LeapScratch, _leap_spans)
from repro.serve_sim.workload import RequestBatch


@dataclass(frozen=True)
class SeedStats:
    """Cross-seed distribution of one scalar metric (e.g. TTFT p99).

    ``ci_lo``/``ci_hi`` bound the *mean* at 95% confidence via the
    normal approximation (mean ± 1.96·std/√K, sample std); with K < 2
    the interval collapses to the point estimate.  ``values`` keeps the
    per-seed draws for attainment counts and convergence plots.
    """

    n: int
    mean: float
    std: float
    ci_lo: float
    ci_hi: float
    values: Tuple[float, ...] = ()

    @property
    def half_width(self) -> float:
        return (self.ci_hi - self.ci_lo) / 2.0

    @staticmethod
    def of(values) -> "SeedStats":
        vals = tuple(float(v) for v in values)
        k = len(vals)
        if k == 0:
            return SeedStats(0, 0.0, 0.0, 0.0, 0.0, ())
        a = np.asarray(vals)
        mean = float(a.mean())
        if k < 2:
            return SeedStats(k, mean, 0.0, mean, mean, vals)
        std = float(a.std(ddof=1))
        hw = 1.96 * std / sqrt(k)
        return SeedStats(k, mean, std, mean - hw, mean + hw, vals)

    def __str__(self) -> str:
        return f"{self.mean:g} ± {self.half_width:g} (95% CI, n={self.n})"


#: latency populations × summaries exposed as cross-seed :class:`SeedStats`
_METRIC_KEYS = tuple(f"{m}_{p}"
                     for m in ("ttft", "tpot", "e2e", "queue_delay")
                     for p in ("mean", "p50", "p95", "p99"))


@dataclass
class MonteCarloServingReport:
    """Cross-seed serving estimate: per-seed reports + summary statistics."""

    workload: str
    scheduler: str
    cost_model: str
    replicas: int
    slots: int
    seeds: Tuple[int, ...]
    reports: List[ServingReport]
    stats: Dict[str, SeedStats]

    @property
    def num_seeds(self) -> int:
        return len(self.seeds)

    @property
    def n_requests(self) -> int:
        """Total requests simulated across all seeds."""
        return sum(r.n_requests for r in self.reports)

    def stat(self, name: str) -> SeedStats:
        """Cross-seed stats for ``"<metric>_<summary>"`` (e.g.
        ``"ttft_p99"``), ``"throughput_rps"``, or ``"duration"``."""
        return self.stats[name]

    @property
    def ttft_p99(self) -> SeedStats:
        return self.stats["ttft_p99"]

    @property
    def tpot_p99(self) -> SeedStats:
        return self.stats["tpot_p99"]

    @property
    def e2e_p99(self) -> SeedStats:
        return self.stats["e2e_p99"]

    @property
    def throughput_rps(self) -> SeedStats:
        return self.stats["throughput_rps"]

    @property
    def availability(self) -> SeedStats:
        """Cross-seed replica availability (1.0 per seed without faults)."""
        return self.stats["availability"]

    @property
    def abandonment_rate(self) -> SeedStats:
        return self.stats["abandonment_rate"]

    def attainment(self, slo) -> float:
        """Fraction of seeds whose report satisfies ``slo``
        (anything with a ``satisfied_by(report) -> bool``)."""
        if not self.reports:
            return 0.0
        ok = sum(1 for r in self.reports if slo.satisfied_by(r))
        return ok / len(self.reports)

    def summary(self) -> str:
        t = self.stats["ttft_p99"]
        o = self.stats["tpot_p99"]
        e = self.stats["e2e_p99"]
        x = self.stats["throughput_rps"]
        s = (
            f"mc-serve[{self.cost_model}|{self.scheduler}|{self.workload}] "
            f"{self.replicas}x{self.slots} slots, {self.num_seeds} seeds: "
            f"{x.mean:.2f} ± {x.half_width:.2f} req/s\n"
            f"  TTFT p99 = {t.mean * 1e3:.0f} ± {t.half_width * 1e3:.0f} ms"
            f"   TPOT p99 = {o.mean * 1e3:.2f} ± {o.half_width * 1e3:.2f} ms"
            f"   E2E p99 = {e.mean:.2f} ± {e.half_width:.2f} s"
            f"   (95% CI over seeds)")
        if any(r.n_failures or r.n_retries or r.n_abandoned or r.n_shed
               for r in self.reports):
            a = self.stats["availability"]
            ab = self.stats["abandonment_rate"]
            at = self.stats["attempt_rps"]
            s += (
                f"\n  availability = {a.mean:.4%} ± {a.half_width:.4%}"
                f"   abandonment = {ab.mean:.2%} ± {ab.half_width:.2%}"
                f"   attempts = {at.mean:.2f} ± {at.half_width:.2f} req/s")
        return s


def _cross_seed_stats(reports: List[ServingReport]) -> Dict[str, SeedStats]:
    stats: Dict[str, SeedStats] = {}
    for key in _METRIC_KEYS:
        metric, _, pct = key.rpartition("_")
        stats[key] = SeedStats.of(
            [getattr(getattr(r, metric), pct) for r in reports])
    stats["throughput_rps"] = SeedStats.of(
        [r.throughput_rps for r in reports])
    stats["duration"] = SeedStats.of([r.duration for r in reports])
    # resilience metrics (degenerate — 1.0 / 0.0 / = throughput — when the
    # run had no fault injection, so consumers can read them uniformly)
    stats["availability"] = SeedStats.of([r.availability for r in reports])
    stats["abandonment_rate"] = SeedStats.of(
        [r.abandonment_rate for r in reports])
    stats["attempt_rps"] = SeedStats.of([r.attempt_rps for r in reports])
    return stats


def _simulate_continuous_fast(cost: ServingCostModel, times: List[float],
                              prompts: List[int], outputs: List[int],
                              replicas: int, slots: int,
                              wl_name: str, probe=None,
                              faults=None, retry=None) -> ServingReport:
    """Specialized replay of one open-loop trace under
    :class:`ContinuousBatchingScheduler` + the stock affine cost model.

    Re-implements exactly the event sequence the scalar
    :class:`ServingSimulator` express path produces — same tie-breaking
    (arrivals always precede same-time lane completions because they are
    enqueued first; lane-vs-lane ties resolve by submission sequence),
    same decode-leap fusion/speculation/rollback arithmetic (shared
    :func:`_leap_spans`), same ``busy_time``/makespan accumulation order
    — but with O(1) bookkeeping per event instead of the DES heap,
    ``Request``/``InFlight`` objects, and per-slot advance loops:

    * per-replica ``dec_total`` counts cumulative fused decode steps;
      a slot admitted at count ``a`` with ``o`` output tokens finishes
      when ``dec_total`` reaches ``a + o``, so slot finishes pop off a
      per-replica min-heap of packed integer keys
      (``threshold * slots + slot`` — plain ints heap-compare in C) and
      the scalar path's per-slot ``rem``/``ctx`` advance loop disappears
      (its values are recovered exactly from the counters — all
      integers);
    * the minimum remaining-token count (the fused-leap length) is
      ``heap[0] // slots - dec_total``, O(1) instead of a slot scan;
    * the next lane completion is ``min()`` over per-lane
      ``(end, seq, lane)`` tuples — a C tuple-compare pass instead of a
      Python scan per event;
    * finished-request rows buffer in a plain list and fill the
      :class:`LaneStateArrays` columns in one vectorized pass at the end.

    Bit-identical output is the contract; ``tests/test_monte_carlo.py``
    enforces it.  ``probe`` records the same serve/* metric names as the
    scalar simulator (one child probe per seed upstream), guarded by a
    single local None-check per site.  Enabled sites bump plain-int
    accumulators and a shared countdown (``obs_left``) — the same trick
    the scalar path's ``_obs_tick`` uses — and every
    ``probe.sample_every``-th instrumented event ``obs_tick`` appends
    one aligned sample to every serving track (occupancy is read
    straight off ``occ`` at tick time).  Simulation results are
    bit-identical with or without the probe.

    ``faults`` (a pre-compiled
    :class:`~repro.serve_sim.faults.CompiledFaults` or None) mirrors the
    scalar path's fault injection event-for-event: fault events hold the
    lowest sequence numbers (they beat arrivals — and everything else —
    at a tied timestamp), arrivals beat retries, and retries order
    against lane completions by ``(time, seq)`` exactly as the scalar
    heap would pop them.  A crash commits the fused-leap steps whose
    boundary precedes it, truncates the lane's busy time at the fault,
    frees slots in slot order and re-enqueues their requests under
    ``retry`` — every arithmetic operation in the same order as
    ``ServingSimulator._fail``/``_retry_or_abandon``, so per-seed
    reports stay bit-identical across the scalar and fused paths.
    """
    pf, pp = cost.prefill_fixed, cost.prefill_per_token
    df, dt, dc = (cost.decode_fixed, cost.decode_per_token,
                  cost.decode_per_ctx_token)
    R, S = replicas, slots
    n_req = len(times)
    scratch = _LeapScratch()
    INF = float("inf")

    # ---- fault-injection state (all inert when faults is None) ----------
    crash = faults is not None and faults.mode == "crash"
    slow_factor = faults.slow_factor if faults is not None else 1.0
    fault_events = faults.events if faults is not None else ()
    n_fe = len(fault_events)
    fi = 0
    nft = fault_events[0][0] if n_fe else INF   # next fault-event time
    down = [False] * R
    speed = [1.0] * R
    fbounds: List = [None] * R   # (step bounds, n_dec) of in-flight leap
    retries: List[tuple] = []    # (t_retry, seq, req index) min-heap
    attempts: Dict[int, int] = {}
    rng = faults.rng() if crash else None
    rp = retry if retry is not None else RetryPolicy()
    n_fail_events = n_retries = n_abandoned = 0
    last_retry_t = 0.0

    prb = probe
    n_queue = n_completed = n_leap_steps = n_spec = n_rollbacks = 0
    obs_every = obs_left = 1
    if prb is not None:
        p_queue = prb.counter("serve/queue_depth", unit="requests")
        p_completed = prb.counter("serve/completed", unit="requests")
        p_leaps = prb.counter("serve/leap_steps", unit="steps")
        p_spec = prb.counter("serve/spec_leaps")
        p_rollbacks = prb.counter("serve/rollbacks")
        p_failures = prb.counter("serve/failures")
        p_retries = prb.counter("serve/retries", unit="requests")
        p_abandoned = prb.counter("serve/abandoned", unit="requests")
        p_shed = prb.counter("serve/shed", unit="requests")
        p_occ = [prb.gauge(f"serve/replica{r}/occupancy", unit="slots")
                 for r in range(R)]
        obs_every = obs_left = prb.sample_every

    rows: List[tuple] = []       # finished (rid, r, slot, admit, first, done)
    rows_append = rows.append
    pending: deque = deque()
    busy = [False] * R
    is_decode = [False] * R
    idle_key = [(INF, 0, r) for r in range(R)]
    ekey = list(idle_key)        # (phase end, seq, lane): min() = next event
    busy_time = [0.0] * R
    free = [list(range(S)) for _ in range(R)]     # free-slot min-heaps
    occ = [0] * R                # occupied-slot count
    thresh = [[] for _ in range(R)]  # min-heap of threshold * S + slot
    s_req = [[0] * S for _ in range(R)]           # slot -> request index
    s_adm = [[0] * S for _ in range(R)]           # slot -> dec_total at admit
    s_tadmit = [[0.0] * S for _ in range(R)]
    s_tfirst = [[0.0] * S for _ in range(R)]
    need_tf = [[] for _ in range(R)]  # slots admitted since last decode
    dec_total = [0] * R          # cumulative decode steps on this replica
    ctx_sum = [0] * R            # sum of active slots' cached tokens
    dec_k = [1] * R              # fused steps in the in-flight decode
    dec_tf = [0.0] * R           # end of its first step (token-1 time)
    leap = [None] * R            # armed speculative leap: step bounds
    armed = 0                    # count of non-None entries in `leap`
    busy_count = 0
    total_out = 0
    # the scalar run() schedules fault events first, then arrivals, then
    # runtime events — mirror those implicit sequence-number bands
    seqc = n_fe + n_req
    makespan = 0.0

    def obs_tick(now: float) -> None:
        # one aligned sample per serving track from the plain-int
        # accumulators the hot sites bump (scalar-path ``_obs_tick``)
        nonlocal obs_left
        obs_left = obs_every
        for h, v in ((p_queue, n_queue), (p_completed, n_completed),
                     (p_leaps, n_leap_steps), (p_spec, n_spec),
                     (p_rollbacks, n_rollbacks),
                     (p_failures, n_fail_events), (p_retries, n_retries),
                     (p_abandoned, n_abandoned), (p_shed, 0)):
            h.value = v = float(v)
            h.series._append(now, v)
        for r in range(R):
            h = p_occ[r]
            h.value = v = float(occ[r])
            h.series._append(now, v)

    def submit(r: int, now: float, dur: float, decode: bool) -> None:
        nonlocal busy_count, seqc
        busy[r] = True
        busy_count += 1
        busy_time[r] += dur
        seqc += 1
        ekey[r] = (now + dur, seqc, r)
        is_decode[r] = decode

    def rollback(r: int, now: float) -> None:
        # mirrors ServingSimulator._rollback_leap + ServiceLane.truncate
        nonlocal armed, seqc, n_rollbacks, obs_left
        bounds = leap[r]
        leap[r] = None
        armed -= 1
        j = bisect_left(bounds, now)
        if j >= len(bounds) - 1:
            return               # lands in the final step: leap was exact
        dec_k[r] = j + 1
        if crash:
            fb = fbounds[r]
            if fb is not None:
                # the truncated leap keeps only j+1 steps; a later crash
                # must not commit tokens for the discarded ones
                fbounds[r] = (fb[0][:j + 1], fb[1])
        new_end = bounds[j]
        old_end = ekey[r][0]
        if new_end >= old_end:
            return               # zero-length tail: completion stands
        busy_time[r] -= old_end - new_end
        seqc += 1
        ekey[r] = (new_end, seqc, r)
        if prb is not None:
            n_rollbacks += 1
            obs_left -= 1
            if not obs_left:
                obs_tick(now)

    def start_decode(r: int, now: float) -> None:
        nonlocal armed, n_leap_steps, n_spec, obs_left
        n = occ[r]
        ctx = ctx_sum[r]
        k_min = thresh[r][0] // S - dec_total[r]
        base = df + dt * n
        cd = dc
        f = speed[r]
        if f != 1.0:
            # slow-degrade window: scale the step coefficients exactly as
            # the scalar path does, so per-step arithmetic stays bit-equal
            base *= f
            cd *= f
        c0 = base + cd * ctx
        if k_min > 1:
            speculate = bool(free[r])   # admission possible -> arm rollback
            dur, bounds = _leap_spans(now, c0, base, cd, ctx, n, k_min,
                                      speculate or crash, scratch)
            dec_k[r] = k_min
            if speculate:
                leap[r] = bounds
                armed += 1
            if crash:
                # crashes need every fused decode's step boundaries (the
                # commit point of a mid-leap fault), blocked leaps included
                fbounds[r] = (bounds, n)
            if prb is not None:
                n_leap_steps += k_min
                if speculate:
                    n_spec += 1
                obs_left -= 1
                if not obs_left:
                    obs_tick(now)
        else:
            dur = c0
            dec_k[r] = 1
        dec_tf[r] = now + c0
        submit(r, now, dur, True)

    def kick(r: int, now: float) -> None:
        nonlocal n_queue, obs_left
        if down[r]:
            return
        if pending and occ[r] < S:
            i = pending.popleft()
            s = heappop(free[r])
            occ[r] += 1
            p = prompts[i]
            s_req[r][s] = i
            s_adm[r][s] = dec_total[r]
            s_tadmit[r][s] = now
            need_tf[r].append(s)
            heappush(thresh[r], (dec_total[r] + outputs[i]) * S + s)
            ctx_sum[r] += p
            if prb is not None:
                n_queue -= 1
                obs_left -= 1
                if not obs_left:
                    obs_tick(now)
            dur = pf + pp * (p if p > 0 else 0)
            if speed[r] != 1.0:
                dur *= speed[r]     # slow-degrade (started-phase rule)
            submit(r, now, dur, False)
            if armed:                   # admission invalidates sibling leaps
                for r2 in range(R):
                    if r2 != r and leap[r2] is not None:
                        rollback(r2, now)
        elif occ[r]:
            start_decode(r, now)

    def retry_or_abandon(i: int, now: float) -> None:
        # mirrors ServingSimulator._retry_or_abandon arithmetic exactly:
        # jitter draws happen in the same order (slot order within a fail
        # event, fail events in time order), so the RNG streams match
        nonlocal n_retries, n_abandoned, seqc, obs_left
        att = attempts.get(i, 0) + 1
        if att >= rp.max_attempts:
            n_abandoned += 1
            if prb is not None:
                obs_left -= 1
                if not obs_left:
                    obs_tick(now)
            return
        attempts[i] = att
        delay = rp.backoff * rp.backoff_factor ** (att - 1)
        if rp.jitter:
            delay *= 1.0 + rp.jitter * float(rng.random())
        t_retry = now + delay
        if t_retry - times[i] > rp.deadline:
            n_abandoned += 1
            if prb is not None:
                obs_left -= 1
                if not obs_left:
                    obs_tick(now)
            return
        n_retries += 1
        if prb is not None:
            obs_left -= 1
            if not obs_left:
                obs_tick(now)
        seqc += 1
        heappush(retries, (t_retry, seqc, i))

    def do_fail(r: int, now: float) -> None:
        # mirrors ServingSimulator._fail
        nonlocal n_fail_events, busy_count, armed, makespan, total_out
        nonlocal obs_left
        if not crash:
            # brownout: phases *started* while degraded run slower
            speed[r] = slow_factor
            if prb is not None:
                prb.event("replica_degrade", now, replica=r)
            return
        down[r] = True
        n_fail_events += 1
        if prb is not None:
            prb.event("replica_fail", now, replica=r)
            obs_left -= 1
            if not obs_left:
                obs_tick(now)
        if busy[r]:
            # commit the fused-decode steps whose boundary strictly
            # precedes the fault (the per-step baseline already delivered
            # their tokens), then truncate the lane's span at the fault
            fb = fbounds[r]
            if fb is not None:
                j = bisect_left(fb[0], now)
                if j:
                    total_out += j * fb[1]
            old_end = ekey[r][0]
            if now < old_end:
                busy_time[r] -= old_end - now
            busy[r] = False
            busy_count -= 1
            ekey[r] = idle_key[r]
            if now > makespan:
                makespan = now   # the truncated span still ends a lane
        if leap[r] is not None:
            leap[r] = None
            armed -= 1
        fbounds[r] = None
        # lost in-flight requests retry (or abandon) in slot order; slots
        # free in the same order so the heap state matches the scalar path
        occupied = sorted(x % S for x in thresh[r])
        fr = free[r]
        req_r = s_req[r]
        for s in occupied:
            heappush(fr, s)
            retry_or_abandon(req_r[s], now)
        thresh[r].clear()
        need_tf[r].clear()
        ctx_sum[r] = 0
        occ[r] = 0

    def do_repair(r: int, now: float) -> None:
        # mirrors ServingSimulator._repair
        if not crash:
            speed[r] = 1.0
            if prb is not None:
                prb.event("replica_recover", now, replica=r)
            return
        down[r] = False
        if prb is not None:
            prb.event("replica_repair", now, replica=r)
        kick(r, now)

    # The lane-completion path below inlines finish-decode bookkeeping,
    # the kick, decode start, and submission — it runs once per lane
    # event and the call overhead is measurable at Monte-Carlo scale.
    # The closures above cover the arrival-side kicks and rollbacks
    # (rare under load); both encode the same policy, and the golden
    # parity tests exercise both.
    ai = 0
    na = INF                     # next clamped arrival time
    if n_req:
        t = times[0]
        na = t if t > 0.0 else 0.0
    while True:
        m = min(ekey)
        bt = m[0]
        if fi < n_fe or retries:
            # ---- fault events & retries (scalar heap (time, seq) order:
            # fault events hold the lowest seqs so they win every tie;
            # arrivals beat retries; retries order against completions by
            # push sequence) ----
            if fi < n_fe:
                if (nft <= na and nft <= bt
                        and (not retries or nft <= retries[0][0])):
                    ft, code, fr2 = fault_events[fi]
                    fi += 1
                    nft = fault_events[fi][0] if fi < n_fe else INF
                    if code:
                        do_fail(fr2, ft)
                    else:
                        do_repair(fr2, ft)
                    continue
            if retries:
                rt = retries[0]
                t_r = rt[0]
                if t_r < na and (t_r, rt[1]) < (bt, m[1]):
                    heappop(retries)
                    last_retry_t = t_r
                    # a retry re-arrives through the arrival path
                    pending.append(rt[2])
                    if prb is not None:
                        n_queue += 1
                        obs_left -= 1
                        if not obs_left:
                            obs_tick(t_r)
                    if busy_count < R:
                        for r2 in range(R):
                            if not busy[r2]:
                                kick(r2, t_r)
                    if pending and armed:
                        for r2 in range(R):
                            if leap[r2] is not None:
                                rollback(r2, t_r)
                    continue
        if na <= bt:                    # arrivals win same-time ties
            if na == INF:
                break                   # both streams exhausted
            if (armed == 0 and busy_count == R and nft > bt
                    and (not retries or retries[0][0] > bt)):
                # No idle replica to kick, no leap to roll back:
                # every arrival up to (and at) the next completion is
                # a pure queue append — take them in one jump.
                j = bisect_right(times, bt, ai)
                pending.extend(range(ai, j))
                if prb is not None:
                    n_queue += j - ai
                    obs_left -= 1
                    if not obs_left:
                        tx = times[j - 1]
                        obs_tick(tx if tx > 0.0 else 0.0)
                ai = j
            else:
                pending.append(ai)
                ai += 1
                if prb is not None:
                    n_queue += 1
                    obs_left -= 1
                    if not obs_left:
                        obs_tick(na)
                if busy_count < R:
                    for r in range(R):
                        if not busy[r]:
                            kick(r, na)
                if pending and armed:
                    for r in range(R):
                        if leap[r] is not None:
                            rollback(r, na)
            if ai < n_req:
                t = times[ai]
                na = t if t > 0.0 else 0.0
            else:
                na = INF
            continue
        r = m[2]
        now = bt
        busy[r] = False
        busy_count -= 1
        ekey[r] = idle_key[r]
        if now > makespan:
            makespan = now
        if is_decode[r]:
            # ---- finish the fused decode (inline finish_decode) ----
            if crash:
                fbounds[r] = None   # scalar _finish_decode clears too
            if leap[r] is not None:
                leap[r] = None
                armed -= 1
            k = dec_k[r]
            n = occ[r]
            total_out += k * n
            ctx_sum[r] += k * n
            a = dec_total[r] + k
            dec_total[r] = a
            ntf = need_tf[r]
            if ntf:
                tf = dec_tf[r]
                tf_r = s_tfirst[r]
                for s in ntf:
                    tf_r[s] = tf
                ntf.clear()
            th = thresh[r]
            lim = (a + 1) * S           # packed key < lim  <=>  threshold <= a
            if th and th[0] < lim:
                # slot finishes, in slot order (matching the scalar
                # path's slot-sorted active iteration)
                done = [heappop(th) % S]
                while th and th[0] < lim:
                    done.append(heappop(th) % S)
                if len(done) > 1:
                    done.sort()
                fr = free[r]
                req_r, adm_r = s_req[r], s_adm[r]
                ta_r, tf_r = s_tadmit[r], s_tfirst[r]
                for s in done:
                    heappush(fr, s)
                    # released ctx = prompt + every step it participated
                    # in (the last fused leap may overshoot its output
                    # count, exactly as the scalar fl.ctx += k does)
                    ctx_sum[r] -= prompts[req_r[s]] + (a - adm_r[s])
                occ[r] = n - len(done)
                for s in done:
                    rows_append((req_r[s], r, s, ta_r[s], tf_r[s], now))
                if prb is not None:
                    n_completed += len(done)
                    obs_left -= 1
                    if not obs_left:
                        obs_tick(now)
        # ---- kick the now-idle replica (inline kick) ----
        if pending and occ[r] < S:
            i = pending.popleft()
            s = heappop(free[r])
            occ[r] += 1
            s_req[r][s] = i
            s_adm[r][s] = dec_total[r]
            s_tadmit[r][s] = now
            need_tf[r].append(s)
            heappush(thresh[r], (dec_total[r] + outputs[i]) * S + s)
            p = prompts[i]
            ctx_sum[r] += p
            if prb is not None:
                n_queue -= 1
                obs_left -= 1
                if not obs_left:
                    obs_tick(now)
            dur = pf + pp * (p if p > 0 else 0)
            if speed[r] != 1.0:
                dur *= speed[r]     # slow-degrade (started-phase rule)
            busy[r] = True
            busy_count += 1
            busy_time[r] += dur
            seqc += 1
            ekey[r] = (now + dur, seqc, r)
            is_decode[r] = False
            if armed:                   # admission invalidates sibling leaps
                for r2 in range(R):
                    if r2 != r and leap[r2] is not None:
                        rollback(r2, now)
        elif occ[r]:
            # ---- issue the next fused decode (inline start_decode,
            # with _leap_spans' small-k Python path unrolled in place:
            # same `ctx += n; dur += base + cd*ctx` accumulation).
            # Fault runs share this path: slow-degrade scales the step
            # coefficients, crash mode additionally keeps the step
            # boundaries (the commit point of a mid-leap fault) — both
            # behind a single `faults is not None` short-circuit, so the
            # no-fault scenario pays one pointer test per decode start.
            n = occ[r]
            ctx = ctx_sum[r]
            k_min = thresh[r][0] // S - dec_total[r]
            base = df + dt * n
            cd = dc
            if faults is not None and speed[r] != 1.0:
                f = speed[r]
                base *= f
                cd *= f
            c0 = base + cd * ctx
            dec_tf[r] = now + c0
            if k_min > 1:
                dec_k[r] = k_min
                speculate = bool(free[r])   # admission -> arm rollback
                if speculate:
                    if k_min < 16:
                        dur = c0
                        bounds = [now + c0]
                        ba = bounds.append
                        cx = ctx
                        for _ in range(k_min - 1):
                            cx += n
                            dur += base + cd * cx
                            ba(now + dur)
                    else:
                        dur, bounds = _leap_spans(now, c0, base, cd, ctx,
                                                  n, k_min, True, scratch)
                    leap[r] = bounds
                    armed += 1
                    if crash:
                        fbounds[r] = (bounds, n)
                else:
                    if k_min < 16:
                        dur = c0
                        cx = ctx
                        for _ in range(k_min - 1):
                            cx += n
                            dur += base + cd * cx
                    else:
                        dur, _nb = _leap_spans(now, c0, base, cd, ctx, n,
                                               k_min, False, scratch)
                    if crash and now + dur >= nft:
                        # a fail event may strike mid-leap: it commits
                        # the step boundaries that precede it (do_fail),
                        # so this leap needs them materialized.  Leaps
                        # ending before the next fault event skip the
                        # O(k) bounds build — that is the armed-but-idle
                        # hot path the chaos-smoke overhead gate bounds.
                        if k_min < 16:
                            dur = c0
                            bounds = [now + c0]
                            ba = bounds.append
                            cx = ctx
                            for _ in range(k_min - 1):
                                cx += n
                                dur += base + cd * cx
                                ba(now + dur)
                        else:
                            dur, bounds = _leap_spans(now, c0, base, cd,
                                                      ctx, n, k_min, True,
                                                      scratch)
                        fbounds[r] = (bounds, n)
                if prb is not None:
                    n_leap_steps += k_min
                    if speculate:
                        n_spec += 1
                    obs_left -= 1
                    if not obs_left:
                        obs_tick(now)
            else:
                dur = c0
                dec_k[r] = 1
            busy[r] = True
            busy_count += 1
            busy_time[r] += dur
            seqc += 1
            ekey[r] = (now + dur, seqc, r)
            is_decode[r] = True

    # one vectorized fill of the SoA columns from the buffered rows
    nf = len(rows)
    ls = LaneStateArrays(capacity=nf)
    if nf:
        rid, rep, slot, t_admit, t_first, t_done = zip(*rows)
        ls.rid[:nf] = rid
        ls.replica[:nf] = rep
        ls.slot[:nf] = slot
        ls.t_admit[:nf] = t_admit
        ls.t_first[:nf] = t_first
        ls.t_done[:nf] = t_done
        rid_arr = ls.rid[:nf]
        ls.t_arrive[:nf] = np.asarray(times)[rid_arr]
        ls.prompt[:nf] = np.asarray(prompts)[rid_arr]
        ls.output[:nf] = np.asarray(outputs)[rid_arr]
    ls.n = nf
    ls.sort_by_rid()
    ttft, tpot, e2e, queue_delay = ls.stats()
    util = 0.0
    if makespan > 0:
        util = sum(busy_time) / (R * makespan)
    if prb is not None:
        # close every serving track where the scalar path would: at the
        # max of the makespan and the last processed event time (fault
        # events and retries may extend past the last completion)
        end_t = makespan
        if n_req:
            t = times[-1]
            if t < 0.0:
                t = 0.0
            if t > end_t:
                end_t = t
        if n_fe and fault_events[-1][0] > end_t:
            end_t = fault_events[-1][0]
        if last_retry_t > end_t:
            end_t = last_retry_t
        obs_tick(end_t)
        prb.gauge("serve/replica_util", unit="frac").set(end_t, util)
        prb.flush()
    return ServingReport(
        workload=wl_name, scheduler="continuous", cost_model=cost.name,
        replicas=R, slots=S, n_requests=ls.n, duration=makespan,
        output_tokens=total_out, ttft=ttft, tpot=tpot, e2e=e2e,
        queue_delay=queue_delay, replica_util=util,
        requests=_LazyRequests(ls), sim_result=None, events=[],
        n_offered=n_req,
        n_failures=(faults.n_failures(makespan)
                    if faults is not None else 0),
        n_retries=n_retries, n_abandoned=n_abandoned, n_shed=0,
        availability=(faults.availability(makespan, R)
                      if faults is not None else 1.0))


class MonteCarloServingSimulator:
    """Replays every row of a :class:`RequestBatch` against one
    (cost model, scheduler, replicas, slots) design point.

    Rows eligible for the specialized continuous-batching loop (stock
    :class:`ContinuousBatchingScheduler`, stock affine cost methods,
    time-sorted arrivals) run through :func:`_simulate_continuous_fast`;
    anything else runs the scalar :class:`ServingSimulator` per seed.
    Both paths produce identical per-seed :class:`ServingReport`\\ s, so
    switching paths never changes results — only speed.
    """

    def __init__(self, cost: ServingCostModel,
                 scheduler_factory: Callable[[], BatchScheduler],
                 batch: RequestBatch,
                 replicas: int = 1,
                 slots: int = 8,
                 probe=None,
                 failures=None,
                 retry: Optional[RetryPolicy] = None):
        """``probe`` enables per-seed instrumentation: seed ``s`` records
        into ``probe.child(f"seed{s}")`` with the scalar simulator's
        serve/* metric names, so
        :meth:`repro.obs.probe.Probe.merged_child_series` yields
        cross-seed mean/CI bands per metric.  Results stay bit-identical
        with or without a probe.

        ``failures`` injects a fault profile into every seed.  A
        :class:`~repro.serve_sim.faults.FailureModel` draws an
        *independent* failure schedule per seed — the fault RNG is
        re-seeded with ``(failures.seed, batch.seeds[k])``, so seed ``k``
        sees its own replica churn (and the K-seed CI genuinely samples
        scenario randomness) while staying bit-reproducible run-to-run.
        An explicit :class:`~repro.serve_sim.faults.ReplicaFault`
        sequence is shared verbatim across seeds.  ``retry`` is the
        re-enqueue policy for crash-lost requests (default
        :class:`RetryPolicy`)."""
        if replicas < 1 or slots < 1:
            raise ValueError("need replicas >= 1 and slots >= 1")
        if not isinstance(batch, RequestBatch):
            raise TypeError(f"expected a RequestBatch, got {type(batch)!r}")
        self.cost = cost
        self.scheduler_factory = scheduler_factory
        self.batch = batch
        self.replicas = replicas
        self.slots = slots
        self.probe = probe
        self.failures = failures
        self.retry = retry
        sched = scheduler_factory()
        self.scheduler_name = sched.name
        cls = type(cost)
        self.fast_path = (
            type(sched) is ContinuousBatchingScheduler
            and cls.decode_step_time is ServingCostModel.decode_step_time
            and cls.prefill_time is ServingCostModel.prefill_time
            and bool(np.all(np.diff(batch.t_arrive, axis=1) >= 0.0)))

    def _run_seed(self, k: int) -> ServingReport:
        b = self.batch
        child = (self.probe.child(f"seed{b.seeds[k]}")
                 if self.probe is not None else None)
        failures = self.failures
        # per-seed failure draws: both paths re-seed the fault RNG with
        # (model seed, scenario seed), so the schedules — and the retry
        # jitter stream — are bit-identical scalar vs. fused
        fseed = ((failures.seed, int(b.seeds[k]))
                 if isinstance(failures, FailureModel) else None)
        if self.fast_path:
            cf = (compile_faults(failures, self.replicas, seed=fseed)
                  if failures is not None else None)
            return _simulate_continuous_fast(
                self.cost, b.t_arrive[k].tolist(), b.prompt[k].tolist(),
                b.output[k].tolist(), self.replicas, self.slots,
                f"{b.name}/seed{b.seeds[k]}", probe=child,
                faults=cf, retry=self.retry)
        return ServingSimulator(self.cost, self.scheduler_factory,
                                b.workload(k), replicas=self.replicas,
                                slots=self.slots, probe=child,
                                failures=failures, retry=self.retry,
                                fault_seed=fseed).run()

    def run(self) -> MonteCarloServingReport:
        reports = [self._run_seed(k) for k in range(self.batch.num_seeds)]
        return MonteCarloServingReport(
            workload=self.batch.name,
            scheduler=self.scheduler_name,
            cost_model=self.cost.name,
            replicas=self.replicas, slots=self.slots,
            seeds=self.batch.seeds,
            reports=reports,
            stats=_cross_seed_stats(reports))


def monte_carlo_serving(cost: ServingCostModel,
                        scheduler_factory: Callable[[], BatchScheduler],
                        batch: RequestBatch, replicas: int = 1,
                        slots: int = 8, failures=None,
                        retry: Optional[RetryPolicy] = None
                        ) -> MonteCarloServingReport:
    """One-shot convenience wrapper around
    :class:`MonteCarloServingSimulator`."""
    return MonteCarloServingSimulator(cost, scheduler_factory, batch,
                                      replicas=replicas, slots=slots,
                                      failures=failures, retry=retry).run()
