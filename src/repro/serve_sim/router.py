"""Routing-tier policies for cluster serving.

The :class:`~repro.serve_sim.cluster.ClusterSimulator` places
heterogeneous :class:`~repro.serve_sim.cluster.ReplicaPool`\\ s behind a
pluggable :class:`RouterPolicy` and layers the resilience machinery on
top: health-checked rotation (:class:`HealthCheckPolicy`), per-pool
circuit breakers (:class:`CircuitBreakerPolicy` +
:class:`CircuitBreaker`), latency hedging (:class:`HedgePolicy`) and
reactive scaling (:class:`AutoscalerPolicy`).  Everything here is
deterministic — policies keep plain counters, never draw randomness —
so seeded cluster runs replay bit-identically.

Router contract: the cluster calls ``pick(candidates, cluster, req)``
with the pool indices currently routable (in rotation, breaker
allowing); ``candidates`` is never empty (the cluster fails open to
every pool when nothing is routable, and counts it).  ``pick`` must
return one of ``candidates``.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.serve_sim.workload import Request


def _check_pos(name: str, v: float) -> None:
    if not (isinstance(v, (int, float)) and math.isfinite(v) and v > 0):
        raise ValueError(f"{name} must be finite and > 0, got {v!r}")


def _check_int_ge(name: str, v: int, lo: int) -> None:
    if not isinstance(v, int) or isinstance(v, bool) or v < lo:
        raise ValueError(f"{name} must be an int >= {lo}, got {v!r}")


# ---------------------------------------------------------------------------
# Router policies
# ---------------------------------------------------------------------------


class RouterPolicy:
    """Base router: picks a pool index for each request.

    ``retry_budget`` is a *router-level* cap on failover re-routes per
    request, on top of each pool's :class:`RetryPolicy` attempt budget:
    a crash-lost request whose pool-level retry fires is re-routed
    through the router at most ``retry_budget`` times (``None`` =
    unlimited, which preserves single-pool parity with the standalone
    :class:`~repro.serve_sim.simulator.ServingSimulator`).
    """

    name = "router"

    def __init__(self, retry_budget: Optional[int] = None):
        if retry_budget is not None:
            _check_int_ge("retry_budget", retry_budget, 0)
        self.retry_budget = retry_budget

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        raise NotImplementedError


class PassThroughRouter(RouterPolicy):
    """Always the first routable pool — with one pool this is the
    golden-parity configuration (zero routing decisions)."""

    name = "passthrough"

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        return candidates[0]


class RoundRobinRouter(RouterPolicy):
    """Cycle over the routable pools in index order."""

    name = "round_robin"

    def __init__(self, retry_budget: Optional[int] = None):
        super().__init__(retry_budget)
        self._i = 0

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        c = candidates[self._i % len(candidates)]
        self._i += 1
        return c


class LeastLoadedRouter(RouterPolicy):
    """Pool with the lowest load per unit of healthy capacity (queued +
    in-flight requests over in-rotation replicas x slots); ties go to
    the lowest pool index.  Load is what a real balancer observes at its
    own edge — not the pools' internal fault state."""

    name = "least_loaded"

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        best = candidates[0]
        best_load = math.inf
        for i in candidates:
            load = cluster.pool_load(i) / max(1.0, cluster.pool_capacity(i))
            if load < best_load:
                best, best_load = i, load
        return best


class WeightedRouter(RouterPolicy):
    """Smooth weighted round-robin (the nginx algorithm): pool ``i`` is
    chosen ``weight_i / sum(weights)`` of the time with no bursts, fully
    deterministically.  Weights default to each pool's raw capacity
    (replicas x slots) scaled by its chip speed, so faster variants
    absorb proportionally more traffic."""

    name = "weighted"

    def __init__(self, retry_budget: Optional[int] = None):
        super().__init__(retry_budget)
        self._cur: Dict[int, float] = {}

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        cur = self._cur
        total = 0.0
        best = candidates[0]
        best_cur = -math.inf
        for i in candidates:
            w = cluster.pool_weight(i)
            total += w
            c = cur.get(i, 0.0) + w
            cur[i] = c
            if c > best_cur:
                best, best_cur = i, c
        cur[best] -= total
        return best


class StickyRouter(RouterPolicy):
    """Session-sticky: the same user (or request id, for anonymous
    open-loop traffic) consistently maps to the same pool via a
    deterministic integer hash over the *routable* set — so a pool
    leaving rotation only remaps its own sessions."""

    name = "sticky"

    @staticmethod
    def _mix(key: int) -> int:
        # splitmix64 finalizer: cheap, stable across processes (unlike
        # Python's salted hash()), well spread for sequential keys
        z = (key + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    def pick(self, candidates: Sequence[int], cluster, req: Request) -> int:
        key = req.user if req.user >= 0 else req.rid
        return candidates[self._mix(key) % len(candidates)]


ROUTERS: Dict[str, Callable[..., RouterPolicy]] = {
    "passthrough": PassThroughRouter,
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "weighted": WeightedRouter,
    "sticky": StickyRouter,
}


def make_router(name: str, **kwargs) -> RouterPolicy:
    """Build a router policy by registry name."""
    try:
        cls = ROUTERS[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r} "
                         f"(available: {sorted(ROUTERS)})") from None
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Health checks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HealthCheckPolicy:
    """Periodic replica probing with hysteresis.

    Every ``interval`` seconds each replica is probed; a probe fails if
    the replica is crashed, or browned out beyond ``max_slow_factor``
    (the probe's timeout proxy: a slow-degrade window scaling phases by
    more than this would also time the probe out).  ``unhealthy_after``
    consecutive failures take the replica out of rotation,
    ``healthy_after`` consecutive successes put it back — so crashes are
    *detected* with realistic lag (up to
    ``unhealthy_after * interval``), not omnisciently avoided, and
    repairs re-admit traffic only after the hysteresis clears.
    """

    interval: float = 1.0
    unhealthy_after: int = 3
    healthy_after: int = 2
    max_slow_factor: float = math.inf

    def __post_init__(self):
        _check_pos("HealthCheckPolicy.interval", self.interval)
        _check_int_ge("HealthCheckPolicy.unhealthy_after",
                      self.unhealthy_after, 1)
        _check_int_ge("HealthCheckPolicy.healthy_after",
                      self.healthy_after, 1)
        f = self.max_slow_factor
        if not (isinstance(f, (int, float)) and f >= 1.0):
            raise ValueError("HealthCheckPolicy.max_slow_factor must be "
                             f">= 1.0, got {f!r}")


# ---------------------------------------------------------------------------
# Circuit breakers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CircuitBreakerPolicy:
    """Per-pool breaker: trip open after ``error_threshold`` errors
    (crash-losses and abandonments) within ``window`` seconds; after
    ``cooldown`` seconds half-open and let ``half_open_probes`` trial
    requests through — a success closes the breaker, an error re-opens
    it for another cooldown."""

    error_threshold: int = 5
    window: float = 10.0
    cooldown: float = 30.0
    half_open_probes: int = 1

    def __post_init__(self):
        _check_int_ge("CircuitBreakerPolicy.error_threshold",
                      self.error_threshold, 1)
        _check_pos("CircuitBreakerPolicy.window", self.window)
        _check_pos("CircuitBreakerPolicy.cooldown", self.cooldown)
        _check_int_ge("CircuitBreakerPolicy.half_open_probes",
                      self.half_open_probes, 1)


class CircuitBreaker:
    """Runtime state machine for one pool (closed -> open -> half-open).

    Purely counter-driven: ``record_error`` / ``record_success`` come
    from the cluster's failure/completion hooks, ``allow`` gates
    routing, ``on_route`` consumes half-open probe slots.  Tracks
    ``n_trips`` and total open time for :class:`ClusterReport`."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    __slots__ = ("policy", "state", "n_trips", "_errors", "_opened_at",
                 "_probes_out", "time_open")

    def __init__(self, policy: CircuitBreakerPolicy):
        self.policy = policy
        self.state = self.CLOSED
        self.n_trips = 0
        self._errors: deque = deque()   # error timestamps inside window
        self._opened_at = 0.0
        self._probes_out = 0
        self.time_open = 0.0

    def _trip(self, now: float) -> None:
        self.state = self.OPEN
        self.n_trips += 1
        self._opened_at = now
        self._probes_out = 0
        self._errors.clear()

    def record_error(self, now: float) -> None:
        if self.state == self.HALF_OPEN:
            # the trial request failed: straight back to open
            self.time_open += now - self._opened_at
            self._trip(now)
            return
        if self.state == self.OPEN:
            return
        errs = self._errors
        errs.append(now)
        lo = now - self.policy.window
        while errs and errs[0] < lo:
            errs.popleft()
        if len(errs) >= self.policy.error_threshold:
            self._trip(now)

    def record_success(self, now: float) -> None:
        if self.state == self.HALF_OPEN:
            self.state = self.CLOSED
            self.time_open += now - self._opened_at
            self._probes_out = 0
            self._errors.clear()

    def allow(self, now: float) -> bool:
        state = self.state
        if state == self.CLOSED:
            return True
        if state == self.OPEN:
            if now - self._opened_at >= self.policy.cooldown:
                self.state = self.HALF_OPEN
                return True
            return False
        return self._probes_out < self.policy.half_open_probes

    def on_route(self, now: float) -> None:
        if self.state == self.HALF_OPEN:
            self._probes_out += 1

    def finalize(self, makespan: float) -> None:
        """Close the open-time integral at the end of the run."""
        if self.state != self.CLOSED:
            self.time_open += max(0.0, makespan - self._opened_at)


# ---------------------------------------------------------------------------
# Hedging
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HedgePolicy:
    """Latency hedging: a request still unfinished ``delay`` seconds
    after arrival is duplicated to a second pool; the first completion
    wins and the loser is cancelled.

    The delay is derived from the running ``quantile`` of completed E2E
    latencies (recomputed every ``refresh_every`` completions over the
    last ``window`` samples) once ``min_samples`` completions exist;
    until then ``initial_delay`` applies (the ``inf`` default simply
    disables hedging during warm-up).  A fixed ``delay`` overrides the
    derivation.  ``max_fraction`` is the hedging budget: hedges issued
    never exceed that fraction of offered requests."""

    quantile: float = 0.99
    min_samples: int = 64
    refresh_every: int = 256
    window: int = 2048
    initial_delay: float = math.inf
    delay: Optional[float] = None
    max_fraction: float = 0.05

    def __post_init__(self):
        if not (0.0 < self.quantile <= 1.0):
            raise ValueError("HedgePolicy.quantile must be in (0, 1], "
                             f"got {self.quantile!r}")
        _check_int_ge("HedgePolicy.min_samples", self.min_samples, 1)
        _check_int_ge("HedgePolicy.refresh_every", self.refresh_every, 1)
        _check_int_ge("HedgePolicy.window", self.window, 1)
        if self.delay is not None:
            _check_pos("HedgePolicy.delay", self.delay)
        if not (isinstance(self.initial_delay, (int, float))
                and self.initial_delay > 0):
            raise ValueError("HedgePolicy.initial_delay must be > 0, "
                             f"got {self.initial_delay!r}")
        if not (0.0 < self.max_fraction <= 1.0):
            raise ValueError("HedgePolicy.max_fraction must be in (0, 1], "
                             f"got {self.max_fraction!r}")


class HedgeDelayTracker:
    """Streaming p-quantile over recent E2E latencies — the hedge
    trigger.  Keeps a ring of the last ``policy.window`` samples and
    recomputes the quantile every ``policy.refresh_every`` completions
    (sorting 2k floats a few hundred times is noise next to the event
    loop; recomputing per-arrival would not be)."""

    __slots__ = ("policy", "_ring", "_n", "_since", "_delay")

    def __init__(self, policy: HedgePolicy):
        self.policy = policy
        self._ring: List[float] = []
        self._n = 0
        self._since = 0
        self._delay = (policy.delay if policy.delay is not None
                       else policy.initial_delay)

    def observe(self, e2e: float) -> None:
        if self.policy.delay is not None:
            return
        ring = self._ring
        w = self.policy.window
        if len(ring) < w:
            ring.append(e2e)
        else:
            ring[self._n % w] = e2e
        self._n += 1
        self._since += 1
        if (self._n >= self.policy.min_samples
                and self._since >= self.policy.refresh_every):
            self._since = 0
            s = sorted(ring)
            i = min(len(s) - 1, int(self.policy.quantile * len(s)))
            self._delay = s[i]

    @property
    def delay(self) -> float:
        return self._delay


# ---------------------------------------------------------------------------
# Autoscaling
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AutoscalerPolicy:
    """Reactive per-pool scaling on queue pressure.

    Every ``interval`` seconds the cluster evaluates each pool's queue
    depth per enabled replica: above ``up_threshold`` it *orders* a
    replica (active after ``scale_up_lag`` — boot/warm-up is what makes
    reactive scaling lose to faults); below ``down_threshold`` it drains
    one immediately (the replica finishes in-flight work, admits
    nothing, and stops accruing cost once idle).  ``min_replicas``
    floors the drain; pools scale at most ``step`` replicas per tick and
    never beyond their ``max_replicas`` headroom."""

    interval: float = 5.0
    up_threshold: float = 2.0
    down_threshold: float = 0.25
    scale_up_lag: float = 30.0
    min_replicas: int = 1
    step: int = 1

    def __post_init__(self):
        _check_pos("AutoscalerPolicy.interval", self.interval)
        _check_pos("AutoscalerPolicy.up_threshold", self.up_threshold)
        if not (isinstance(self.down_threshold, (int, float))
                and math.isfinite(self.down_threshold)
                and 0.0 <= self.down_threshold < self.up_threshold):
            raise ValueError(
                "AutoscalerPolicy.down_threshold must satisfy 0 <= "
                f"down_threshold < up_threshold, got {self.down_threshold!r}")
        if not (isinstance(self.scale_up_lag, (int, float))
                and math.isfinite(self.scale_up_lag)
                and self.scale_up_lag >= 0.0):
            raise ValueError("AutoscalerPolicy.scale_up_lag must be finite "
                             f"and >= 0, got {self.scale_up_lag!r}")
        _check_int_ge("AutoscalerPolicy.min_replicas", self.min_replicas, 1)
        _check_int_ge("AutoscalerPolicy.step", self.step, 1)
