"""Pluggable batching policies for the virtual serving simulator.

Each policy answers one question — *what should this replica do next?* —
given its slot occupancy and the shared request queue.  The simulator
(``repro.serve_sim.simulator``) invokes :meth:`BatchScheduler.decide`
whenever a replica goes idle (after a prefill/decode task completes, on a
request arrival, or at a requested wake-up time) and turns the returned
action into a task on the replica's DES resource.

Policies (virtual counterparts of real serving loops):

  * :class:`ContinuousBatchingScheduler` — slot-based continuous batching,
    mirroring the *measured* ``repro.launch.serve.BatchedServer`` loop
    admit-for-admit and step-for-step (asserted by
    ``tests/test_serve_sim.py``): admit queued requests one at a time into
    free slots, then run one decode step for every active slot; a finished
    request's slot is refilled from the queue before the next step.
  * :class:`BucketedPrefillScheduler` — dynamic batching with bucketed
    prefill: all admissible queued requests are prefilled together, each
    prompt padded to the next bucket boundary (padding is paid as extra
    prefill tokens); decode then continues slot-style.
  * :class:`StaticBatchScheduler` — classic static batching: wait until
    ``batch_size`` requests are queued (or ``max_wait`` expired), run the
    whole batch to completion before admitting again.  Finished requests
    hold their slot until the batch drains — the padding waste that
    continuous batching eliminates, now measurable in the virtual model.
"""
from __future__ import annotations

import abc
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Union

from repro.serve_sim.workload import Request


@dataclass(slots=True)
class InFlight:
    """One admitted request's runtime state on a replica."""

    req: Request
    slot: int
    ctx: int = 0                 # cached tokens (prompt + generated)
    generated: int = 0
    t_admit: float = 0.0
    t_first: Optional[float] = None   # end of the step emitting token 1
    done: bool = False           # finished but still holding its slot

    @property
    def finished(self) -> bool:
        return self.generated >= self.req.output_tokens


@dataclass
class ReplicaState:
    """Slot occupancy of one replica (owned by the simulator)."""

    index: int
    slots: int
    active: List[InFlight] = field(default_factory=list)
    busy: bool = False

    @property
    def free_slots(self) -> int:
        return self.slots - len(self.active)

    @property
    def decoding(self) -> List[InFlight]:
        """Slots that still generate tokens (excludes held finished slots)."""
        return [f for f in self.active if not f.done]

    @property
    def any_decoding(self) -> bool:
        """True if any slot still generates tokens — the O(n) early-exit
        check the per-step ``decide`` hot path needs (``decoding`` builds
        a list)."""
        return any(not f.done for f in self.active)


# ---- actions -------------------------------------------------------------


@dataclass(frozen=True)
class Prefill:
    """Admit ``reqs`` (already popped from the queue) and charge
    ``tokens`` prefill tokens (includes any bucket padding)."""

    reqs: Sequence[Request]
    tokens: int


@dataclass(frozen=True)
class Decode:
    """Run one decode step for every decoding slot."""


@dataclass(frozen=True)
class Wait:
    """Re-invoke ``decide`` at time ``t`` (batching timeout)."""

    t: float


@dataclass(frozen=True)
class Shed:
    """Drop ``reqs`` (already popped from the queue) — graceful-degradation
    load shedding.  The simulator accounts them as shed and immediately
    re-invokes ``decide`` for the replica's real next action."""

    reqs: Sequence[Request]


Action = Union[Prefill, Decode, Wait, Shed, None]

#: ``Decode`` carries no state — reuse one instance in the per-step hot path.
_DECODE = Decode()


def _bucket(n: int, bucket: int) -> int:
    """Round ``n`` up to the next multiple of ``bucket``."""
    return -(-n // bucket) * bucket if bucket > 1 else n


class BatchScheduler(abc.ABC):
    """One batching policy.  Stateless w.r.t. time: all runtime state lives
    in :class:`ReplicaState` and the shared queue, so a fresh instance per
    simulation run is cheap and the policy is trivially seedable."""

    name: str = "abstract"
    #: finished requests keep their slot until every batch member finishes
    hold_finished: bool = False
    #: policy guarantees that once a decode step is issued and no admission
    #: is possible (no free slot, or ``hold_finished`` blocking admissions),
    #: every subsequent ``decide`` returns ``Decode`` until a slot finishes.
    #: The simulator then fuses the steps up to the next finish into one
    #: task (exact per-step costs, ~10x fewer events).  Custom policies
    #: whose decisions depend on time or queue state mid-batch must leave
    #: this False.
    steady_decode: bool = False
    #: weaker contract enabling the *speculative* decode leap: between slot
    #: finishes, a ``Decode`` decision is a pure function of the queue and
    #: the slot occupancy — it may change when the queue changes (an
    #: arrival) but only if admission is possible (a free slot exists and
    #: no ``hold_finished`` batch is draining); with admission blocked the
    #: decision must repeat.  The simulator then fuses decode steps
    #: optimistically even while admission is possible, snapshots the
    #: per-step boundaries, and rolls the fused task back to the first
    #: boundary at/after an arrival that lands mid-leap, replaying from
    #: there per the policy's real decisions — exact parity with per-step
    #: simulation (tests/test_serve_sim.py).  The same contract powers
    #: both serving representations: the express ``ServiceLane`` truncates
    #: its fused task, and task-graph mode (``phase_tasks=N`` on the fast
    #: engine) books the leap as one ``TemplateLane`` burst of per-step
    #: template instances and truncates the burst at a snapshot boundary.
    #: Policies whose mid-batch decisions depend on ``now``, on step
    #: count, or on queue depth while no slot is free must leave this
    #: False.
    decode_stable: bool = False

    @abc.abstractmethod
    def decide(self, replica: ReplicaState, queue: Deque[Request],
               now: float) -> Action:
        """Pick the replica's next action.  May ``popleft`` requests off
        ``queue`` (they are then owned by the returned :class:`Prefill`)."""


class ContinuousBatchingScheduler(BatchScheduler):
    """Slot-based continuous batching — the virtual twin of the measured
    ``repro.launch.serve.BatchedServer`` loop: admit one queued request per
    free slot (sequential prefill), decode every active slot, refill freed
    slots before the next step."""

    name = "continuous"
    steady_decode = True
    decode_stable = True

    def decide(self, replica: ReplicaState, queue: Deque[Request],
               now: float) -> Action:
        if queue and len(replica.active) < replica.slots:
            req = queue.popleft()
            return Prefill((req,), req.prompt_tokens)
        return _DECODE if replica.any_decoding else None


class BucketedPrefillScheduler(BatchScheduler):
    """Dynamic batching with bucketed prefill: admit every admissible
    queued request at once, padding each prompt to the next ``bucket``
    boundary (the padding cost is real prefill work)."""

    name = "bucketed"
    steady_decode = True
    decode_stable = True

    def __init__(self, bucket: int = 128):
        if bucket < 1:
            raise ValueError("bucket must be >= 1")
        self.bucket = bucket

    def decide(self, replica: ReplicaState, queue: Deque[Request],
               now: float) -> Action:
        if queue and replica.free_slots > 0:
            n = min(len(queue), replica.free_slots)
            reqs = [queue.popleft() for _ in range(n)]
            tokens = sum(_bucket(r.prompt_tokens, self.bucket) for r in reqs)
            return Prefill(tuple(reqs), tokens)
        return _DECODE if replica.any_decoding else None


class StaticBatchScheduler(BatchScheduler):
    """Classic static batching: form a batch of ``batch_size`` (or whatever
    arrived within ``max_wait`` of the oldest queued request), run it to
    completion, repeat.  Prompts are padded to the longest in the batch."""

    name = "static"
    hold_finished = True
    steady_decode = True
    decode_stable = True

    def __init__(self, batch_size: int = 8, max_wait: float = 0.5):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.batch_size = batch_size
        self.max_wait = max_wait

    def decide(self, replica: ReplicaState, queue: Deque[Request],
               now: float) -> Action:
        if replica.active:
            if replica.any_decoding:
                return _DECODE
            return None       # simulator releases the drained batch
        if not queue:
            return None
        deadline = queue[0].t_arrive + self.max_wait
        if len(queue) < self.batch_size and now < deadline:
            return Wait(deadline)
        n = min(len(queue), self.batch_size, replica.slots)
        reqs = [queue.popleft() for _ in range(n)]
        longest = max(r.prompt_tokens for r in reqs)
        return Prefill(tuple(reqs), longest * n)
        # padding to the longest prompt: the whole batch pays max-length
        # prefill, the static-batching cost continuous batching removes


class LoadSheddingScheduler(ContinuousBatchingScheduler):
    """Continuous batching with graceful-degradation admission control.

    When the shared queue grows past ``max_queue`` — the queue-depth
    proxy for a blown ETA, e.g. during a replica outage — the scheduler
    sheds queued requests down to ``shed_to`` before admitting.  The drop
    set is priority-aware: lowest :attr:`Request.priority` first, and
    newest-first among equals (older requests have waited longest and are
    closest to service, so fresh low-priority load is the cheapest to
    refuse).  Shedding is deterministic — no RNG — so fault scenarios
    reproduce bit-identically.

    Decode decisions are inherited unchanged, but a ``decide`` call can
    now return :class:`Shed` whenever the queue is deep — even mid-batch
    with admission blocked — so *both* decode-leap contracts are off:
    fused steps would skip the per-step shedding checks the per-step
    path performs.  The Monte-Carlo fast path falls back to the scalar
    loop automatically (subclass ≠ stock continuous batching).
    """

    name = "shedding"
    steady_decode = False
    decode_stable = False

    def __init__(self, max_queue: int = 64, shed_to: Optional[int] = None):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.max_queue = max_queue
        self.shed_to = max_queue if shed_to is None else shed_to
        if not (0 <= self.shed_to <= max_queue):
            raise ValueError("need 0 <= shed_to <= max_queue")

    def decide(self, replica: ReplicaState, queue: Deque[Request],
               now: float) -> Action:
        if len(queue) > self.max_queue:
            n_drop = len(queue) - self.shed_to
            order = sorted(range(len(queue)),
                           key=lambda i: (queue[i].priority, -i))
            drop = set(order[:n_drop])
            kept = [queue[i] for i in range(len(queue)) if i not in drop]
            shed = tuple(queue[i] for i in sorted(drop))
            queue.clear()
            queue.extend(kept)
            return Shed(shed)
        return super().decide(replica, queue, now)


SCHEDULERS = {
    "continuous": ContinuousBatchingScheduler,
    "bucketed": BucketedPrefillScheduler,
    "static": StaticBatchScheduler,
    "shedding": LoadSheddingScheduler,
}


def make_scheduler(name: str, **kwargs) -> BatchScheduler:
    if name not in SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; "
                       f"available: {sorted(SCHEDULERS)}")
    return SCHEDULERS[name](**kwargs)
