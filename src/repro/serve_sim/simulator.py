"""Traffic-driven serving simulator over the extended DES engine.

Where ``repro.core.estimator`` answers *"how long is one static step?"*,
this module answers the ROADMAP's serving question at the concept phase:
*"what tail latency does this system + scheduler sustain under this
traffic?"* — before any prototype exists.

Mechanics: every request arrival is a timed callback
(:meth:`~repro.core.sim.engine.Simulator.at`) on the DES engine; each
scheduler decision (prefill batch, decode step) is injected as a
:class:`~repro.core.sim.engine.Task` on the replica's FIFO resource, with
durations from the :class:`~repro.serve_sim.cost.ServingCostModel` (itself
derived from a compiled task graph, so what-if re-annotation flows through
to serving metrics).  Completion callbacks drive the scheduler causally:
finish a request, free its slot, admit the next, issue the next step.

The emitted :class:`ServingReport` carries throughput, replica
utilization, and the serving tail metrics — TTFT (arrival to first
generated token), TPOT (mean inter-token time after the first), and E2E
latency — at p50/p95/p99, plus the raw per-request rows and the engine's
``SimResult`` for Gantt / Chrome-trace export
(:func:`repro.core.sim.trace.serving_chrome_trace`).

The measured counterpart is ``repro.launch.serve.BatchedServer``, which
logs the same per-request TTFT/TPOT — the paper's predicted-vs-measured
accuracy loop, extended to serving.
"""
from __future__ import annotations

from bisect import bisect_left, insort
from collections import deque
from dataclasses import dataclass, field
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.engine import (DynamicSimulator, GraphTemplate,
                                   SimResult, Simulator, Task)
from repro.serve_sim.cost import ServingCostModel
from repro.serve_sim.faults import RetryPolicy, compile_faults
from repro.serve_sim.scheduler import (BatchScheduler, Decode, InFlight,
                                       Prefill, ReplicaState, Shed, Wait)
from repro.serve_sim.workload import Request, Workload


@dataclass(frozen=True)
class LatencyStats:
    """Percentile summary of one latency population (seconds)."""

    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    max: float

    @staticmethod
    def of(values) -> "LatencyStats":
        """Summarize a list or 1-D array of latency values."""
        if len(values) == 0:
            return LatencyStats(0, 0.0, 0.0, 0.0, 0.0, 0.0)
        a = np.asarray(values, dtype=np.float64)
        p50, p95, p99 = np.percentile(a, (50, 95, 99))
        return LatencyStats(n=len(a), mean=float(a.mean()), p50=float(p50),
                            p95=float(p95), p99=float(p99),
                            max=float(a.max()))


@dataclass
class RequestMetrics:
    """Per-request outcome (the rows behind the percentiles)."""

    rid: int
    replica: int
    slot: int
    t_arrive: float
    t_admit: float
    t_first: float
    t_done: float
    prompt_tokens: int
    output_tokens: int

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrive

    @property
    def queue_delay(self) -> float:
        return self.t_admit - self.t_arrive

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrive

    @property
    def tpot(self) -> float:
        n = self.output_tokens
        return (self.t_done - self.t_first) / (n - 1) if n > 1 else 0.0


class LaneStateArrays:
    """Structure-of-arrays serving state — the *state advance* half of the
    simulator split.

    The serving hot loop separates into branchy per-lane *policy
    decisions* (which request to admit, when to decode — driven by
    :class:`~repro.serve_sim.scheduler.BatchScheduler` and the per-lane
    event machinery) and a uniform *state advance* (arrival/admit/first/
    finish timestamps, slot placement, token counts) that is identical
    arithmetic for every request.  This class holds the advance side as
    flat NumPy columns: the scalar :class:`ServingSimulator` records each
    finished request into one instance, and the seed-batched
    :class:`~repro.serve_sim.monte_carlo.MonteCarloServingSimulator`
    allocates one per seed so cross-seed statistics reduce to vectorized
    column arithmetic.

    Latency populations (TTFT/TPOT/E2E/queue delay) are derived from the
    columns bit-identically to the per-row :class:`RequestMetrics`
    properties they replace; ``RequestMetrics`` rows themselves are
    materialized lazily (:class:`_LazyRequests`) only when a consumer
    asks for them.
    """

    __slots__ = ("n", "rid", "replica", "slot", "t_arrive", "t_admit",
                 "t_first", "t_done", "prompt", "output")

    def __init__(self, capacity: int = 0):
        cap = max(int(capacity), 16)
        self.n = 0
        self.rid = np.empty(cap, np.int64)
        self.replica = np.empty(cap, np.int32)
        self.slot = np.empty(cap, np.int32)
        self.t_arrive = np.empty(cap, np.float64)
        self.t_admit = np.empty(cap, np.float64)
        self.t_first = np.empty(cap, np.float64)
        self.t_done = np.empty(cap, np.float64)
        self.prompt = np.empty(cap, np.int64)
        self.output = np.empty(cap, np.int64)

    def _grow(self) -> None:
        for name in self.__slots__[1:]:
            col = getattr(self, name)
            new = np.empty(2 * len(col), col.dtype)
            new[:self.n] = col[:self.n]
            setattr(self, name, new)

    def record(self, rid: int, replica: int, slot: int, t_arrive: float,
               t_admit: float, t_first: float, t_done: float,
               prompt: int, output: int) -> None:
        i = self.n
        if i >= len(self.rid):
            self._grow()
        self.rid[i] = rid
        self.replica[i] = replica
        self.slot[i] = slot
        self.t_arrive[i] = t_arrive
        self.t_admit[i] = t_admit
        self.t_first[i] = t_first
        self.t_done[i] = t_done
        self.prompt[i] = prompt
        self.output[i] = output
        self.n = i + 1

    def sort_by_rid(self) -> None:
        n = self.n
        order = np.argsort(self.rid[:n], kind="stable")
        for name in self.__slots__[1:]:
            col = getattr(self, name)
            col[:n] = col[:n][order]

    # ---- derived latency populations (vectorized column arithmetic) ----

    def stats(self) -> Tuple["LatencyStats", "LatencyStats",
                             "LatencyStats", "LatencyStats"]:
        """(ttft, tpot, e2e, queue_delay) percentile summaries."""
        n = self.n
        t_arrive = self.t_arrive[:n]
        t_first = self.t_first[:n]
        t_done = self.t_done[:n]
        out = self.output[:n]
        mask = out > 1
        tpot = (t_done[mask] - t_first[mask]) / (out[mask] - 1)
        return (LatencyStats.of(t_first - t_arrive),
                LatencyStats.of(tpot),
                LatencyStats.of(t_done - t_arrive),
                LatencyStats.of(self.t_admit[:n] - t_arrive))

    def to_request_metrics(self) -> List["RequestMetrics"]:
        return [RequestMetrics(
            rid=int(self.rid[i]), replica=int(self.replica[i]),
            slot=int(self.slot[i]), t_arrive=float(self.t_arrive[i]),
            t_admit=float(self.t_admit[i]), t_first=float(self.t_first[i]),
            t_done=float(self.t_done[i]), prompt_tokens=int(self.prompt[i]),
            output_tokens=int(self.output[i])) for i in range(self.n)]


class _LazyRequests(Sequence):
    """Sequence view over :class:`LaneStateArrays` that materializes
    :class:`RequestMetrics` rows on first access — reports stay cheap to
    build and to pickle (only the columns cross process boundaries)."""

    __slots__ = ("_arrays", "_rows")

    def __init__(self, arrays: LaneStateArrays):
        self._arrays = arrays
        self._rows: Optional[List[RequestMetrics]] = None

    def _materialize(self) -> List[RequestMetrics]:
        if self._rows is None:
            self._rows = self._arrays.to_request_metrics()
        return self._rows

    def __len__(self) -> int:
        return self._arrays.n

    def __bool__(self) -> bool:
        return self._arrays.n > 0

    def __getitem__(self, i):
        return self._materialize()[i]

    def __iter__(self):
        return iter(self._materialize())

    def __reduce__(self):
        return (_LazyRequests, (self._arrays,))


#: leap length from which the fused-step accumulation switches to
#: ``np.add.accumulate`` (same left-to-right addition order as the Python
#: loop, so the switch is bit-invisible; below this the loop is faster).
_LEAP_NUMPY_MIN = 16

#: shared step-index cache for the numpy leap path (grown on demand;
#: read-only views are sliced out, so sharing across simulators is safe)
_ARANGE = np.arange(1024, dtype=np.int64)


def _arange1(k: int) -> np.ndarray:
    """Cached ``np.arange(1, k)`` view."""
    global _ARANGE
    if k > len(_ARANGE):
        _ARANGE = np.arange(max(k, 2 * len(_ARANGE)), dtype=np.int64)
    return _ARANGE[1:k]


class _LeapScratch:
    """Reusable buffers for :func:`_leap_spans`' numpy path — one fused
    decode leap per call makes the per-call ``np.empty``/``np.arange``
    allocations the hot path's dominant constant; a scratch instance per
    simulator removes them without touching the arithmetic."""

    __slots__ = ("f", "i")

    def __init__(self):
        self.f = np.empty(64)
        self.i = np.empty(64, np.int64)

    def resize(self, k: int) -> None:
        if len(self.f) < k:
            n = max(k, 2 * len(self.f))
            self.f = np.empty(n)
            self.i = np.empty(n, np.int64)


def _leap_spans(now: float, c0: float, base: float, c_d: float,
                ctx: int, n_dec: int, k: int, speculate: bool,
                scratch: Optional[_LeapScratch] = None):
    """Fused decode-leap state advance under the affine cost model.

    Accumulates the exact per-step costs of a ``k``-step leap starting
    from ``ctx`` cached tokens (``base = decode_fixed +
    decode_per_token * n`` is the ctx-independent part of one step).
    Returns ``(total_duration, bounds)`` where ``bounds`` are the
    absolute per-step boundary times (only when ``speculate`` — they arm
    the rollback) — bit-identical whether the sequential Python loop or
    the vectorized ``np.add.accumulate`` path ran (and whether or not a
    ``scratch`` buffer set is supplied: every elementwise op and the
    left-to-right accumulation order are unchanged).
    """
    if k >= _LEAP_NUMPY_MIN:
        ar = _arange1(k)
        if scratch is not None:
            scratch.resize(k)
            steps = scratch.f[:k]
            ints = scratch.i[1:k]
            np.multiply(ar, n_dec, out=ints)
            np.add(ints, ctx, out=ints)
            tail = steps[1:]
            np.multiply(ints, c_d, out=tail)
            np.add(tail, base, out=tail)
            steps[0] = c0
            cum = np.add.accumulate(steps, out=steps)
        else:
            steps = np.empty(k)
            steps[0] = c0
            steps[1:] = base + c_d * (ctx + n_dec * ar)
            cum = np.add.accumulate(steps)
        return float(cum[-1]), (now + cum if speculate else None)
    dur = c0
    if speculate:
        bounds = [now + c0]
        for _ in range(k - 1):
            ctx += n_dec
            dur += base + c_d * ctx
            bounds.append(now + dur)
        return dur, bounds
    for _ in range(k - 1):
        ctx += n_dec
        dur += base + c_d * ctx
    return dur, None


@dataclass
class ServingReport:
    """End-to-end serving estimate for one (system, scheduler, traffic)."""

    workload: str
    scheduler: str
    cost_model: str
    replicas: int
    slots: int
    n_requests: int
    duration: float                    # makespan, seconds
    output_tokens: int
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    queue_delay: LatencyStats
    replica_util: float                # mean busy fraction across replicas
    #: per-request rows; a list, or a :class:`_LazyRequests` view that
    #: materializes :class:`RequestMetrics` on first access
    requests: Sequence[RequestMetrics] = field(default_factory=list)
    sim_result: Optional[SimResult] = None
    events: List[Tuple] = field(default_factory=list)
    # ---- resilience metrics (fault-injection runs; defaults = no faults) --
    n_offered: int = 0          # requests that ever arrived (excl. retries)
    n_failures: int = 0         # replica failure windows begun by makespan
    n_retries: int = 0          # re-enqueues after a replica crash
    n_abandoned: int = 0        # dropped: retry budget / deadline exhausted
    n_shed: int = 0             # dropped at admission (load shedding)
    #: shed counts keyed by request priority class — the audit-friendly
    #: breakdown behind ``n_shed`` (always sums to it)
    shed_by_priority: Dict[int, int] = field(default_factory=dict)
    availability: float = 1.0   # up replica-seconds / total replica-seconds

    @property
    def throughput_rps(self) -> float:
        return self.n_requests / self.duration if self.duration > 0 else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.output_tokens / self.duration if self.duration > 0 else 0.0

    @property
    def goodput_rps(self) -> float:
        """Completed requests per second — under faults this is the rate
        of *delivered* work (retried attempts are not double-counted)."""
        return self.throughput_rps

    @property
    def attempt_rps(self) -> float:
        """Retry-amplified attempt rate: completed + retried attempts per
        second.  ``attempt_rps / goodput_rps`` is the amplification the
        fleet actually pays for the goodput it delivers."""
        if self.duration <= 0:
            return 0.0
        return (self.n_requests + self.n_retries) / self.duration

    @property
    def abandonment_rate(self) -> float:
        """Fraction of offered requests never served (abandoned after
        retries/deadline, or shed at admission)."""
        if self.n_offered <= 0:
            return 0.0
        return (self.n_abandoned + self.n_shed) / self.n_offered

    def slo_attainment(self, slo) -> float:
        """Per-request SLO attainment: the fraction of *offered* requests
        individually meeting every target of ``slo`` (its p99 fields read
        as per-request bounds here).  Abandoned and shed requests count
        as misses, so churn shows up even when the survivors' percentiles
        look healthy.  Returns 1.0 for an empty run."""
        if self.n_offered > 0:
            denom = self.n_offered
        else:
            denom = len(self.requests)
        if denom == 0:
            return 1.0
        ok = 0
        for r in self.requests:
            if (r.ttft <= slo.ttft_p99 and r.tpot <= slo.tpot_p99
                    and r.e2e <= slo.e2e_p99):
                ok += 1
        return ok / denom

    def summary(self) -> str:
        s = (
            f"serve[{self.cost_model}|{self.scheduler}|{self.workload}] "
            f"{self.replicas}x{self.slots} slots: "
            f"{self.n_requests} reqs in {self.duration:.1f}s "
            f"({self.throughput_rps:.2f} req/s, {self.throughput_tps:.1f} "
            f"tok/s, util={self.replica_util:.1%})\n"
            f"  TTFT p50/p95/p99 = {self.ttft.p50 * 1e3:.0f}/"
            f"{self.ttft.p95 * 1e3:.0f}/{self.ttft.p99 * 1e3:.0f} ms   "
            f"TPOT p50/p99 = {self.tpot.p50 * 1e3:.2f}/"
            f"{self.tpot.p99 * 1e3:.2f} ms   "
            f"E2E p99 = {self.e2e.p99:.2f} s")
        if (self.n_failures or self.n_retries or self.n_abandoned
                or self.n_shed or self.availability < 1.0):
            s += (
                f"\n  faults: {self.n_failures} failures, "
                f"{self.n_retries} retries "
                f"({self.attempt_rps:.2f} attempt/s vs "
                f"{self.goodput_rps:.2f} goodput/s), "
                f"{self.n_abandoned} abandoned + {self.n_shed} shed "
                f"({self.abandonment_rate:.1%} of offered), "
                f"availability={self.availability:.4%}")
        return s


def _slot_of(fl: InFlight) -> int:
    return fl.slot


#: queue view a drained (autoscaler-disabled) replica consults — always
#: empty, so schedulers admit nothing while in-flight work runs down.
_EMPTY_PENDING: deque = deque()


class ServingSimulator:
    """Replays a :class:`Workload` against replicas of one cost model.

    ``scheduler_factory`` is called once per replica (schedulers are
    per-replica state-free policies); ``record_events`` keeps the
    admit/step/finish sequence for scheduler-parity assertions against the
    real ``BatchedServer``.
    """

    def __init__(self, cost: ServingCostModel,
                 scheduler_factory: Callable[[], BatchScheduler],
                 workload: Workload,
                 replicas: int = 1,
                 slots: int = 8,
                 record_events: bool = False,
                 phase_tasks: int = 0,
                 engine: str = "fast",
                 probe=None,
                 probe_engine: bool = False,
                 failures=None,
                 retry: Optional[RetryPolicy] = None,
                 fault_seed=None,
                 sim=None,
                 res_prefix: str = "",
                 obs_ns: str = "serve"):
        """``phase_tasks > 0`` switches from the ServiceLane express path
        to *full task-graph mode*: every prefill/decode phase carries a
        real task graph (chained compute chunks, each followed by a
        KV-write DMA on a sibling resource).  Chunk durations either
        exact-split the phase cost or, when the cost model carries
        compiled-graph :class:`~repro.serve_sim.cost.PhaseProfile`\\ s,
        follow the compiled prefill/decode graphs' real compute/DMA
        structure — either way the chunk chain's total is the exact phase
        cost, so serving metrics match the express path to float
        round-off while traces show intra-phase overlap.  ``engine``
        selects the implementation: ``"fast"`` runs each replica as a
        :class:`TemplateLane` (one event per phase, speculative decode
        leaps with burst truncation — lane-path speed with full graph
        records) while ``"dict"`` injects per-chunk tasks through the
        general :class:`Simulator` and never speculates (the golden
        per-step parity baseline).  ``probe`` (a
        :class:`repro.obs.probe.Probe`) enables queue-depth/occupancy/
        leap instrumentation; probes only read state, so instrumented
        runs stay bit-identical.  ``probe_engine=True`` additionally
        threads the probe into the embedded engine (per-event
        completion counters — deeper but ~2x the instrumentation cost,
        and the replica span tracks already cover the engine's view).

        ``failures`` (a :class:`~repro.serve_sim.faults.FailureModel` or
        an explicit :class:`~repro.serve_sim.faults.ReplicaFault` list)
        injects seeded replica failures as DES events: a crash cancels
        the replica's in-flight phase via the lane epoch machinery and
        re-enqueues its requests under ``retry`` (default
        :class:`~repro.serve_sim.faults.RetryPolicy`), a slow-degrade
        window scales phases *started* inside it.  ``fault_seed``
        overrides the model's seed (the Monte-Carlo simulator threads
        per-scenario seeds through it).

        ``sim``/``res_prefix``/``obs_ns`` exist for
        :class:`repro.serve_sim.cluster.ClusterSimulator`, which runs
        several pools as one discrete-event simulation: ``sim`` shares
        an already-built engine (the caller owns scheduling order and
        ``run()``), ``res_prefix`` namespaces the per-replica resources
        (``poolA/replica0``), and ``obs_ns`` namespaces the probe
        tracks.  Left at their defaults the behavior is bit-identical
        to earlier revisions."""
        if replicas < 1 or slots < 1:
            raise ValueError("need replicas >= 1 and slots >= 1")
        if phase_tasks < 0:
            raise ValueError("phase_tasks must be >= 0")
        if engine not in ("fast", "dict"):
            raise ValueError(f"unknown engine {engine!r} "
                             "(expected 'fast' or 'dict')")
        self.cost = cost
        self.workload = workload
        self.res_prefix = res_prefix
        self._obs_ns = obs_ns
        self.replicas = [ReplicaState(index=r, slots=slots)
                         for r in range(replicas)]
        self.schedulers = [scheduler_factory() for _ in range(replicas)]
        self.slots = slots
        self.record_events = record_events
        self.phase_tasks = int(phase_tasks)
        self.events: List[Tuple] = []
        self.pending: deque = deque()
        try:
            cap = int(workload.n_requests)
        except Exception:
            cap = 0
        self.lane_state = LaneStateArrays(capacity=cap)
        self._lanes: List = []
        self._templates: Optional[Dict[Tuple[int, str], GraphTemplate]] = None
        self._tail_handlers: Dict[int, Callable[[float], None]] = {}
        # Probe handles are bound once here; every hot-path site guards on
        # a single ``is not None`` branch so disabled runs pay one branch.
        # Enabled sites only bump plain-int accumulators and a shared
        # countdown (``_obs_left``); every ``probe.sample_every``-th
        # serving event, :meth:`_obs_tick` appends one aligned sample to
        # every serving track.  That keeps the per-event cost to a few
        # integer slot ops instead of a handle method call per metric.
        self.probe = probe
        if probe is not None:
            ns = obs_ns
            self._p_queue = probe.counter(f"{ns}/queue_depth",
                                          unit="requests")
            self._p_completed = probe.counter(f"{ns}/completed",
                                              unit="requests")
            self._p_leaps = probe.counter(f"{ns}/leap_steps", unit="steps")
            self._p_spec = probe.counter(f"{ns}/spec_leaps")
            self._p_rollbacks = probe.counter(f"{ns}/rollbacks")
            self._p_failures = probe.counter(f"{ns}/failures")
            self._p_retries = probe.counter(f"{ns}/retries",
                                            unit="requests")
            self._p_abandoned = probe.counter(f"{ns}/abandoned",
                                              unit="requests")
            self._p_shed = probe.counter(f"{ns}/shed", unit="requests")
            self._p_occ = [probe.gauge(f"{ns}/replica{r}/occupancy",
                                       unit="slots")
                           for r in range(replicas)]
            self._obs_every = probe.sample_every
            self._obs_left = self._obs_every
            self._n_queue = 0
            self._n_completed = 0
            self._n_leap_steps = 0
            self._n_spec = 0
            self._n_rollbacks = 0
        else:
            self._p_queue = None
            self._p_completed = None
            self._p_leaps = None
            self._p_spec = None
            self._p_rollbacks = None
            self._p_failures = None
            self._p_retries = None
            self._p_abandoned = None
            self._p_shed = None
            self._p_occ = None
        # Graph-mode chunk structure: compiled-graph profiles when the
        # cost model carries them (chunk count comes from the profile),
        # else the synthetic equal split into ``phase_tasks`` chunks.
        pp = getattr(cost, "prefill_profile", None) if self.phase_tasks \
            else None
        dp = getattr(cost, "decode_profile", None) if self.phase_tasks \
            else None
        self._profiles = {"prefill": pp, "decode": dp}
        self._chunks = {
            "prefill": len(pp.compute) if pp is not None else self.phase_tasks,
            "decode": len(dp.compute) if dp is not None else self.phase_tasks,
        }
        eng_probe = probe if probe_engine else None
        if self.phase_tasks:
            if engine == "fast":
                self._sim = sim if sim is not None \
                    else DynamicSimulator(probe=eng_probe)
                self._templates = {}
                # Graph mode on the fast engine: each replica is a
                # TemplateLane — full chunk/DMA records per phase, one
                # heap event per phase (and per fused leap), and burst
                # truncation for speculative rollback.  The dict engine
                # stays per-chunk injection: the parity baseline.
                self._lanes = [
                    self._sim.template_lane(self._res(r),
                                            step_durs=self._burst_step_durs)
                    for r in range(replicas)]
            else:
                # A shared dict engine already carries the owner's
                # ``on_complete`` dispatcher, which must forward phase
                # tails to this pool's ``_task_done``.
                self._sim = sim if sim is not None \
                    else Simulator(on_complete=self._task_done,
                                   probe=eng_probe)
        else:
            self._sim = sim if sim is not None else Simulator(probe=eng_probe)
            # Express path: each replica is a ServiceLane (one phase at a
            # time on a dedicated single-server resource) — no Task
            # construction or dependency bookkeeping per decode step,
            # record names deferred.
            self._lanes = [self._sim.lane(self._res(r),
                                          name_fn=self._name_fn(r))
                           for r in range(replicas)]
        # Speculative leaps need a truncatable lane: the express
        # ServiceLane or graph mode's TemplateLane.  Dict-engine graph
        # mode (per-chunk injection) stays per-step — it is the golden
        # baseline the leap path is verified against.
        self._spec_ok = bool(self._lanes)
        # Completion handlers are bound once per replica, not per step.
        self._phase_done = [self._phase_handler(rep) for rep in self.replicas]
        self._decode_done = [self._decode_handler(rep)
                             for rep in self.replicas]
        # Free slots per replica as min-heaps: admission pops the lowest
        # slot id (the order the old sorted-set-difference scan produced).
        self._free_slots = [list(range(slots)) for _ in range(replicas)]
        # Decode-leap state: steps fused into the in-flight decode task and
        # the exact end time of its first step (token-1 emission).
        self._decode_k = [1] * replicas
        self._decode_tfirst = [0.0] * replicas
        # Speculative-leap state per replica: (per-step boundary times,
        # batch width) while a rollback-able fused decode is in flight.
        self._leap: List[Optional[Tuple[List[float], int]]] = \
            [None] * replicas
        self._total_out_tokens = 0
        self._wait_until: Dict[int, float] = {}   # replica -> armed wake-up
        self._leap_scratch = _LeapScratch()
        # ---- fault injection --------------------------------------------
        self.retry = retry if retry is not None else RetryPolicy()
        self._faults = (compile_faults(failures, replicas, seed=fault_seed)
                        if failures is not None else None)
        self._fault_rng = (self._faults.rng() if self._faults is not None
                           else None)
        self._down = [False] * replicas        # crash windows (no admission)
        self._speed = [1.0] * replicas         # slow-degrade cost factor
        self._attempts: Dict[int, int] = {}    # rid -> crashes survived
        # dict-graph mode: in-flight phase's (tid0, tid_end, tail_tid) so a
        # crash can cancel the injected chunk tasks
        self._phase_range: List[Optional[Tuple[int, int, int]]] = \
            [None] * replicas
        # (step boundaries, n_dec) of an in-flight fused decode: a crash
        # mid-leap commits the tokens of the steps whose boundary precedes
        # it — exactly what the per-step baseline would have delivered
        self._fault_bounds: List[Optional[Tuple]] = [None] * replicas
        self._n_offered = 0
        self._n_fail_events = 0                # obs track (incl. post-run)
        self._n_retries = 0
        self._n_abandoned = 0
        self._n_shed = 0
        self._shed_by_priority: Dict[int, int] = {}
        # ---- cluster hooks (repro.serve_sim.cluster) --------------------
        # All default to None / empty and every hot site guards on one
        # ``is not None`` (the probe pattern), so standalone runs and a
        # 1-pool pass-through cluster stay bit-identical.  The hooks do
        # bookkeeping only — no RNG draws, no event scheduling of their
        # own on the parity path.
        self._route_hook: Optional[Callable[[Request], None]] = None
        self._retry_hook: Optional[Callable[[Request, float], None]] = None
        self._abandon_hook: Optional[Callable[[Request], None]] = None
        self._shed_hook: Optional[Callable[[Sequence[Request]], None]] = None
        self._finish_hook: Optional[Callable[[InFlight, float], bool]] = None
        #: hedge losers awaiting release at the next scheduler boundary
        self._cancelled_rids: set = set()
        #: autoscaler rotation mask; None means "all replicas admit"
        self._enabled: Optional[List[bool]] = None

    def _res(self, r: int) -> str:
        return f"{self.res_prefix}replica{r}"

    def _name_fn(self, r: int) -> Callable[[str, object], str]:
        pre = self.res_prefix
        def fmt(kind: str, info: object) -> str:
            if kind == "prefill":
                return f"prefill/{pre}r{r}/{'+'.join(str(i) for i in info)}"
            if isinstance(info, tuple):          # fused decode leap
                return f"decode/{pre}r{r}/b{info[0]}x{info[1]}"
            return f"decode/{pre}r{r}/b{info}"
        return fmt

    def _phase_handler(self, replica: ReplicaState):
        return lambda now: self._finish_phase(replica, now)

    def _decode_handler(self, replica: ReplicaState):
        return lambda now: self._finish_decode(replica, now)

    # ---- phase submission: ServiceLane express path or task-graph mode --

    def _task_done(self, task: Task, now: float) -> None:
        """Dict-engine ``on_complete`` observer: dispatch phase-tail
        completions to the bound replica handler."""
        h = self._tail_handlers.pop(task.tid, None)
        if h is not None:
            h(now)

    def _template(self, idx: int, kind: str) -> GraphTemplate:
        tpl = self._templates.get((idx, kind))
        if tpl is None:
            c = self._chunks[kind]
            res = self._res(idx)
            kv = res + ":kv"
            tasks = []
            for i in range(c):
                tasks.append(Task(2 * i, f"{kind}/r{idx}/c{i}", res, res,
                                  0.0, deps=(2 * i - 2,) if i else (),
                                  kind=kind))
                tasks.append(Task(2 * i + 1, f"{kind}/r{idx}/kv{i}", kv, kv,
                                  0.0, deps=(2 * i,), kind="dma"))
            tpl = GraphTemplate(tasks, tail=2 * c - 2)
            self._templates[(idx, kind)] = tpl
        return tpl

    def _phase_durs(self, kind: str, dur: float) -> List[float]:
        """Per-task durations (compute chunk, KV DMA, ...) for one phase
        of total duration ``dur`` — compiled-graph profile shares when the
        cost model carries them, else the synthetic equal split."""
        profile = self._profiles[kind]
        c = self._chunks[kind]
        durs = [0.0] * (2 * c)
        if profile is None:
            if c == 1:
                chunk_durs = [dur]
            else:
                d = dur / c
                chunk_durs = [d] * (c - 1)
                chunk_durs.append(dur - d * (c - 1))
        else:
            chunk_durs, dma_durs = profile.chunk_durations(dur)
            durs[1::2] = dma_durs
        durs[0::2] = chunk_durs
        return durs

    def _burst_step_durs(self, tpl: GraphTemplate, dur: float) -> List[float]:
        """TemplateLane burst materializer callback: bursts are always
        fused decode steps, so split one step of total ``dur``."""
        return self._phase_durs("decode", dur)

    def _submit_phase(self, idx: int, dur: float,
                      handler: Callable[[float], None],
                      kind: str, info: object) -> None:
        if not self.phase_tasks:
            self._lanes[idx].submit(dur, handler, kind=kind, info=info)
            return
        durs = self._phase_durs(kind, dur)
        sim = self._sim
        if self._templates is not None:     # fast engine: TemplateLane
            # Accumulate the tail end left-to-right over the chunk chain
            # — bit-identical to the dict engine's chained chunk events.
            end = sim.now
            for i in range(0, len(durs), 2):
                end += durs[i]
            self._lanes[idx].submit(self._template(idx, kind), durs, end,
                                    handler)
            return
        res = self._res(idx)                # dict engine baseline
        kv = res + ":kv"
        tid = tid0 = sim.next_task_id()
        prev = -1
        for i in range(0, len(durs), 2):
            sim.inject(Task(tid, f"{kind}/r{idx}/c{i // 2}", res, res,
                            durs[i], deps=(prev,) if prev >= 0 else (),
                            kind=kind))
            sim.inject(Task(tid + 1, f"{kind}/r{idx}/kv{i // 2}", kv, kv,
                            durs[i + 1], deps=(tid,), kind="dma"))
            prev = tid
            tid += 2
        self._tail_handlers[prev] = handler
        if self._faults is not None:
            self._phase_range[idx] = (tid0, tid, prev)

    # ---- arrivals --------------------------------------------------------

    def _arrive(self, req: Request, now: float) -> None:
        self.pending.append(req)
        if self._p_queue is not None:
            self._n_queue += 1
            n = self._obs_left - 1
            if n > 0:
                self._obs_left = n
            else:
                self._obs_tick(now)
        en = self._enabled
        for replica in self.replicas:
            if not replica.busy and (en is None or en[replica.index]):
                self._kick(replica, now)
        if self.pending:
            # The arrival survived the idle replicas, so a mid-flight
            # speculative decode leap may now be wrong: the scheduler
            # could decide differently at the next step boundary.  Roll
            # each armed leap back to the first boundary at/after now.
            for idx, leap in enumerate(self._leap):
                if leap is not None:
                    self._rollback_leap(idx, leap, now)

    def _rollback_leap(self, idx: int,
                       leap: Tuple[List[float], int], now: float) -> None:
        """Truncate a speculative decode leap at the first per-step
        boundary >= ``now``: the steps before it ran exactly as fused
        (the ``decode_stable`` contract — nothing the policy looks at
        changed), and from the truncated end the normal finish/kick path
        replays the policy's real decisions per step."""
        self._leap[idx] = None
        bounds, n = leap
        j = bisect_left(bounds, now)
        if j >= len(bounds) - 1:
            return            # lands in the final step: the leap was exact
        k = j + 1
        self._decode_k[idx] = k
        self._lanes[idx].truncate(bounds[j], info=n if k == 1 else (n, k))
        fb = self._fault_bounds[idx]
        if fb is not None:
            # the truncated leap keeps only k steps; a later crash must
            # not commit tokens for the steps the rollback discarded
            self._fault_bounds[idx] = (fb[0][:k], fb[1])
        if self._p_rollbacks is not None:
            self._n_rollbacks += 1

    def _schedule_arrival(self, req: Request) -> None:
        if self._route_hook is not None:
            # cluster mode: follow-up arrivals (closed-loop workloads)
            # go back through the router, which picks a pool at the
            # request's arrival time and accounts cluster-level offers
            self._route_hook(req)
            return
        self._n_offered += 1
        self._sim.at(max(0.0, req.t_arrive),
                     lambda r=req: self._arrive(r, self._sim.now))

    # ---- fault injection -------------------------------------------------

    def _fail(self, idx: int) -> None:
        """Replica ``idx``'s failure window opens (a pre-scheduled DES
        event — fault events at a timestamp fire before arrivals and
        completions at the same timestamp; see ``faults``)."""
        now = self._sim.now
        faults = self._faults
        if faults.mode == "slow":
            # brownout: phases *started* in the window run slower; nothing
            # is cancelled and the replica keeps admitting
            self._speed[idx] = faults.slow_factor
            if self.probe is not None:
                self.probe.event("replica_degrade", now, replica=idx)
            return
        replica = self.replicas[idx]
        self._down[idx] = True
        self._n_fail_events += 1
        if self.probe is not None:
            self.probe.event("replica_fail", now, replica=idx)
            if self._p_failures is not None:
                n = self._obs_left - 1
                if n > 0:
                    self._obs_left = n
                else:
                    self._obs_tick(now)
        if replica.busy:
            # A crash mid-fused-decode first commits the tokens of the
            # steps whose boundary precedes it — the per-step baseline
            # already delivered them (a step ending exactly at the fault
            # time loses: fault events win the timestamp tie everywhere).
            fb = self._fault_bounds[idx]
            if fb is not None:
                bounds, n_dec = fb
                j = bisect_left(bounds, now)
                if j:
                    self._total_out_tokens += j * n_dec
            # then cancel the in-flight phase via the epoch machinery:
            # the express lane keeps the truncated span, the fast-graph
            # lane keeps committed burst steps and drops the rest, and
            # dict-graph mode voids the injected chunks
            if self._lanes:
                self._lanes[idx].cancel(now)
            else:
                rng_t = self._phase_range[idx]
                if rng_t is not None:
                    tid0, tid_end, tail = rng_t
                    self._tail_handlers.pop(tail, None)
                    self._sim.cancel_tasks(range(tid0, tid_end))
            replica.busy = False
        self._phase_range[idx] = None
        self._leap[idx] = None
        self._fault_bounds[idx] = None
        if self.record_events:
            self.events.append(("fail", idx))
        # lost in-flight requests retry (or abandon) in slot order; slots
        # free in the same order so the heap state matches the fused path
        free = self._free_slots[idx]
        for fl in replica.active:
            heappush(free, fl.slot)
            if not fl.done:         # done-but-held slots were delivered
                self._retry_or_abandon(fl.req, now)
        replica.active.clear()

    def _repair(self, idx: int) -> None:
        now = self._sim.now
        if self._faults.mode == "slow":
            self._speed[idx] = 1.0
            if self.probe is not None:
                self.probe.event("replica_recover", now, replica=idx)
            return
        self._down[idx] = False
        if self.probe is not None:
            self.probe.event("replica_repair", now, replica=idx)
        if self.record_events:
            self.events.append(("repair", idx))
        self._kick(self.replicas[idx], now)

    def _retry_or_abandon(self, req: Request, now: float) -> None:
        """Re-enqueue a crash-lost request per the retry policy, or
        abandon it (attempt budget / per-request deadline exhausted).
        All progress is lost: the retried request prefills from scratch,
        but keeps its original ``t_arrive`` so E2E spans every attempt."""
        retry = self.retry
        att = self._attempts.get(req.rid, 0) + 1
        if att >= retry.max_attempts:
            self._abandon(req, now)
            return
        self._attempts[req.rid] = att
        delay = retry.backoff * retry.backoff_factor ** (att - 1)
        if retry.jitter:
            delay *= 1.0 + retry.jitter * float(self._fault_rng.random())
        t_retry = now + delay
        if t_retry - req.t_arrive > retry.deadline:
            self._abandon(req, now)
            return
        self._n_retries += 1
        if self._p_retries is not None:
            n = self._obs_left - 1
            if n > 0:
                self._obs_left = n
            else:
                self._obs_tick(now)
        if self.record_events:
            self.events.append(("retry", req.rid, att))
        if self._retry_hook is not None:
            # cluster failover: the backoff/jitter/deadline decision (and
            # the RNG draw order) above is unchanged; only the final
            # re-enqueue is redirected through the router, which picks
            # the target pool when the retry *fires*, not here.
            self._retry_hook(req, t_retry)
            return
        self._sim.at(t_retry, lambda r=req: self._arrive(r, self._sim.now))

    def _abandon(self, req: Request, now: float) -> None:
        self._n_abandoned += 1
        if self._p_abandoned is not None:
            n = self._obs_left - 1
            if n > 0:
                self._obs_left = n
            else:
                self._obs_tick(now)
        if self.record_events:
            self.events.append(("abandon", req.rid))
        if self._abandon_hook is not None:
            self._abandon_hook(req)

    # ---- the scheduling loop --------------------------------------------

    def _kick(self, replica: ReplicaState, now: float) -> None:
        idx = replica.index
        if replica.busy or self._down[idx]:
            return
        sched = self.schedulers[idx]
        en = self._enabled
        # A drained (autoscaler-disabled) replica admits nothing but
        # finishes its in-flight batch: it consults the policy against an
        # empty queue, so every stock scheduler naturally runs the batch
        # down and then idles.
        q = self.pending if en is None or en[idx] else _EMPTY_PENDING
        action = sched.decide(replica, q, now)
        while isinstance(action, Shed):
            # graceful degradation: the scheduler dropped queued requests
            # to keep the backlog bounded; account, then re-decide
            n_dropped = len(action.reqs)
            self._n_shed += n_dropped
            sbp = self._shed_by_priority
            for req in action.reqs:
                sbp[req.priority] = sbp.get(req.priority, 0) + 1
            if self._p_shed is not None:
                self._n_queue -= n_dropped
                n = self._obs_left - 1
                if n > 0:
                    self._obs_left = n
                else:
                    self._obs_tick(now)
            if self.record_events:
                for req in action.reqs:
                    self.events.append(("shed", req.rid))
            if self._shed_hook is not None:
                self._shed_hook(action.reqs)
            action = sched.decide(replica, q, now)

        if isinstance(action, Prefill):
            self._start_prefill(replica, action, now)
        elif isinstance(action, Decode):
            self._start_decode(replica, now)
        elif isinstance(action, Wait):
            key = replica.index
            if np.isfinite(action.t) and self._wait_until.get(key) != action.t:
                self._wait_until[key] = action.t
                self._sim.at(action.t, lambda r=replica: self._wake(r))
        # None: replica stays idle until an arrival or wake-up kicks it

    def _wake(self, replica: ReplicaState) -> None:
        self._wait_until.pop(replica.index, None)
        self._kick(replica, self._sim.now)

    def _start_prefill(self, replica: ReplicaState, action: Prefill,
                       now: float) -> None:
        free = self._free_slots[replica.index]
        if len(action.reqs) > len(free):
            raise RuntimeError(
                f"scheduler {self.schedulers[replica.index].name!r} admitted "
                f"{len(action.reqs)} requests with only {len(free)} free "
                f"slots on replica{replica.index}")
        record = self.record_events
        rids = []
        for req in action.reqs:
            fl = InFlight(req=req, slot=heappop(free),
                          ctx=req.prompt_tokens, t_admit=now)
            # keep actives slot-sorted: decode iteration then matches the
            # real BatchedServer's per-slot order without re-sorting
            insort(replica.active, fl, key=_slot_of)
            rids.append(req.rid)
            if record:
                self.events.append(("admit", req.rid))
        dur = self.cost.prefill_time(action.tokens)
        f = self._speed[replica.index]
        if f != 1.0:
            dur *= f            # slow-degrade window (started-phase rule)
        replica.busy = True
        if self._p_queue is not None:
            self._n_queue -= len(action.reqs)
            n = self._obs_left - 1
            if n > 0:
                self._obs_left = n
            else:
                self._obs_tick(now)
        self._submit_phase(replica.index, dur,
                           self._phase_done[replica.index],
                           "prefill", tuple(rids))
        # This admission consumed queued requests — the other change (in
        # addition to arrivals) a decode_stable policy's mid-batch
        # decision may depend on.  Roll back sibling replicas' armed
        # speculative leaps so their next boundaries consult the policy
        # against the shrunk queue, exactly like the per-step path.
        for i, leap in enumerate(self._leap):
            if leap is not None and i != replica.index:
                self._rollback_leap(i, leap, now)

    def _start_decode(self, replica: ReplicaState, now: float) -> None:
        idx = replica.index
        sched = self.schedulers[idx]
        hold = sched.hold_finished
        # static batching pays for held (finished) slots too
        n = 0
        ctx = 0
        n_dec = 0
        k_min = 0
        for f in replica.active:
            if f.done:
                if hold:
                    n += 1
                    ctx += f.ctx
                continue
            n += 1
            ctx += f.ctx
            n_dec += 1
            rem = f.req.output_tokens - f.generated
            if k_min == 0 or rem < k_min:
                k_min = rem
        # Decode leap: until the shortest slot finishes, a steady_decode
        # policy will issue identical decode steps (admission is blocked:
        # no free slot, or hold_finished holds the batch) — fuse them into
        # one task, accumulating the exact per-step costs.  When admission
        # *is* possible, a decode_stable policy still leaps, but
        # speculatively: the per-step boundaries are kept so an arrival
        # landing mid-leap rolls the fused task back (ServiceLane
        # truncation on the express path, TemplateLane burst truncation
        # in fast-engine graph mode).
        k = 1
        speculate = False
        leap_ok = k_min > 1 and not self.record_events
        blocked = hold or not self._free_slots[idx]
        if leap_ok and blocked and (sched.steady_decode
                                    or sched.decode_stable):
            # Admission impossible until a slot finishes: both contracts
            # guarantee identical decode steps, so the leap is exact with
            # no snapshot needed.
            k = k_min
        elif leap_ok and sched.decode_stable and self._spec_ok:
            # Admission possible: leap speculatively and arm rollback (an
            # arrival may change the next-step decision).  Requires a
            # truncatable lane — the dict-engine graph baseline has none
            # and runs these batches per-step.
            k = k_min
            speculate = True
        # Exact per-step cost accumulation.  For the stock affine
        # ServingCostModel, decode_step_time(n, ctx) is inlined with
        # identical arithmetic (bit-for-bit, ~2x fewer ns per fused
        # step); subclasses overriding the method are honored per step.
        cost = self.cost
        affine = (type(cost).decode_step_time
                  is ServingCostModel.decode_step_time)
        f = self._speed[idx]
        # crash-faults need the step boundaries of *every* fused decode
        # (blocked leaps included): a crash mid-leap commits the steps
        # whose boundary precedes it.  Collecting bounds never changes
        # the duration arithmetic (see _leap_spans).
        faultable = (self._faults is not None
                     and self._faults.mode == "crash" and k > 1)
        if affine:
            base = cost.decode_fixed + cost.decode_per_token * n
            c_d = cost.decode_per_ctx_token
            if f != 1.0:
                # slow-degrade: scale the step coefficients (the fused
                # Monte-Carlo path applies the identical scaling, so the
                # per-step arithmetic stays bit-equal across paths)
                base *= f
                c_d *= f
            c0 = base + c_d * ctx
            dur, bounds = _leap_spans(now, c0, base, c_d, ctx, n_dec, k,
                                      speculate or faultable,
                                      self._leap_scratch)
        else:
            c0 = cost.decode_step_time(n, ctx)
            if f != 1.0:
                c0 *= f
            dur = c0
            bounds = None
            if speculate or faultable:
                bounds = [now + c0]
                for _ in range(k - 1):
                    ctx += n_dec
                    s = cost.decode_step_time(n, ctx)
                    dur += s * f if f != 1.0 else s
                    bounds.append(now + dur)
            else:
                for _ in range(k - 1):
                    ctx += n_dec
                    s = cost.decode_step_time(n, ctx)
                    dur += s * f if f != 1.0 else s
        if self.record_events:
            self.events.append(
                ("step", tuple(sorted(f.req.rid for f in replica.active
                                      if not f.done))))
        self._decode_k[idx] = k
        self._decode_tfirst[idx] = now + c0
        self._leap[idx] = (bounds, n) if speculate else None
        if faultable:
            self._fault_bounds[idx] = (bounds, n_dec)
        if self._p_leaps is not None and k > 1:
            self._n_leap_steps += k
            if speculate:
                self._n_spec += 1
        replica.busy = True
        if speculate and self.phase_tasks:
            # Graph-mode leap: K chained step instances as ONE lane entry
            # and one completion event — O(1) bookkeeping per leap; the
            # per-step `bounds` double as the rollback snapshot points.
            self._lanes[idx].submit_burst(self._template(idx, "decode"),
                                          bounds, self._decode_done[idx])
        else:
            self._submit_phase(idx, dur, self._decode_done[idx], "decode",
                               n if k == 1 else (n, k))

    def _finish_phase(self, replica: ReplicaState, now: float) -> None:
        replica.busy = False
        if self._cancelled_rids:
            self._sweep_cancelled(replica)
        self._kick(replica, now)

    def _sweep_cancelled(self, replica: ReplicaState) -> None:
        """Release hedge-cancelled requests at a prefill boundary: they
        leave the batch and free their slots without ever decoding.
        (Decode boundaries release through ``_finish_decode``'s finished
        path instead, which preserves hold-finished batch semantics.)"""
        cr = self._cancelled_rids
        free = self._free_slots[replica.index]
        kept = []
        changed = False
        for fl in replica.active:
            if not fl.done and fl.req.rid in cr:
                heappush(free, fl.slot)
                cr.discard(fl.req.rid)
                changed = True
            else:
                kept.append(fl)
        if changed:
            replica.active[:] = kept

    def _finish_decode(self, replica: ReplicaState, now: float) -> None:
        idx = replica.index
        self._leap[idx] = None
        self._fault_bounds[idx] = None
        sched = self.schedulers[idx]
        k = self._decode_k[idx]
        t_first = self._decode_tfirst[idx]
        finished: List[InFlight] = []
        decoding_left = 0
        tokens = 0
        # actives are slot-sorted, mirroring the real BatchedServer's
        # finish ordering
        cr = self._cancelled_rids or None
        for fl in replica.active:
            if fl.done:
                continue
            fl.generated += k
            fl.ctx += k
            tokens += k
            if fl.t_first is None:
                fl.t_first = t_first
            if fl.generated >= fl.req.output_tokens:
                fl.done = True
                finished.append(fl)
            elif cr is not None and fl.req.rid in cr:
                # hedge loser: leaves the batch at this step boundary —
                # the same instant on every engine, so dict-vs-fast
                # golden parity holds under cancellation
                fl.done = True
                finished.append(fl)
            else:
                decoding_left += 1
        self._total_out_tokens += tokens
        release = finished
        if sched.hold_finished:
            # the batch drains only when every member is done
            release = [] if decoding_left else list(replica.active)
        free = self._free_slots[replica.index]
        for fl in release:
            replica.active.remove(fl)
            heappush(free, fl.slot)
        fh = self._finish_hook
        n_rec = 0
        for fl in finished:
            if fh is not None and not fh(fl, now):
                continue     # swallowed: a hedge duplicate already won
            n_rec += 1
            if self.record_events:
                self.events.append(("finish", fl.req.rid))
            self.lane_state.record(
                fl.req.rid, replica.index, fl.slot, fl.req.t_arrive,
                fl.t_admit, fl.t_first, now, fl.req.prompt_tokens,
                fl.req.output_tokens)
            follow = self.workload.on_complete(fl.req, now)
            if follow is not None:
                self._schedule_arrival(follow)
        if self._p_completed is not None:
            self._n_completed += n_rec
            n = self._obs_left - 1
            if n > 0:
                self._obs_left = n
            else:
                self._obs_tick(now)
        replica.busy = False
        self._kick(replica, now)

    # ---- cluster support -------------------------------------------------

    def cancel_request(self, rid: int, now: float) -> str:
        """Withdraw ``rid`` from this pool (a hedge duplicate lost the
        race on another pool).  A queued copy leaves immediately; an
        admitted copy is marked and released at its replica's next
        scheduler boundary — a prefill end or a decode step boundary,
        which fall at the same instants on every engine, so the
        dict-vs-fast golden contract survives cancellation.  An armed
        speculative decode leap is rolled back first so that boundary
        arrives at per-step fidelity instead of the leap's far end.
        Returns ``"queued"`` / ``"inflight"`` / ``"absent"``."""
        pending = self.pending
        for i, req in enumerate(pending):
            if req.rid == rid:
                del pending[i]
                if self._p_queue is not None:
                    self._n_queue -= 1
                    n = self._obs_left - 1
                    if n > 0:
                        self._obs_left = n
                    else:
                        self._obs_tick(now)
                return "queued"
        for replica in self.replicas:
            for fl in replica.active:
                if fl.req.rid == rid and not fl.done:
                    self._cancelled_rids.add(rid)
                    idx = replica.index
                    leap = self._leap[idx]
                    if leap is not None:
                        self._rollback_leap(idx, leap, now)
                    return "inflight"
        return "absent"

    def set_replica_enabled(self, idx: int, enabled: bool,
                            now: float) -> None:
        """Autoscaler support: a disabled replica admits nothing (its
        scheduler sees an empty queue) but drains in-flight work
        naturally; re-enabling kicks it against the real queue."""
        en = self._enabled
        if en is None:
            en = self._enabled = [True] * len(self.replicas)
        if en[idx] == enabled:
            return
        en[idx] = enabled
        if enabled:
            self._kick(self.replicas[idx], now)

    def n_enabled(self) -> int:
        en = self._enabled
        return len(self.replicas) if en is None else sum(en)

    # ---- observability ---------------------------------------------------

    def _obs_tick(self, now: float) -> None:
        """Append one aligned sample to every serving track from the
        plain-int accumulators the hot sites bump.  Runs every
        ``probe.sample_every``-th instrumented event (and once at the end
        of the run), so handles/series see raw appends — the site
        countdown IS the decimation layer for serving metrics."""
        self._obs_left = self._obs_every
        for h, v in ((self._p_queue, self._n_queue),
                     (self._p_completed, self._n_completed),
                     (self._p_leaps, self._n_leap_steps),
                     (self._p_spec, self._n_spec),
                     (self._p_rollbacks, self._n_rollbacks),
                     (self._p_failures, self._n_fail_events),
                     (self._p_retries, self._n_retries),
                     (self._p_abandoned, self._n_abandoned),
                     (self._p_shed, self._n_shed)):
            h.value = v = float(v)
            h.series._append(now, v)
        for r, h in zip(self.replicas, self._p_occ):
            h.value = v = float(len(r.active))
            h.series._append(now, v)

    # ---- entry point -----------------------------------------------------

    def _arm_faults(self) -> None:
        """Schedule this pool's compiled fault events on the engine.
        Called before any arrival is scheduled — fault events at a tied
        timestamp must beat arrivals/completions on the heap's sequence
        tie-break (the cluster arms every pool first, then routes)."""
        faults = self._faults
        if faults is not None:
            # Fault events are scheduled FIRST, in schedule order (sorted
            # by time, repairs before failures at equal times), so at any
            # tied timestamp they beat arrivals — and every runtime event
            # (completions, retries) — on the heap's sequence tie-break.
            # The fused Monte-Carlo loop mirrors this priority exactly.
            for t, code, r in faults.events:
                if code:
                    self._sim.at(t, lambda i=r: self._fail(i))
                else:
                    self._sim.at(t, lambda i=r: self._repair(i))

    def run(self) -> ServingReport:
        self._arm_faults()
        for req in self.workload.initial():
            self._schedule_arrival(req)
        sim_result = self._sim.run()
        return self._build_report(sim_result)

    def _build_report(self, sim_result: SimResult,
                      flush: bool = True) -> ServingReport:
        faults = self._faults
        util = 0.0
        if sim_result.makespan > 0:
            util = sum(
                sim_result.resource_busy.get(self._res(r.index), 0.0)
                for r in self.replicas
            ) / (len(self.replicas) * sim_result.makespan)

        probe = self.probe
        if probe is not None:
            # close the counter tracks at the makespan so they span the
            # whole run, and record the end-of-run utilization level
            # (fault events past the last completion may extend the span)
            end_t = max(sim_result.makespan, self._sim.now)
            self._obs_tick(end_t)
            probe.gauge(f"{self._obs_ns}/replica_util",
                        unit="frac").set(end_t, util)
            if flush:
                probe.flush()

        ls = self.lane_state
        ls.sort_by_rid()
        ttft, tpot, e2e, queue_delay = ls.stats()
        mk = sim_result.makespan
        return ServingReport(
            workload=self.workload.name,
            scheduler=self.schedulers[0].name,
            cost_model=self.cost.name,
            replicas=len(self.replicas), slots=self.slots,
            n_requests=ls.n,
            duration=mk,
            output_tokens=self._total_out_tokens,
            ttft=ttft, tpot=tpot, e2e=e2e, queue_delay=queue_delay,
            replica_util=util,
            requests=_LazyRequests(ls),
            sim_result=sim_result,
            events=self.events,
            n_offered=self._n_offered,
            n_failures=(faults.n_failures(mk) if faults is not None else 0),
            n_retries=self._n_retries,
            n_abandoned=self._n_abandoned,
            n_shed=self._n_shed,
            shed_by_priority=dict(self._shed_by_priority),
            availability=(faults.availability(mk, len(self.replicas))
                          if faults is not None else 1.0))


def simulate_serving(cost: ServingCostModel,
                     scheduler_factory: Callable[[], BatchScheduler],
                     workload: Workload, replicas: int = 1, slots: int = 8,
                     record_events: bool = False,
                     phase_tasks: int = 0, engine: str = "fast",
                     probe=None, failures=None,
                     retry: Optional[RetryPolicy] = None,
                     fault_seed=None) -> ServingReport:
    """One-shot convenience wrapper around :class:`ServingSimulator`."""
    return ServingSimulator(cost, scheduler_factory, workload,
                            replicas=replicas, slots=slots,
                            record_events=record_events,
                            phase_tasks=phase_tasks, engine=engine,
                            probe=probe, failures=failures, retry=retry,
                            fault_seed=fault_seed).run()
