"""Traffic-driven serving simulator over the extended DES engine.

Where ``repro.core.estimator`` answers *"how long is one static step?"*,
this module answers the ROADMAP's serving question at the concept phase:
*"what tail latency does this system + scheduler sustain under this
traffic?"* — before any prototype exists.

Mechanics: every request arrival is a timed callback
(:meth:`~repro.core.sim.engine.Simulator.at`) on the DES engine; each
scheduler decision (prefill batch, decode step) is injected as a
:class:`~repro.core.sim.engine.Task` on the replica's FIFO resource, with
durations from the :class:`~repro.serve_sim.cost.ServingCostModel` (itself
derived from a compiled task graph, so what-if re-annotation flows through
to serving metrics).  Completion callbacks drive the scheduler causally:
finish a request, free its slot, admit the next, issue the next step.

The emitted :class:`ServingReport` carries throughput, replica
utilization, and the serving tail metrics — TTFT (arrival to first
generated token), TPOT (mean inter-token time after the first), and E2E
latency — at p50/p95/p99, plus the raw per-request rows and the engine's
``SimResult`` for Gantt / Chrome-trace export
(:func:`repro.core.sim.trace.serving_chrome_trace`).

The measured counterpart is ``repro.launch.serve.BatchedServer``, which
logs the same per-request TTFT/TPOT — the paper's predicted-vs-measured
accuracy loop, extended to serving.
"""
from __future__ import annotations

from bisect import insort
from collections import deque
from dataclasses import dataclass, field
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sim.engine import SimResult, Simulator
from repro.serve_sim.cost import ServingCostModel
from repro.serve_sim.scheduler import (BatchScheduler, Decode, InFlight,
                                       Prefill, ReplicaState, Wait)
from repro.serve_sim.workload import Request, Workload


@dataclass(frozen=True)
class LatencyStats:
    """Percentile summary of one latency population (seconds)."""

    n: int
    mean: float
    p50: float
    p95: float
    p99: float
    max: float

    @staticmethod
    def of(values: List[float]) -> "LatencyStats":
        if not values:
            return LatencyStats(0, 0.0, 0.0, 0.0, 0.0, 0.0)
        a = np.asarray(values, dtype=np.float64)
        p50, p95, p99 = np.percentile(a, (50, 95, 99))
        return LatencyStats(n=len(a), mean=float(a.mean()), p50=float(p50),
                            p95=float(p95), p99=float(p99),
                            max=float(a.max()))


@dataclass
class RequestMetrics:
    """Per-request outcome (the rows behind the percentiles)."""

    rid: int
    replica: int
    slot: int
    t_arrive: float
    t_admit: float
    t_first: float
    t_done: float
    prompt_tokens: int
    output_tokens: int

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_arrive

    @property
    def queue_delay(self) -> float:
        return self.t_admit - self.t_arrive

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrive

    @property
    def tpot(self) -> float:
        n = self.output_tokens
        return (self.t_done - self.t_first) / (n - 1) if n > 1 else 0.0


@dataclass
class ServingReport:
    """End-to-end serving estimate for one (system, scheduler, traffic)."""

    workload: str
    scheduler: str
    cost_model: str
    replicas: int
    slots: int
    n_requests: int
    duration: float                    # makespan, seconds
    output_tokens: int
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    queue_delay: LatencyStats
    replica_util: float                # mean busy fraction across replicas
    requests: List[RequestMetrics] = field(default_factory=list)
    sim_result: Optional[SimResult] = None
    events: List[Tuple] = field(default_factory=list)

    @property
    def throughput_rps(self) -> float:
        return self.n_requests / self.duration if self.duration > 0 else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.output_tokens / self.duration if self.duration > 0 else 0.0

    def summary(self) -> str:
        return (
            f"serve[{self.cost_model}|{self.scheduler}|{self.workload}] "
            f"{self.replicas}x{self.slots} slots: "
            f"{self.n_requests} reqs in {self.duration:.1f}s "
            f"({self.throughput_rps:.2f} req/s, {self.throughput_tps:.1f} "
            f"tok/s, util={self.replica_util:.1%})\n"
            f"  TTFT p50/p95/p99 = {self.ttft.p50 * 1e3:.0f}/"
            f"{self.ttft.p95 * 1e3:.0f}/{self.ttft.p99 * 1e3:.0f} ms   "
            f"TPOT p50/p99 = {self.tpot.p50 * 1e3:.2f}/"
            f"{self.tpot.p99 * 1e3:.2f} ms   "
            f"E2E p99 = {self.e2e.p99:.2f} s")


def _slot_of(fl: InFlight) -> int:
    return fl.slot


class ServingSimulator:
    """Replays a :class:`Workload` against replicas of one cost model.

    ``scheduler_factory`` is called once per replica (schedulers are
    per-replica state-free policies); ``record_events`` keeps the
    admit/step/finish sequence for scheduler-parity assertions against the
    real ``BatchedServer``.
    """

    def __init__(self, cost: ServingCostModel,
                 scheduler_factory: Callable[[], BatchScheduler],
                 workload: Workload,
                 replicas: int = 1,
                 slots: int = 8,
                 record_events: bool = False):
        if replicas < 1 or slots < 1:
            raise ValueError("need replicas >= 1 and slots >= 1")
        self.cost = cost
        self.workload = workload
        self.replicas = [ReplicaState(index=r, slots=slots)
                         for r in range(replicas)]
        self.schedulers = [scheduler_factory() for _ in range(replicas)]
        self.slots = slots
        self.record_events = record_events
        self.events: List[Tuple] = []
        self.pending: deque = deque()
        self.metrics: List[RequestMetrics] = []
        self._sim = Simulator()
        # Express path: each replica is a ServiceLane (one phase at a time
        # on a dedicated single-server resource) — no Task construction or
        # dependency bookkeeping per decode step, record names deferred.
        self._lanes = [self._sim.lane(self._res(r), name_fn=self._name_fn(r))
                       for r in range(replicas)]
        # Completion handlers are bound once per replica, not per step.
        self._phase_done = [self._phase_handler(rep) for rep in self.replicas]
        self._decode_done = [self._decode_handler(rep)
                             for rep in self.replicas]
        # Free slots per replica as min-heaps: admission pops the lowest
        # slot id (the order the old sorted-set-difference scan produced).
        self._free_slots = [list(range(slots)) for _ in range(replicas)]
        # Decode-leap state: steps fused into the in-flight decode task and
        # the exact end time of its first step (token-1 emission).
        self._decode_k = [1] * replicas
        self._decode_tfirst = [0.0] * replicas
        self._total_out_tokens = 0
        self._wait_until: Dict[int, float] = {}   # replica -> armed wake-up

    @staticmethod
    def _res(r: int) -> str:
        return f"replica{r}"

    @staticmethod
    def _name_fn(r: int) -> Callable[[str, object], str]:
        def fmt(kind: str, info: object) -> str:
            if kind == "prefill":
                return f"prefill/r{r}/{'+'.join(str(i) for i in info)}"
            if isinstance(info, tuple):          # fused decode leap
                return f"decode/r{r}/b{info[0]}x{info[1]}"
            return f"decode/r{r}/b{info}"
        return fmt

    def _phase_handler(self, replica: ReplicaState):
        return lambda now: self._finish_phase(replica, now)

    def _decode_handler(self, replica: ReplicaState):
        return lambda now: self._finish_decode(replica, now)

    # ---- arrivals --------------------------------------------------------

    def _arrive(self, req: Request, now: float) -> None:
        self.pending.append(req)
        for replica in self.replicas:
            if not replica.busy:
                self._kick(replica, now)

    def _schedule_arrival(self, req: Request) -> None:
        self._sim.at(max(0.0, req.t_arrive),
                     lambda r=req: self._arrive(r, self._sim.now))

    # ---- the scheduling loop --------------------------------------------

    def _kick(self, replica: ReplicaState, now: float) -> None:
        if replica.busy:
            return
        sched = self.schedulers[replica.index]
        action = sched.decide(replica, self.pending, now)

        if isinstance(action, Prefill):
            self._start_prefill(replica, action, now)
        elif isinstance(action, Decode):
            self._start_decode(replica, now)
        elif isinstance(action, Wait):
            key = replica.index
            if np.isfinite(action.t) and self._wait_until.get(key) != action.t:
                self._wait_until[key] = action.t
                self._sim.at(action.t, lambda r=replica: self._wake(r))
        # None: replica stays idle until an arrival or wake-up kicks it

    def _wake(self, replica: ReplicaState) -> None:
        self._wait_until.pop(replica.index, None)
        self._kick(replica, self._sim.now)

    def _start_prefill(self, replica: ReplicaState, action: Prefill,
                       now: float) -> None:
        free = self._free_slots[replica.index]
        if len(action.reqs) > len(free):
            raise RuntimeError(
                f"scheduler {self.schedulers[replica.index].name!r} admitted "
                f"{len(action.reqs)} requests with only {len(free)} free "
                f"slots on replica{replica.index}")
        record = self.record_events
        rids = []
        for req in action.reqs:
            fl = InFlight(req=req, slot=heappop(free),
                          ctx=req.prompt_tokens, t_admit=now)
            # keep actives slot-sorted: decode iteration then matches the
            # real BatchedServer's per-slot order without re-sorting
            insort(replica.active, fl, key=_slot_of)
            rids.append(req.rid)
            if record:
                self.events.append(("admit", req.rid))
        dur = self.cost.prefill_time(action.tokens)
        replica.busy = True
        self._lanes[replica.index].submit(
            dur, self._phase_done[replica.index], kind="prefill",
            info=tuple(rids))

    def _start_decode(self, replica: ReplicaState, now: float) -> None:
        idx = replica.index
        sched = self.schedulers[idx]
        hold = sched.hold_finished
        # static batching pays for held (finished) slots too
        n = 0
        ctx = 0
        n_dec = 0
        k_min = 0
        for f in replica.active:
            if f.done:
                if hold:
                    n += 1
                    ctx += f.ctx
                continue
            n += 1
            ctx += f.ctx
            n_dec += 1
            rem = f.req.output_tokens - f.generated
            if k_min == 0 or rem < k_min:
                k_min = rem
        # Decode leap: until the shortest slot finishes, a steady_decode
        # policy will issue identical decode steps (admission is blocked:
        # no free slot, or hold_finished holds the batch) — fuse them into
        # one task, accumulating the exact per-step costs.
        k = 1
        if (k_min > 1 and sched.steady_decode and not self.record_events
                and (hold or not self._free_slots[idx])):
            k = k_min
        step_time = self.cost.decode_step_time
        c0 = step_time(n, ctx)
        dur = c0
        for _ in range(k - 1):
            ctx += n_dec
            dur += step_time(n, ctx)
        if self.record_events:
            self.events.append(
                ("step", tuple(sorted(f.req.rid for f in replica.active
                                      if not f.done))))
        self._decode_k[idx] = k
        self._decode_tfirst[idx] = now + c0
        replica.busy = True
        self._lanes[idx].submit(
            dur, self._decode_done[idx], kind="decode",
            info=n if k == 1 else (n, k))

    def _finish_phase(self, replica: ReplicaState, now: float) -> None:
        replica.busy = False
        self._kick(replica, now)

    def _finish_decode(self, replica: ReplicaState, now: float) -> None:
        idx = replica.index
        sched = self.schedulers[idx]
        k = self._decode_k[idx]
        t_first = self._decode_tfirst[idx]
        finished: List[InFlight] = []
        decoding_left = 0
        tokens = 0
        # actives are slot-sorted, mirroring the real BatchedServer's
        # finish ordering
        for fl in replica.active:
            if fl.done:
                continue
            fl.generated += k
            fl.ctx += k
            tokens += k
            if fl.t_first is None:
                fl.t_first = t_first
            if fl.generated >= fl.req.output_tokens:
                fl.done = True
                finished.append(fl)
            else:
                decoding_left += 1
        self._total_out_tokens += tokens
        release = finished
        if sched.hold_finished:
            # the batch drains only when every member is done
            release = [] if decoding_left else list(replica.active)
        free = self._free_slots[replica.index]
        for fl in release:
            replica.active.remove(fl)
            heappush(free, fl.slot)
        for fl in finished:
            if self.record_events:
                self.events.append(("finish", fl.req.rid))
            self.metrics.append(RequestMetrics(
                rid=fl.req.rid, replica=replica.index, slot=fl.slot,
                t_arrive=fl.req.t_arrive, t_admit=fl.t_admit,
                t_first=fl.t_first, t_done=now,
                prompt_tokens=fl.req.prompt_tokens,
                output_tokens=fl.req.output_tokens))
            follow = self.workload.on_complete(fl.req, now)
            if follow is not None:
                self._schedule_arrival(follow)
        replica.busy = False
        self._kick(replica, now)

    # ---- entry point -----------------------------------------------------

    def run(self) -> ServingReport:
        for req in self.workload.initial():
            self._schedule_arrival(req)
        sim_result = self._sim.run()

        util = 0.0
        if sim_result.makespan > 0:
            util = sum(
                sim_result.resource_busy.get(self._res(r.index), 0.0)
                for r in self.replicas
            ) / (len(self.replicas) * sim_result.makespan)

        self.metrics.sort(key=lambda m: m.rid)
        return ServingReport(
            workload=self.workload.name,
            scheduler=self.schedulers[0].name,
            cost_model=self.cost.name,
            replicas=len(self.replicas), slots=self.slots,
            n_requests=len(self.metrics),
            duration=sim_result.makespan,
            output_tokens=self._total_out_tokens,
            ttft=LatencyStats.of([m.ttft for m in self.metrics]),
            tpot=LatencyStats.of([m.tpot for m in self.metrics
                                  if m.output_tokens > 1]),
            e2e=LatencyStats.of([m.e2e for m in self.metrics]),
            queue_delay=LatencyStats.of([m.queue_delay
                                         for m in self.metrics]),
            replica_util=util,
            requests=self.metrics,
            sim_result=sim_result,
            events=self.events)


def simulate_serving(cost: ServingCostModel,
                     scheduler_factory: Callable[[], BatchScheduler],
                     workload: Workload, replicas: int = 1, slots: int = 8,
                     record_events: bool = False) -> ServingReport:
    """One-shot convenience wrapper around :class:`ServingSimulator`."""
    return ServingSimulator(cost, scheduler_factory, workload,
                            replicas=replicas, slots=slots,
                            record_events=record_events).run()
